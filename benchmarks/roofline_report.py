"""Roofline report generator: reads dry-run artifacts, emits the per-cell
three-term roofline table (EXPERIMENTS.md §Roofline) and CSV summary rows.
"""
from __future__ import annotations

import gzip
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from benchmarks.common import Row  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.roofline.hlo_analysis import (  # noqa: E402
    analyze_hlo,
    dominant_term,
    roofline_terms,
)

CHIPS = {"16_16": 256, "2_16_16": 512}


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def improvement_note(dom: str, arch: str, shape: str) -> str:
    if dom == "memory":
        return ("fuse more elementwise chains / wider remat blocks to cut "
                "HLO bytes; bf16 residual stream end-to-end")
    if dom == "collective":
        return ("bf16 (not f32) TP psums + Megatron-style sequence-parallel "
                "norms to halve per-layer all-reduce payload")
    return ("raise arithmetic intensity: larger per-device microbatch or "
            "causal-skip flash attention to cut redundant score FLOPs")


def analyze_cell(art_dir: pathlib.Path, stem: str) -> dict | None:
    jpath = art_dir / f"{stem}.json"
    hpath = art_dir / f"{stem}.hlo.txt.gz"
    if not (jpath.exists() and hpath.exists()):
        return None
    rec = json.loads(jpath.read_text())
    analysis = analyze_hlo(gzip.open(hpath, "rt").read())
    terms = roofline_terms(analysis)
    dom = dominant_term(terms)
    chips = CHIPS[rec["mesh"].replace("x", "_")]
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    util = mf / max(analysis["flops"], 1.0)
    bound = max(terms.values())
    # Roofline fraction: useful model compute time / achievable step time
    # (the bound given the dominant term).
    frac = (mf / 197e12) / max(bound, 1e-12)
    return {
        "rec": rec,
        "analysis": analysis,
        "terms": terms,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": util,
        "roofline_fraction": frac,
    }


def all_cells(art_dir: pathlib.Path) -> list[dict]:
    out = []
    for jpath in sorted(art_dir.glob("*.json")):
        cell = analyze_cell(art_dir, jpath.stem)
        if cell:
            out.append(cell)
    return out


def summary_rows(art_dir: pathlib.Path) -> list[Row]:
    rows = []
    for cell in all_cells(art_dir):
        r = cell["rec"]
        t = cell["terms"]
        rows.append(Row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            0.0,
            f"compute_s={t['compute_s']:.3f};memory_s={t['memory_s']:.3f};"
            f"collective_s={t['collective_s']:.3f};"
            f"dominant={cell['dominant']};"
            f"model_over_hlo={cell['useful_ratio']:.3f};"
            f"roofline_frac={cell['roofline_fraction']:.3f}",
        ))
    return rows


def markdown_table(art_dir: pathlib.Path, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL/HLO flops | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in all_cells(art_dir):
        r = cell["rec"]
        if r["mesh"] != mesh:
            continue
        t = cell["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} "
            f"| {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| **{cell['dominant']}** | {cell['useful_ratio']:.3f} "
            f"| {cell['roofline_fraction']:.3f} "
            f"| {improvement_note(cell['dominant'], r['arch'], r['shape'])} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    d = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    print(markdown_table(d))
