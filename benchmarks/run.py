"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a trailing roofline summary
derived from the dry-run artifacts when present).

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig9] [--quick]

``--quick`` is the CI smoke mode: reduced device counts, restricted to the
cohort-engine perf benchmarks (``fig8_device_tier_batched`` and
``multi_grade_round``), and a non-zero exit when any claim row reports
``ok=False`` — so the round-engine perf path can't silently break.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import common  # noqa: E402
from benchmarks.paper_benchmarks import ALL_BENCHMARKS  # noqa: E402

QUICK_BENCHMARKS = ("fig8_device_tier_batched", "multi_grade_round")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: reduced scales, perf benchmarks only, "
                         "fail on ok=False claim rows")
    args = ap.parse_args(argv)
    common.QUICK = args.quick

    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHMARKS:
        if args.only and args.only not in bench.__name__:
            continue
        if args.quick and not args.only and \
                bench.__name__ not in QUICK_BENCHMARKS:
            continue
        try:
            for row in bench():
                print(row.csv(), flush=True)
                if args.quick and "ok=False" in row.derived:
                    failures += 1
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{bench.__name__},0.0,ERROR={type(e).__name__}:{e}",
                  flush=True)

    # Roofline summary rows from dry-run artifacts, if present.
    art = pathlib.Path("artifacts/dryrun")
    if art.exists():
        try:
            from benchmarks.roofline_report import summary_rows
            for row in summary_rows(art):
                print(row.csv(), flush=True)
        except Exception as e:
            print(f"roofline_summary,0.0,ERROR={type(e).__name__}:{e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
