"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a trailing roofline summary
derived from the dry-run artifacts when present).

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig9]
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.paper_benchmarks import ALL_BENCHMARKS  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHMARKS:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for row in bench():
                print(row.csv(), flush=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{bench.__name__},0.0,ERROR={type(e).__name__}:{e}",
                  flush=True)

    # Roofline summary rows from dry-run artifacts, if present.
    art = pathlib.Path("artifacts/dryrun")
    if art.exists():
        try:
            from benchmarks.roofline_report import summary_rows
            for row in summary_rows(art):
                print(row.csv(), flush=True)
        except Exception as e:
            print(f"roofline_summary,0.0,ERROR={type(e).__name__}:{e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
