"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a trailing roofline summary
derived from the dry-run artifacts when present).

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig9] [--quick]
                                               [--json BENCH_PR3.json]

``--quick`` is the CI smoke mode: reduced device counts, restricted to the
cohort-engine perf benchmarks (``fig8_device_tier_batched``,
``multi_grade_round``, ``round_pipeline``), and a non-zero exit when any claim
row reports ``ok=False`` — so the round-engine perf path can't silently break.

``--json PATH`` persists every row to a machine-readable artifact.  The repo
commits one ``BENCH_PR<N>.json`` per PR; when a previous artifact exists, the
harness prints ``bench_diff/...`` rows comparing throughput metrics
(devices_per_s, speedup, ...) against it, so the perf trajectory across PRs
is diffable by machines and reviewers alike.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import common  # noqa: E402
from benchmarks.paper_benchmarks import ALL_BENCHMARKS  # noqa: E402

QUICK_BENCHMARKS = ("fig8_device_tier_batched", "multi_grade_round",
                    "round_pipeline", "million_device_round",
                    "quantized_wire", "workers_round",
                    "multi_task_schedule", "multi_task_preemption",
                    "continuous_serving")

# Throughput-ish metrics worth tracking across PRs (higher is better except
# slowdown/makespan_s/queueing_delay_s; the diff just reports the ratio
# either way).
DIFF_METRICS = ("devices_per_s", "device_messages_per_s",
                "worker_device_messages_per_s", "speedup",
                "slowdown", "per_device_us", "makespan_s",
                "queueing_delay_s", "bytes_per_round", "loss_drift_pct",
                "p99_latency_s", "goodput_rps")


def parse_derived(derived: str) -> dict:
    """``k1=v1;k2=v2`` -> dict with floats where they parse."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def previous_artifact(out_path: pathlib.Path) -> pathlib.Path | None:
    """Newest committed ``BENCH_PR<N>.json`` that isn't the output file."""
    best, best_n = None, -1
    for p in out_path.resolve().parent.glob("BENCH_PR*.json"):
        if p.resolve() == out_path.resolve():
            continue
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", p.name)
        if m and int(m.group(1)) > best_n:
            best, best_n = p, int(m.group(1))
    return best


def diff_rows(prev: dict, cur_rows: list[dict]) -> list[str]:
    """CSV lines comparing tracked metrics against a previous artifact."""
    prev_rows = {r["name"]: r for r in prev.get("rows", ())}
    lines = []
    for r in cur_rows:
        p = prev_rows.get(r["name"])
        if p is None:
            continue
        pm, cm = parse_derived(p["derived"]), parse_derived(r["derived"])
        for k in DIFF_METRICS:
            pv, cv = pm.get(k), cm.get(k)
            if isinstance(pv, float) and isinstance(cv, float) and pv:
                lines.append(
                    f"bench_diff/{r['name']},0.0,"
                    f"metric={k};prev={pv:g};now={cv:g};ratio={cv / pv:.3f}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: reduced scales, perf benchmarks only, "
                         "fail on ok=False claim rows")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist rows to a JSON artifact and diff tracked "
                         "metrics against the newest BENCH_PR*.json")
    args = ap.parse_args(argv)
    common.QUICK = args.quick

    print("name,us_per_call,derived")
    failures = 0
    collected: list[dict] = []
    for bench in ALL_BENCHMARKS:
        if args.only and args.only not in bench.__name__:
            continue
        if args.quick and not args.only and \
                bench.__name__ not in QUICK_BENCHMARKS:
            continue
        try:
            for row in bench():
                print(row.csv(), flush=True)
                rec = {"name": row.name,
                       "us_per_call": float(row.us_per_call),
                       "derived": row.derived}
                if isinstance(row.us_per_call, common.TimedStat):
                    # %std + iteration count ride into the artifact so a
                    # diff reader can weigh noisy means appropriately.
                    rec["pstd"] = row.us_per_call.pstd
                    rec["iters"] = row.us_per_call.iters
                collected.append(rec)
                if args.quick and "ok=False" in row.derived:
                    failures += 1
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{bench.__name__},0.0,ERROR={type(e).__name__}:{e}",
                  flush=True)

    # Roofline summary rows from dry-run artifacts, if present.
    art = pathlib.Path("artifacts/dryrun")
    if art.exists():
        try:
            from benchmarks.roofline_report import summary_rows
            for row in summary_rows(art):
                print(row.csv(), flush=True)
        except Exception as e:
            print(f"roofline_summary,0.0,ERROR={type(e).__name__}:{e}")

    if args.json:
        out_path = pathlib.Path(args.json)
        out_path.write_text(json.dumps(
            {"quick": args.quick, "only": args.only, "rows": collected},
            indent=1))
        prev = previous_artifact(out_path)
        if prev is not None:
            try:
                prev_data = json.loads(prev.read_text())
                if bool(prev_data.get("quick")) != bool(args.quick):
                    # Quick and full runs use different scales; a ratio
                    # between them would read as a phantom regression.
                    print(f"bench_diff,0.0,SKIPPED=scale_mismatch:"
                          f"{prev.name}")
                else:
                    for line in diff_rows(prev_data, collected):
                        print(line, flush=True)
            except (json.JSONDecodeError, KeyError) as e:
                print(f"bench_diff,0.0,ERROR={type(e).__name__}:{e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
