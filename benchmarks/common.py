"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import DeviceFlow, Message
from repro.core.federation import AggregationService, Trigger
from repro.data.synthetic_ctr import CTRDataset, make_federated_ctr
from repro.models import ctr as ctr_lib


# Set by ``benchmarks.run --quick``: CI smoke mode with reduced scales.
QUICK = False


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        # TimedStat means carry their own spread; surface it so CSV/JSON
        # consumers can tell a tight mean from a noisy one.
        extra = (f";pstd={self.us_per_call.pstd:.1f}"
                 if isinstance(self.us_per_call, TimedStat)
                 and self.us_per_call.iters > 1 else "")
        return f"{self.name},{float(self.us_per_call):.1f},{self.derived}{extra}"


class TimedStat(float):
    """Mean microseconds per call, float-compatible everywhere a plain
    timing was used, with the spread riding along: ``pstd`` is the standard
    deviation as a percentage of the mean, ``iters`` the number of timed
    iterations it was computed over."""

    __slots__ = ("pstd", "iters")

    def __new__(cls, times_s) -> "TimedStat":
        arr = np.asarray(times_s, dtype=float)
        mean = float(arr.mean())
        self = float.__new__(cls, mean * 1e6)
        self.pstd = float(100.0 * arr.std() / mean) if mean > 0 else 0.0
        self.iters = int(arr.size)
        return self


def timed(fn: Callable, *args, repeats: int = 1, warmup: int = 0,
          target_total_secs: float | None = None, **kwargs):
    """Time ``fn(*args, **kwargs)``; returns ``(last_output, TimedStat)``.

    ``warmup`` iterations run untimed first, so jit compilation and cache
    population don't pollute the mean.  After at least ``repeats`` timed
    iterations, iteration continues until ``target_total_secs`` of timed
    wall-clock has accumulated (when given) — a %std computed over a
    handful of samples is mostly noise.
    """
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args, **kwargs))
    times: list[float] = []
    while (len(times) < repeats
           or (target_total_secs is not None
               and sum(times) < target_total_secs)):
        t0 = time.perf_counter()
        # JAX dispatch is async: block on returned arrays (pytrees pass
        # through; non-array leaves are untouched) so device-side timings
        # report compute cost, not dispatch cost.
        out = jax.block_until_ready(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return out, TimedStat(times)


def run_federated_ctr(
    *,
    num_devices: int,
    records_per_device: int = 20,
    dim: int = 64,
    rounds: int = 5,
    local_epochs: int = 10,
    lr: float = 1e-3,
    dtype=jnp.float32,
    seed: int = 0,
    deviceflow_hook=None,
    trigger: Trigger | None = None,
    positive_rate_split=None,
    eval_data: CTRDataset | None = None,
) -> dict:
    """The paper's experiment skeleton: LR-on-CTR federated rounds.

    Returns per-round global accuracy/loss on held-out devices.  The local
    step runs vectorized over the whole cohort (logical-simulation tier).
    """
    data = make_federated_ctr(
        num_devices=num_devices, records_per_device=records_per_device,
        dim=dim, seed=seed, positive_rate_split=positive_rate_split)
    test = eval_data or make_federated_ctr(
        num_devices=100, records_per_device=records_per_device,
        dim=dim, seed=seed + 1)
    local = ctr_lib.make_local_train_fn(lr=lr, epochs=local_epochs)
    vlocal = jax.jit(jax.vmap(local))

    params = ctr_lib.lr_init(jax.random.PRNGKey(seed), dim)
    dev_ids = np.arange(num_devices)
    X, Y, counts = data.stacked_shards(dev_ids, records_per_device)
    mask = (np.arange(records_per_device)[None] < counts[:, None]).astype(np.float32)
    Xj, Yj, Mj = jnp.asarray(X), jnp.asarray(Y), jnp.asarray(mask)
    Xt, Yt = jnp.asarray(test.features), jnp.asarray(test.labels)

    cast = lambda t: jax.tree.map(lambda x: x.astype(dtype), t)
    history = []
    for rnd in range(rounds):
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p.astype(dtype), (num_devices,) + p.shape),
            params)
        keys = jax.random.split(jax.random.PRNGKey(rnd), num_devices)
        new_params, metrics = vlocal(
            stacked, {"x": Xj.astype(dtype), "y": Yj, "mask": Mj}, keys)
        new_params = jax.tree.map(lambda x: x.astype(jnp.float32), new_params)
        if deviceflow_hook is not None:
            params = deviceflow_hook(rnd, new_params, counts, params)
        else:
            w = counts.astype(np.float64) / counts.sum()
            params = jax.tree.map(
                lambda stack: jnp.einsum("c...,c->...", stack, jnp.asarray(w, stack.dtype)),
                new_params)
        acc = float(ctr_lib.accuracy(params, Xt, Yt))
        loss = float(ctr_lib.bce_loss(params, Xt, Yt))
        history.append({"round": rnd, "acc": acc, "loss": loss})
    return {"history": history, "final_acc": history[-1]["acc"],
            "final_loss": history[-1]["loss"], "params": params}
