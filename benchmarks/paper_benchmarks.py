"""One benchmark per paper table/figure (§VI).  Each returns Rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Row, run_federated_ctr, timed
from repro.core import allocation as alloc
from repro.core.deviceflow import DeviceFlow, Message
from repro.core.devicemodel import GRADES, DeviceModel, Stage
from repro.core.federation import (
    AggregationService,
    SampleThresholdTrigger,
    ScheduledTrigger,
)
from repro.core.strategies import (
    AccumulatedStrategy,
    TimeIntervalStrategy,
    discretize_curve,
)
from repro.core.task import GradeSpec
from repro.core.traffic_curves import right_tailed_normal, table2_curves
from repro.data.synthetic_ctr import make_federated_ctr
from repro.models import ctr as ctr_lib


# --------------------------------------------------------------------------- #
# Table I — physical performance metrics per stage
# --------------------------------------------------------------------------- #
def table1_device_metrics() -> list[Row]:
    rows = []
    reports = {}
    for grade_name, grade in GRADES.items():
        model = DeviceModel(0, grade, seed=7)
        (rep, us) = timed(model.run_round, 0)
        reports[grade_name] = rep
        for stage in Stage:
            rows.append(Row(
                f"table1/{grade_name}/stage{int(stage)}",
                us / len(Stage),
                f"power_mah={rep.stage_power_mah[stage]:.2f};"
                f"dur_min={rep.stage_duration_min[stage]:.2f}",
            ))
    hi, lo = reports["High"], reports["Low"]
    ok = (hi.total_power_mah < lo.total_power_mah
          and hi.stage_duration_min[Stage.TRAINING]
          < lo.stage_duration_min[Stage.TRAINING])
    rows.append(Row("table1/claim_high_beats_low", 0.0,
                    f"high_cheaper_and_faster={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 6 — hybrid split changes accuracy by < 0.5 %
# --------------------------------------------------------------------------- #
def fig6_hybrid_accuracy() -> list[Row]:
    rows = []
    worst = 0.0
    for scale in (4, 20, 100):
        ref = None
        for frac_logical, label in ((1.0, "type1"), (0.5, "type3"),
                                    (0.0, "type5")):
            n_log = round(scale * frac_logical)

            def hook(rnd, new_params, counts, params, n_log=n_log):
                # Logical tier result = f32 path; device tier = bf16 path
                # (the paper's PyMNN vs C++ MNN operator discrepancy).
                mixed = jax.tree.map(
                    lambda stack: jnp.concatenate([
                        stack[:n_log],
                        stack[n_log:].astype(jnp.bfloat16).astype(jnp.float32),
                    ]), new_params)
                w = counts.astype(np.float64) / counts.sum()
                return jax.tree.map(
                    lambda stack: jnp.einsum(
                        "c...,c->...", stack, jnp.asarray(w, stack.dtype)),
                    mixed)

            t0 = time.perf_counter()
            out = run_federated_ctr(
                num_devices=scale, rounds=5, deviceflow_hook=hook, seed=3)
            us = (time.perf_counter() - t0) * 1e6
            if ref is None:
                ref = out["final_acc"]
            diff = abs(out["final_acc"] - ref) * 100
            worst = max(worst, diff)
            rows.append(Row(
                f"fig6/scale{scale}/{label}", us,
                f"acc={out['final_acc']:.4f};diff_pct={diff:.3f}"))
    rows.append(Row("fig6/claim_diff_below_0.5pct", 0.0,
                    f"max_diff_pct={worst:.3f};ok={worst < 0.5}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 7 — optimized allocation minimizes execution time at every scale
# --------------------------------------------------------------------------- #
def fig7_allocation_time() -> list[Row]:
    rows = []
    runtimes = [
        alloc.GradeRuntime(alpha=16.2, beta=21.6, lam=15.0),  # High (Table I)
        alloc.GradeRuntime(alpha=27.0, beta=21.6 * 0.8, lam=15.0),  # Low
    ]
    all_ok = True
    for scale in (4, 20, 100, 500):
        specs = [
            GradeSpec("High", scale, 0, logical_bundles=200,
                      bundles_per_device=8, physical_devices=17),
            GradeSpec("Low", scale, 0, logical_bundles=200,
                      bundles_per_device=2, physical_devices=13),
        ]
        (opt, us) = timed(alloc.solve_allocation, specs, runtimes)
        fixed = {
            f"type{i+1}": alloc.fixed_ratio_allocation(specs, runtimes, f)
            for i, f in enumerate((1.0, 0.75, 0.5, 0.25, 0.0))
        }
        best_fixed = min(v.makespan for v in fixed.values())
        ok = opt.makespan <= best_fixed + 1e-9
        all_ok &= ok
        rows.append(Row(
            f"fig7/scale{scale}", us,
            f"optimal_s={opt.makespan:.1f};best_fixed_s={best_fixed:.1f};"
            f"optimal_wins={ok}"))
    rows.append(Row("fig7/claim_optimal_beats_all_ratios", 0.0, f"ok={all_ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 8 — scalability of the vectorized client engine
# --------------------------------------------------------------------------- #
def fig8_scalability() -> list[Row]:
    rows = []
    dim, rpd = 64, 16
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=10)
    vlocal = jax.jit(jax.vmap(local))
    rng = np.random.default_rng(0)
    prev_per_dev = None
    for n in (100, 1000, 10000):
        X = jnp.asarray(rng.standard_normal((n, rpd, dim)), jnp.float32)
        Y = jnp.asarray((rng.random((n, rpd)) < 0.3), jnp.float32)
        M = jnp.ones((n, rpd), jnp.float32)
        params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (n,) + p.shape), params)
        keys = jax.random.split(jax.random.PRNGKey(0), n)
        batch = {"x": X, "y": Y, "mask": M}
        jax.block_until_ready(vlocal(stacked, batch, keys))  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(vlocal(stacked, batch, keys))
        dt = time.perf_counter() - t0
        per_dev_us = dt / n * 1e6
        rows.append(Row(
            f"fig8/devices{n}", dt * 1e6,
            f"per_device_us={per_dev_us:.2f};round_s={dt:.3f}"))
        prev_per_dev = per_dev_us
    # Extrapolated 100k-device round (the paper's largest scale).
    rows.append(Row(
        "fig8/devices100000_extrapolated", 0.0,
        f"round_s_est={prev_per_dev * 100000 / 1e6:.2f}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 8 (cont.) — batched device tier: devices simulated per second
# --------------------------------------------------------------------------- #
def fig8_device_tier_batched() -> list[Row]:
    """Devices-per-second of the *device-simulation* tier (bf16 backend).

    Compares the batched cohort engine (``DeviceTier.run_cohort`` + one
    vectorized ``DeviceFleet`` sample) against the seed's per-device loop
    (one ``jax.jit`` dispatch + a fresh ``DeviceModel`` per device).  Also
    checks the cohort path reproduces the loop's numerics per device.
    """
    from repro.core.simulation import DeviceTier

    rows = []
    dim, rpd = 64, 16
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=10)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    rng = np.random.default_rng(0)
    tier = DeviceTier(local, GRADES["High"], cohort_size=1024)
    take = lambda tree, sl: jax.tree.map(lambda x: x[sl], tree)
    loop_per_dev_s = None
    sizes = (256,) if common.QUICK else (1000, 10000)

    def run_batched(batch, keys, n, round_idx):
        outs = []
        for lo in range(0, n, tier.cohort_size):
            sl = slice(lo, min(lo + tier.cohort_size, n))
            new_p, _ = tier.run_cohort(params, take(batch, sl), keys[sl])
            outs.append(new_p)
        tier.sample_round(np.arange(n), round_idx)  # behavioral sample
        return jax.block_until_ready(
            jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs))

    for n in sizes:
        batch = {
            "x": jnp.asarray(rng.standard_normal((n, rpd, dim)), jnp.float32),
            "y": jnp.asarray((rng.random((n, rpd)) < 0.3), jnp.float32),
            "mask": jnp.ones((n, rpd), jnp.float32),
        }
        keys = jax.random.split(jax.random.PRNGKey(1), n)
        run_batched(batch, keys, n, 0)  # compile
        t0 = time.perf_counter()
        stacked = run_batched(batch, keys, n, 1)
        dt_batched = time.perf_counter() - t0

        if n == sizes[0]:  # seed per-device loop, measured once (smallest n)
            tier._jit(params, take(batch, 0), keys[0])  # compile
            t0 = time.perf_counter()
            loop_out = []
            for j in range(n):
                new_p, _, _ = tier.run_device(
                    j, params, take(batch, j), keys[j], 1, benchmark=True)
                loop_out.append(new_p)
            jax.block_until_ready(loop_out[-1])
            dt_loop = time.perf_counter() - t0
            loop_per_dev_s = dt_loop / n
            loop_stack = jax.tree.map(
                lambda *xs: jnp.stack(xs), *loop_out)
            max_diff = max(
                float(jnp.abs(a - b).max()) for a, b in zip(
                    jax.tree.leaves(stacked), jax.tree.leaves(loop_stack)))
            speedup = dt_loop / dt_batched
            rows.append(Row(
                f"fig8/device_tier/loop{n}", dt_loop * 1e6,
                f"devices_per_s={n / dt_loop:.0f}"))
            rows.append(Row(
                "fig8/device_tier/claim_batched_5x_and_matches", 0.0,
                f"speedup={speedup:.1f};max_dev_diff={max_diff:.2e};"
                f"ok={speedup >= 5.0 and max_diff < 2e-2}"))
        rows.append(Row(
            f"fig8/device_tier/batched{n}", dt_batched * 1e6,
            f"devices_per_s={n / dt_batched:.0f};"
            f"loop_est_s={loop_per_dev_s * n:.2f}"))
    return rows


# --------------------------------------------------------------------------- #
# Grade-partitioned round engine — multi-grade devices/s vs single-grade
# --------------------------------------------------------------------------- #
def multi_grade_round() -> list[Row]:
    """Two-grade (High+Low) federated round driven by ``solve_allocation``.

    Fleet-calibrated runtimes feed the allocator, a ``RoundPlan`` maps each
    grade onto its own ``DeviceTier``+fleet, and ``run_plan_round`` executes
    both cohorts and merges sampled arrival times.  Claim: the grade-
    partitioned engine's devices/s stays within 2x of the single-grade
    ``fig8/device_tier`` batched path on the same bf16 device workload.
    """
    from repro.core import (
        GradeSpec, RoundPlan, RuntimeCalibrator, solve_allocation,
    )
    from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier

    rows = []
    dim, rpd = 64, 16
    # The zero-copy engine cut round times enough that at small n the fixed
    # Python engine overhead (plan validation, messages, fleet sampling)
    # dominates the ratio; 2048 devices keep the claim about compute.
    n = 2048 if common.QUICK else 4096
    cohort = min(1024, n // 2)
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=10)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.standard_normal((n, rpd, dim)), jnp.float32),
        "y": jnp.asarray((rng.random((n, rpd)) < 0.3), jnp.float32),
        "mask": jnp.ones((n, rpd), jnp.float32),
    }
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    counts = np.full(n, rpd)
    take = lambda tree, sl: jax.tree.map(lambda x: x[sl], tree)

    # Baseline: the fig8/device_tier batched path (pure bf16 cohorts + one
    # fleet sample, no round engine around it).
    tier = DeviceTier(local, GRADES["High"], cohort_size=cohort)

    def run_single(round_idx):
        outs = []
        for lo in range(0, n, tier.cohort_size):
            sl = slice(lo, min(lo + tier.cohort_size, n))
            new_p, _ = tier.run_cohort(params, take(batch, sl), keys[sl])
            outs.append(new_p)
        tier.sample_round(np.arange(n), round_idx)
        return jax.block_until_ready(
            jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs))

    run_single(0)  # compile
    t0 = time.perf_counter()
    run_single(1)
    dt_single = time.perf_counter() - t0
    rows.append(Row(
        f"multi_grade_round/single_grade{n}", dt_single * 1e6,
        f"devices_per_s={n / dt_single:.0f}"))

    # Grade-partitioned engine: allocator split (all-physical here, so both
    # measurements run the identical bf16 device workload), one tier+fleet
    # per grade, fleet-calibrated runtimes, merged arrival times.
    cal = RuntimeCalibrator()
    specs = [
        GradeSpec("High", n // 2, benchmarking_devices=2, logical_bundles=0,
                  physical_devices=n // 8),
        GradeSpec("Low", n // 2, benchmarking_devices=2, logical_bundles=0,
                  physical_devices=n // 8),
    ]
    plan = RoundPlan.from_allocation(
        solve_allocation(specs, cal.runtimes_for(specs)), specs)
    sim = HybridSimulation(
        LogicalTier(local, cohort_size=cohort),
        tiers={g: DeviceTier(local, GRADES[g], cohort_size=cohort)
               for g in ("High", "Low")})
    gb = {"High": take(batch, slice(0, n // 2)),
          "Low": take(batch, slice(n // 2, n))}
    gs = {"High": counts[:n // 2], "Low": counts[n // 2:]}
    jax.block_until_ready(sim.run_plan_round(
        0, 0, params, plan, gb, gs, jax.random.PRNGKey(4),
        calibrator=cal).client_metrics)  # compile
    t0 = time.perf_counter()
    out = sim.run_plan_round(0, 1, params, plan, gb, gs, jax.random.PRNGKey(5),
                             calibrator=cal)
    # The zero-copy engine dispatches asynchronously: block on the cohort
    # metrics (outputs of the same dispatches as the update buffers) so the
    # timing covers compute, not dispatch.
    jax.block_until_ready(out.client_metrics)
    dt_multi = time.perf_counter() - t0
    mk = {g: b.makespan_s for g, b in out.per_grade.items()}
    rows.append(Row(
        f"multi_grade_round/devices{n}", dt_multi * 1e6,
        f"devices_per_s={n / dt_multi:.0f};"
        f"makespan_high_s={mk['High']:.1f};makespan_low_s={mk['Low']:.1f};"
        f"reports={len(out.reports)}"))
    # Calibrated runtimes drove the split; the makespan ordering must match
    # Table I (Low devices are slower) and throughput stays within 2x.
    ratio = dt_multi / dt_single
    ok = (ratio <= 2.0 and mk["Low"] > 0 and mk["High"] > 0
          and len(out.reports) == 4
          and out.per_grade["Low"].mean_duration_s
          > out.per_grade["High"].mean_duration_s)
    rows.append(Row(
        "multi_grade_round/claim_within_2x_of_single_grade", 0.0,
        f"slowdown={ratio:.2f};ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Zero-copy round pipeline — handle payloads + fused fed_reduce aggregation
# --------------------------------------------------------------------------- #
class _PR2LogicalTier:
    """The PR 2 logical tier, preserved as the host-path baseline.

    Reproduces the PR 2 ``run_cohort`` faithfully: the cohort broadcast of
    the global params is materialized EAGERLY on device before the vmapped
    dispatch (an O(cohort x params) copy per chunk), exactly as the engine
    shipped in PR 2.  The zero-copy engine stacks inside jit instead.
    """

    def __init__(self, local_train, *, cohort_size=64, dtype=jnp.float32):
        self.local_train = local_train
        self.cohort_size = cohort_size
        self.dtype = dtype
        self._compiled = None

    def run_cohort(self, global_params, batches, rng, num_samples):
        from repro.core.simulation import CohortResult, _stack_params
        if self._compiled is None:
            self._compiled = jax.jit(
                jax.vmap(self.local_train, in_axes=(0, 0, 0)))
        n = int(jax.tree.leaves(batches)[0].shape[0])
        cast = lambda x: (x.astype(self.dtype)
                          if jnp.issubdtype(x.dtype, jnp.floating) else x)
        stacked = jax.tree.map(cast, _stack_params(global_params, n))
        rngs = jax.random.split(rng, n)
        params, metrics = self._compiled(stacked, batches, rngs)
        return CohortResult(params=params, metrics=metrics,
                            num_samples=jnp.asarray(num_samples))


def round_pipeline() -> list[Row]:
    """End-to-end round throughput: zero-copy vs the PR 2 host path.

    1k devices (256 in ``--quick``) train a >=1M-param model of 64 stacked
    blocks (128 parameter tensors — mid-size-checkpoint magnitude); the
    local step is deliberately compute-light so the round is
    transport/aggregation-bound, the regime §IV targets for large configs.
    Every update flows through DeviceFlow into the aggregation service.

    The host path is PR 2 verbatim: eager cohort broadcast, blocking
    ``jax.device_get`` per chunk, per-device host pytrees as payloads, and
    the per-message ``fedavg_delta`` chain — O(devices x leaves) host ops.
    The zero-copy path ships ``UpdateHandle``s into device-resident
    ``UpdateBuffer``s and aggregates with one fused ``fed_reduce`` weighted
    row-reduction per leaf in a single XLA dispatch, donating the old
    global-params buffer between rounds and recycling retired update
    buffers into the next round's cohort dispatches.  Claims: >=3x round
    throughput and matching numerics (both paths aggregate identical f32
    cohort outputs).

    Measurement note: per-round times take the MIN over ``timed_rounds``
    (steady state on noisy shared boxes; buffer recycling needs one round
    of warm-up).  Observed on a ~2 GB/s-streaming CPU container: ~4.7x at
    1k devices / 1M params, ~6x at the CI scale; the margin widens further
    on any platform with a real device/host bandwidth split (the regime
    the paper's clusters and TPUs actually run in).
    """
    from repro.core import ClientCountTrigger
    from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier

    n = 256 if common.QUICK else 1000
    blocks, width = (64, 64) if common.QUICK else (64, 128)
    timed_rounds = 4  # per-round timing; min taken (shared boxes are noisy)
    n_params = blocks * (width * width + width)
    rows = []
    rng = np.random.default_rng(0)

    def local_train(params, batch, key):
        # Compute-light local step (one scaled-decay update per tensor,
        # driven by the device's batch): the benchmark isolates the round
        # PIPELINE — transport + aggregation — not client matmul throughput.
        s = 1e-3 * jnp.tanh(jnp.mean(batch["x"]))
        return jax.tree.map(lambda p: p * (1.0 - s), params), {"loss": s}

    params0 = {
        f"blk{i:03d}": {
            "w": jnp.asarray(rng.standard_normal((width, width)) * 0.05,
                             jnp.float32),
            "b": jnp.zeros((width,), jnp.float32),
        } for i in range(blocks)
    }
    batches = {"x": jnp.asarray(rng.standard_normal((n, 2, 16)), jnp.float32)}
    counts = np.full(n, 2)

    results = {}
    for mode in ("host", "zero_copy"):
        zc = mode == "zero_copy"
        svc = AggregationService(
            jax.tree.map(jnp.array, params0),  # fresh buffers (donation)
            trigger=ClientCountTrigger(n), donate_params=zc)
        flow = DeviceFlow(svc)
        flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
        cohort = 512  # 2 chunks at full scale: chunk k+1 overlaps chunk k
        logical = (LogicalTier(local_train, cohort_size=cohort) if zc
                   else _PR2LogicalTier(local_train, cohort_size=cohort))
        sim = HybridSimulation(
            logical,
            DeviceTier(local_train, GRADES["High"], cohort_size=cohort),
            deviceflow=flow, zero_copy=zc, recycle_buffers=zc)

        def one_round(rnd):
            # All-logical split: both paths aggregate identical f32 cohort
            # outputs, so the diff below isolates the transport/aggregation.
            sim.run_round(0, rnd, svc.global_params, batches, counts,
                          num_logical=n, rng=jax.random.PRNGKey(rnd))

        one_round(0)  # compile
        jax.block_until_ready(svc.global_params)
        dt = float("inf")  # min over rounds: steady-state cost, noise-robust
        for r in range(1, 1 + timed_rounds):
            t0 = time.perf_counter()
            one_round(r)
            jax.block_until_ready(svc.global_params)
            dt = min(dt, time.perf_counter() - t0)
        bytes_total = flow.shelf(0).total_bytes_dispatched
        results[mode] = (dt, jax.device_get(svc.global_params))
        rows.append(Row(
            f"round_pipeline/{mode}{n}", dt * 1e6,
            f"devices_per_s={n / dt:.0f};params={n_params};"
            f"leaves={2 * blocks};"
            f"update_mb_dispatched={bytes_total / 2**20:.0f}"))

    (dt_host, p_host), (dt_zc, p_zc) = results["host"], results["zero_copy"]
    speedup = dt_host / dt_zc
    max_diff = max(
        float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
        for a, b in zip(jax.tree.leaves(p_host), jax.tree.leaves(p_zc)))
    ok = speedup >= 3.0 and max_diff < 5e-3
    rows.append(Row(
        "round_pipeline/claim_3x_over_host_path", 0.0,
        f"speedup={speedup:.2f};max_param_diff={max_diff:.2e};ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Columnar message plane at fleet scale — fig8 device_tier scale-up
# --------------------------------------------------------------------------- #
def million_device_round() -> list[Row]:
    """Struct-of-arrays message plane at the 10^6-device round scale.

    Every simulated device contributes one model-update row; arrivals enter
    as columnar ``ArrivalBatch``es — one per cohort chunk of 8192 devices,
    all rows sharing that chunk's device-resident ``UpdateBuffer`` — and
    flow the full plane: DeviceFlow sorter -> shelf -> accumulated dispatch
    -> ``AggregationService`` (``ClientCountTrigger``) -> one fused
    ``fed_reduce`` pass.  No per-device Python object exists anywhere on the
    path, so per-arrival cost amortizes to O(1/chunk) — that is what makes
    the top scale-up row a *completed* million-device round, not an
    extrapolation.

    Rows: ``fig8/device_tier/columnar_plane{n}`` scale-up (top scale 10^6;
    10^5 in ``--quick``), timed over warmed repeats so the %std rides into
    the artifact.  Claims: >=1e6 device-messages/s at the top scale with
    the aggregation fired over exactly n rows and row/byte conservation
    intact, and batched-vs-scalar aggregation numerics within 1e-6.
    """
    from repro.core import ClientCountTrigger
    from repro.core.deviceflow import ArrivalBatch
    from repro.core.updates import UpdateBuffer

    dim, chunk = 8, 8192
    scales = (10_000, 100_000) if common.QUICK else \
        (10_000, 100_000, 1_000_000)
    top = scales[-1]
    rng = np.random.default_rng(0)
    treedef = jax.tree.structure({"w": 0})
    rows_out: list[Row] = []

    def make_buffers(n, chunk, rng):
        bufs = []
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            leaf = jnp.asarray(
                rng.standard_normal((hi - lo, dim)) * 1e-2, jnp.float32)
            bufs.append((lo, UpdateBuffer([leaf], treedef, [(dim,)],
                                          [np.dtype(np.float32)])))
        return bufs

    results = {}
    for n in scales:
        svc = AggregationService({"w": jnp.zeros((dim,), jnp.float32)},
                                 trigger=ClientCountTrigger(n))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, AccumulatedStrategy(thresholds=(n,)))
        buffers = make_buffers(n, chunk, np.random.default_rng(n))
        rnd = [0]

        def one_round():
            batches = [
                ArrivalBatch.from_buffer(
                    0, rnd[0], buf,
                    device_ids=np.arange(lo, lo + buf.num_rows))
                for lo, buf in buffers]
            flow.submit_batches(batches)
            flow.round_complete(0)
            flow.run()
            rnd[0] += 1

        # warmup compiles the fused reduce at this buffer-group count; the
        # timed repeats then measure the steady-state plane.
        _, stat = timed(one_round, warmup=1, repeats=2)
        dt = float(stat) / 1e6
        fired = len(svc.history)
        ok_cons = flow.conservation_ok(0)
        results[n] = n / dt
        rows_out.append(Row(
            f"fig8/device_tier/columnar_plane{n}", stat,
            f"device_messages_per_s={n / dt:.0f};chunks={len(buffers)};"
            f"aggregations={fired};conservation_ok={ok_cons}"))

    rate = results[top]
    ok = rate >= 1e6 and ok_cons
    rows_out.append(Row(
        "million_device_round/claim_1e6_messages_per_s", 0.0,
        f"device_messages_per_s={rate:.0f};devices={top};ok={ok}"))

    # Batched vs scalar aggregation numerics: same updates, same weights,
    # one service fed columnar batches, the other the per-row Message
    # adapter — the fused batch intake must match the scalar plane.
    n_small, chunk_small = 96, 32
    finals = {}
    for mode in ("batched", "scalar"):
        svc = AggregationService({"w": jnp.zeros((dim,), jnp.float32)},
                                 trigger=ClientCountTrigger(n_small))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
        srng = np.random.default_rng(7)
        for lo, buf in make_buffers(n_small, chunk_small,
                                    np.random.default_rng(42)):
            b = ArrivalBatch.from_buffer(
                0, 0, buf, device_ids=np.arange(lo, lo + buf.num_rows),
                num_samples=srng.integers(1, 9, buf.num_rows))
            if mode == "batched":
                flow.submit_batch(b)
            else:
                flow.submit_many(b.messages())
        flow.round_complete(0)
        flow.run()
        finals[mode] = jax.device_get(svc.global_params)
    max_diff = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree.leaves(finals["batched"]),
                        jax.tree.leaves(finals["scalar"])))
    rows_out.append(Row(
        "million_device_round/claim_batched_matches_scalar", 0.0,
        f"max_param_diff={max_diff:.2e};ok={max_diff <= 1e-6}"))
    return rows_out


# --------------------------------------------------------------------------- #
# Event-driven multi-task schedule — interleaved rounds vs serial drain
# --------------------------------------------------------------------------- #
def multi_task_schedule() -> list[Row]:
    """3 contending tasks: event-driven ``TaskEngine`` vs serial drain.

    Both paths execute identical per-task CTR rounds through
    ``HybridSimulation.run_plan_round`` (measured round durations time the
    events).  The serial baseline is ``TaskManager.drain`` — run to
    completion, back to back on the shared ``VirtualClock``; the engine
    interleaves the three tasks' round events on one pool that fits all
    three, and aggregates through *streaming* per-chunk ``fed_reduce``
    partials (``AggregationService(streaming=True)`` fed by
    ``stream_chunks=True``).

    Claims: >=1.5x simulated-makespan improvement over the serial drain, and
    streaming aggregation matching the serial one-shot fused path's final
    per-task global params to 1e-6.
    """
    from repro.core import (
        ClientCountTrigger, GradeSpec, OperatorFlow, ResourceManager,
        ResourcePool, RoundPlan, RuntimeCalibrator, Task, TaskEngine,
        TaskManager, TaskRunner,
    )
    from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier

    n = 32 if common.QUICK else 128  # devices per task
    rounds = 2 if common.QUICK else 3
    n_tasks = 3
    dim, rpd = 32, 8
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=5)
    params0 = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    spec = GradeSpec("High", n, logical_bundles=n // 2, bundles_per_device=1,
                     physical_devices=max(1, n // 4))

    def batch_for(idx: int, round_idx: int):
        rng = np.random.default_rng(10_000 + idx * 97 + round_idx)
        return {
            "x": jnp.asarray(rng.standard_normal((n, rpd, dim)), jnp.float32),
            "y": jnp.asarray((rng.random((n, rpd)) < 0.3), jnp.float32),
            "mask": jnp.ones((n, rpd), jnp.float32),
        }

    def run_mode(mode: str):
        """-> (simulated makespan s, wall s, per-task final params)."""
        streaming = mode == "events"
        tasks = [Task(OperatorFlow(("train",)), (spec,), rounds=rounds)
                 for _ in range(n_tasks)]
        idx_of = {t.task_id: i for i, t in enumerate(tasks)}
        services = {}

        def deliver(d):
            services[d.message.task_id](d)

        flow = DeviceFlow(deliver, seed=0)
        for t in tasks:
            services[t.task_id] = AggregationService(
                jax.tree.map(jnp.array, params0),
                trigger=ClientCountTrigger(n), streaming=streaming)
            flow.register_task(t.task_id, AccumulatedStrategy(thresholds=(1,)))
        sim = HybridSimulation(
            LogicalTier(local, cohort_size=max(2, n // 2)),
            tiers={"High": DeviceTier(local, GRADES["High"],
                                      cohort_size=max(2, n // 2))},
            deviceflow=flow, stream_chunks=streaming)
        cal = RuntimeCalibrator()

        def round_runner(task, round_idx, allocation, t):
            svc = services[task.task_id]
            plan = RoundPlan.from_allocation(allocation, task.grades)
            outcome = sim.run_plan_round(
                task.task_id, round_idx, svc.global_params, plan,
                {"High": batch_for(idx_of[task.task_id], round_idx)},
                {"High": np.full(n, rpd)},
                jax.random.PRNGKey(1 + idx_of[task.task_id] * 31 + round_idx),
                calibrator=cal)
            return outcome.makespan_s

        # Pool fits all three tasks at full demand: the contention is purely
        # temporal — serial drain cannot overlap them, the engine can.
        rm = ResourceManager(ResourcePool(
            {"High": spec.logical_bundles * n_tasks},
            {"High": spec.physical_devices * n_tasks}))
        t0 = time.perf_counter()
        if mode == "events":
            engine = TaskEngine(rm, cal, round_runner=round_runner)
            for t in tasks:
                engine.submit(t)
            result = engine.drain()
            assert not result.stranded
            makespan = engine.makespan
        else:
            runner = TaskRunner(rm, cal, round_runner=round_runner,
                                clock=flow.clock)
            tm = TaskManager(rm, runner)
            for t in tasks:
                tm.submit(t)
            out = tm.drain(strict=True)
            assert len(out) == n_tasks
            makespan = flow.clock.now
        wall = time.perf_counter() - t0
        final = {idx_of[tid]: jax.device_get(svc.global_params)
                 for tid, svc in services.items()}
        return makespan, wall, final

    rows = []
    serial_mk, serial_wall, serial_params = run_mode("serial")
    event_mk, event_wall, event_params = run_mode("events")
    rows.append(Row(
        f"multi_task_schedule/serial{n_tasks}x{n}", serial_wall * 1e6,
        f"makespan_s={serial_mk:.1f};rounds={n_tasks * rounds}"))
    rows.append(Row(
        f"multi_task_schedule/events{n_tasks}x{n}", event_wall * 1e6,
        f"makespan_s={event_mk:.1f};rounds={n_tasks * rounds}"))
    speedup = serial_mk / event_mk
    max_diff = max(
        float(np.abs(np.asarray(a, np.float32)
                     - np.asarray(b, np.float32)).max())
        for i in serial_params
        for a, b in zip(jax.tree.leaves(serial_params[i]),
                        jax.tree.leaves(event_params[i])))
    ok = speedup >= 1.5 and max_diff <= 1e-6
    rows.append(Row(
        "multi_task_schedule/claim_1_5x_and_streaming_matches", 0.0,
        f"speedup={speedup:.2f};max_stream_diff={max_diff:.2e};ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Preemptive priority scheduling — queueing-delay cut for urgent arrivals
# --------------------------------------------------------------------------- #
def multi_task_preemption() -> list[Row]:
    """High-priority arrival vs two running tasks: preemptive vs not.

    Two low-priority tasks freeze the whole pool at t=0; a high-priority
    task arrives mid-round-0.  Both engine modes execute *identical* CTR
    rounds through ``HybridSimulation.run_plan_round`` (measured durations
    time the events; a paused victim resumes at the round it was paused at,
    so the per-task round sequence is the same either way).  The
    non-preemptive PR 4 engine admits the arrival only when a victim
    completes; the preemptive engine refreezes a victim's grant down (here:
    to zero — a pause) at its next round-event boundary.

    Claims: the preemptive engine cuts the high-priority task's simulated
    queueing delay by >= 2x, with every task still completing.  A
    Monte-Carlo row re-runs the schedule as N sampled virtual timelines
    (``calibration.monte_carlo_schedules`` on the calibrator's measured
    observations) reporting makespan / queueing-delay / grant-utilization
    distributions for both modes.
    """
    from repro.core import (
        ClientCountTrigger, GradeSpec, OperatorFlow, ResourceManager,
        ResourcePool, RoundPlan, RuntimeCalibrator, Task, TaskEngine,
        monte_carlo_schedules,
    )
    from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier

    n = 32 if common.QUICK else 128  # devices per task
    victim_rounds = 3
    hi_rounds = 2
    arrival_s = 1.0  # inside round 0 (fleet round makespans are minutes)
    dim, rpd = 32, 8
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=5)
    params0 = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    spec = GradeSpec("High", n, logical_bundles=n // 2, bundles_per_device=1,
                     physical_devices=max(1, n // 4))

    def batch_for(idx: int, round_idx: int):
        rng = np.random.default_rng(20_000 + idx * 97 + round_idx)
        return {
            "x": jnp.asarray(rng.standard_normal((n, rpd, dim)), jnp.float32),
            "y": jnp.asarray((rng.random((n, rpd)) < 0.3), jnp.float32),
            "mask": jnp.ones((n, rpd), jnp.float32),
        }

    def make_tasks():
        flow_spec = OperatorFlow(("train",))
        victims = [Task(flow_spec, (spec,), rounds=victim_rounds)
                   for _ in range(2)]
        hi = Task(flow_spec, (spec,), rounds=hi_rounds, priority=5)
        return victims, hi

    def run_mode(preemptive: bool, cal: "RuntimeCalibrator"):
        victims, hi = make_tasks()
        tasks = victims + [hi]
        idx_of = {t.task_id: i for i, t in enumerate(tasks)}
        services = {}
        flow = DeviceFlow(lambda d: services[d.message.task_id](d), seed=0)
        for t in tasks:
            services[t.task_id] = AggregationService(
                jax.tree.map(jnp.array, params0),
                trigger=ClientCountTrigger(n))
            flow.register_task(t.task_id, AccumulatedStrategy(thresholds=(1,)))
        sim = HybridSimulation(
            LogicalTier(local, cohort_size=max(2, n // 2)),
            tiers={"High": DeviceTier(local, GRADES["High"],
                                      cohort_size=max(2, n // 2))},
            deviceflow=flow)

        def round_runner(task, round_idx, allocation, t):
            svc = services[task.task_id]
            plan = RoundPlan.from_allocation(allocation, task.grades)
            outcome = sim.run_plan_round(
                task.task_id, round_idx, svc.global_params, plan,
                {"High": batch_for(idx_of[task.task_id], round_idx)},
                {"High": np.full(n, rpd)},
                jax.random.PRNGKey(1 + idx_of[task.task_id] * 31 + round_idx),
                calibrator=cal)
            return outcome.makespan_s

        # The pool fits the two victims EXACTLY: the arrival finds nothing
        # free, so only reclamation (not elastic leftovers) can admit it
        # before a victim completes.
        rm = ResourceManager(ResourcePool(
            {"High": spec.logical_bundles * 2},
            {"High": spec.physical_devices * 2}))
        engine = TaskEngine(rm, cal, round_runner=round_runner,
                            clock=flow.clock, preemptive=preemptive)
        t0 = time.perf_counter()
        for v in victims:
            engine.submit(v)
        engine.submit(hi, at=arrival_s)
        result = engine.drain()
        wall = time.perf_counter() - t0
        assert not result.stranded and len(result) == 3
        ex_hi = engine.executions[hi.task_id]
        ex_victims = [engine.executions[v.task_id] for v in victims]
        return {
            "wall": wall,
            "makespan": engine.makespan,
            "hi_delay": ex_hi.queueing_delay_s,
            "victim_util": float(np.mean(
                [e.grant_utilization for e in ex_victims])),
            "preempted": sum(e.preemptions for e in ex_victims),
            "rounds": [e.rounds_done for e in engine.completed],
        }

    cal = RuntimeCalibrator()
    base = run_mode(preemptive=False, cal=cal)
    pre = run_mode(preemptive=True, cal=cal)
    rows = [
        Row(f"multi_task_preemption/nonpreemptive{n}", base["wall"] * 1e6,
            f"queueing_delay_s={base['hi_delay']:.1f};"
            f"makespan_s={base['makespan']:.1f};"
            f"victim_util={base['victim_util']:.3f}"),
        Row(f"multi_task_preemption/preemptive{n}", pre["wall"] * 1e6,
            f"queueing_delay_s={pre['hi_delay']:.1f};"
            f"makespan_s={pre['makespan']:.1f};"
            f"victim_util={pre['victim_util']:.3f};"
            f"preemptions={pre['preempted']}"),
    ]

    # Monte-Carlo distribution over sampled timelines: same contention
    # replayed on the measured round-duration observations.
    victims_mc, hi_mc = make_tasks()
    mc = monte_carlo_schedules(
        victims_mc + [hi_mc],
        ResourcePool({"High": spec.logical_bundles * 2},
                     {"High": spec.physical_devices * 2}),
        cal, arrivals={hi_mc.task_id: arrival_s},
        n_samples=16 if common.QUICK else 64, seed=3)
    base_mc, pre_mc = mc[False], mc[True]
    mc_cut = (base_mc.mean_queueing_delay_s(hi_mc.task_id)
              / max(pre_mc.mean_queueing_delay_s(hi_mc.task_id), 1e-9))
    rows.append(Row(
        "multi_task_preemption/monte_carlo", 0.0,
        f"samples={len(base_mc.makespan_s)};"
        f"mean_mk_nonpre_s={base_mc.mean_makespan_s:.1f};"
        f"mean_mk_pre_s={pre_mc.mean_makespan_s:.1f};"
        f"p95_mk_pre_s={pre_mc.p95_makespan_s:.1f};"
        f"mc_delay_cut={mc_cut:.2f};"
        f"victim_util_pre={np.mean([pre_mc.mean_grant_utilization(v.task_id) for v in victims_mc]):.3f}"))

    # All tasks ran their full round counts in both modes (identical work).
    same_rounds = (sorted(base["rounds"]) == sorted(pre["rounds"])
                   == sorted([victim_rounds, victim_rounds, hi_rounds]))
    delay_cut = base["hi_delay"] / max(pre["hi_delay"], 1e-9)
    ok = delay_cut >= 2.0 and pre["preempted"] >= 1 and same_rounds
    rows.append(Row(
        "multi_task_preemption/claim_2x_queueing_delay_cut", 0.0,
        f"delay_cut={delay_cut:.2f};mc_delay_cut={mc_cut:.2f};"
        f"same_rounds={same_rounds};ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Continuous-batching serving under diurnal traffic (PR 8)
# --------------------------------------------------------------------------- #
def continuous_serving() -> list[Row]:
    """Fixed-batch vs slot-based continuous batching on one diurnal trace.

    Both serving modes replay the SAME arrival trace (DeviceFlow on the
    diurnal curve) and charge virtual service time from the SAME
    ``ServeCostModel``, so the p50/p99/TTFT/goodput gap is purely the
    batching policy: fixed batches couple every request's latency to its
    batch-mates; the continuous engine admits at iteration boundaries and
    retires slots individually.

    Claims: >= 2x p99 latency cut with *token-identical* decodes (the
    ISSUE acceptance bar).  A capacity row drives a ``simulate_only``
    engine (no model compute) with deterministic curve-quantile arrivals
    standing for a million users, reporting peak slot occupancy and SLO
    goodput at that scale.
    """
    from repro.configs.registry import get_config
    from repro.core.deviceflow import VirtualClock
    from repro.core.serving import (
        ContinuousBatchingEngine,
        ContinuousServer,
        ServeCostModel,
    )
    from repro.core.traffic_curves import arrival_quantiles, diurnal
    from repro.launch.serve import BatchedServer, run_trace

    requests = 48 if common.QUICK else 192
    slots, prompt_len, decode_tokens = 4, 8, 4
    max_len = prompt_len + decode_tokens + 1
    slo_s = 30.0
    cfg = get_config("llama3_2_3b", smoke=True)
    cost = ServeCostModel()
    curve = diurnal()
    trace = dict(requests=requests, prompt_len=prompt_len,
                 vocab_size=cfg.vocab_size, curve=curve, interval=60.0,
                 seed=0)

    fixed = BatchedServer(cfg, batch_size=slots, prompt_len=prompt_len,
                          decode_tokens=decode_tokens, max_len=max_len,
                          seed=0, cost_model=cost)
    t0 = time.perf_counter()
    run_trace(fixed, **trace)
    wall_f = time.perf_counter() - t0
    rep_f = fixed.report()

    engine = ContinuousBatchingEngine(
        cfg, slots=slots, prompt_len=prompt_len,
        decode_tokens=decode_tokens, max_len=max_len, seed=0,
        cost_model=cost)
    clock = VirtualClock()
    t0 = time.perf_counter()
    run_trace(ContinuousServer(engine, clock), clock=clock, **trace)
    wall_c = time.perf_counter() - t0
    rep_c = engine.report()

    # One shared horizon so goodput denominators match.
    horizon = max(rep_f.horizon_s, rep_c.horizon_s)
    rep_f.horizon_s = rep_c.horizon_s = horizon
    sf, sc = rep_f.summary(slo_s), rep_c.summary(slo_s)
    occ = max((it.n_active for it in engine.iterations), default=0)
    rows = [
        Row(f"continuous_serving/fixed_batch{requests}", wall_f * 1e6,
            f"p50_latency_s={sf['p50_latency_s']:.4f};"
            f"p99_latency_s={sf['p99_latency_s']:.4f};"
            f"p99_ttft_s={sf['p99_ttft_s']:.4f};"
            f"goodput_rps={sf['goodput_rps']:.4f};"
            f"slo_attainment={sf['slo_attainment']:.3f}"),
        Row(f"continuous_serving/continuous{requests}", wall_c * 1e6,
            f"p50_latency_s={sc['p50_latency_s']:.4f};"
            f"p99_latency_s={sc['p99_latency_s']:.4f};"
            f"p99_ttft_s={sc['p99_ttft_s']:.4f};"
            f"goodput_rps={sc['goodput_rps']:.4f};"
            f"slo_attainment={sc['slo_attainment']:.3f};"
            f"iterations={len(engine.iterations)};"
            f"peak_occupancy={occ}"),
    ]

    # Million-user capacity study: simulate_only (no model compute) with
    # deterministic equal-AUC arrivals on the same diurnal shape.  The day
    # is compressed so the evening peak (~4x the mean rate) pushes the
    # arena toward full occupancy — mean 200 req/s vs the 64-slot engine's
    # ~900 req/s ceiling under this cost model.
    users = 1_000_000
    n_cap = 2_000 if common.QUICK else 20_000
    cap = ContinuousBatchingEngine(
        slots=64, prompt_len=prompt_len, decode_tokens=decode_tokens,
        simulate_only=True, cost_model=cost)
    arrivals = arrival_quantiles(curve, n_cap, duration_s=n_cap / 200.0)
    t0 = time.perf_counter()
    t, i = 0.0, 0
    while i < len(arrivals) or cap.has_work:
        while i < len(arrivals) and arrivals[i] <= t:
            cap.submit(i, None, arrivals[i])
            i += 1
        if cap.has_work:
            t += cap.step(t)
        else:
            t = arrivals[i]  # idle: jump to the next arrival
    wall_cap = time.perf_counter() - t0
    rep_cap = cap.report(horizon_s=t)
    s_cap = rep_cap.summary(slo_s)
    occ_cap = max(it.n_active for it in cap.iterations)
    rows.append(Row(
        "continuous_serving/million_user_capacity", wall_cap * 1e6,
        f"requests={n_cap};users_per_request={users / n_cap:.0f};"
        f"p99_latency_s={s_cap['p99_latency_s']:.4f};"
        f"goodput_rps={s_cap['goodput_rps']:.4f};"
        f"slo_attainment={s_cap['slo_attainment']:.3f};"
        f"peak_occupancy={occ_cap};iterations={len(cap.iterations)}"))

    # Claim: >= 2x p99 cut AND token-identical decode streams.
    toks_f = {r.request_id: r.tokens for r in rep_f.records}
    toks_c = {r.request_id: r.tokens for r in rep_c.records}
    token_identical = toks_f == toks_c and all(
        len(v) == decode_tokens + 1 for v in toks_f.values())
    p99_cut = sf["p99_latency_s"] / max(sc["p99_latency_s"], 1e-9)
    ok = p99_cut >= 2.0 and token_identical
    rows.append(Row(
        "continuous_serving/claim_2x_p99_cut_token_identical", 0.0,
        f"p99_cut={p99_cut:.2f};token_identical={token_identical};"
        f"goodput_gain={sc['goodput_rps'] / max(sf['goodput_rps'], 1e-9):.2f};"
        f"ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 9 — device-behavior traffic curves change aggregation outcomes
# --------------------------------------------------------------------------- #
def fig9_traffic_impact() -> list[Row]:
    rows = []
    results = {}
    for sigma in (1.0, 2.0, 3.0):
        t0 = time.perf_counter()
        num_devices, rounds = 120, 4
        data = make_federated_ctr(num_devices=num_devices, dim=64, seed=5,
                                  noniid_alpha=0.5)
        test = make_federated_ctr(num_devices=100, dim=64, seed=6)
        local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=10)
        vlocal = jax.jit(jax.vmap(local))
        params = ctr_lib.lr_init(jax.random.PRNGKey(0), 64)
        svc = AggregationService(
            params, trigger=SampleThresholdTrigger(num_devices * 20 // 2))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, TimeIntervalStrategy(
            curve=right_tailed_normal(sigma, hi=12.0), interval=1200.0))
        X, Y, counts = data.stacked_shards(np.arange(num_devices), 20)
        M = (np.arange(20)[None] < counts[:, None]).astype(np.float32)
        for rnd in range(rounds):
            stacked = jax.tree.map(
                lambda p: jnp.broadcast_to(
                    p, (num_devices,) + p.shape), svc.global_params)
            keys = jax.random.split(jax.random.PRNGKey(rnd), num_devices)
            new_params, _ = vlocal(
                stacked,
                {"x": jnp.asarray(X), "y": jnp.asarray(Y), "mask": jnp.asarray(M)},
                keys)
            host = jax.device_get(new_params)
            for c in range(num_devices):
                flow.submit(Message(
                    0, c, rnd, jax.tree.map(lambda x: x[c], host),
                    num_samples=int(counts[c])))
            flow.round_complete(0)
            flow.run(flow.clock.now + 1200.0)
        accs = [float(ctr_lib.accuracy(
            ev.global_params, jnp.asarray(test.features),
            jnp.asarray(test.labels))) for ev in svc.history]
        results[sigma] = {
            "aggs": len(svc.history),
            "final_acc": accs[-1] if accs else float("nan"),
        }
        rows.append(Row(
            f"fig9/sigma{sigma:g}", (time.perf_counter() - t0) * 1e6,
            f"aggregations={len(svc.history)};final_acc={results[sigma]['final_acc']:.4f}"))
    ok = results[1.0]["aggs"] >= results[3.0]["aggs"]
    rows.append(Row(
        "fig9/claim_smaller_sigma_more_aggregations", 0.0,
        f"aggs_sigma1={results[1.0]['aggs']};aggs_sigma3={results[3.0]['aggs']};ok={ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 10 + Table II — dispatch fidelity (Pearson r > 0.99)
# --------------------------------------------------------------------------- #
def fig10_dispatch_fidelity() -> list[Row]:
    rows = []
    all_ok = True
    for curve in table2_curves():
        total = 6000  # keeps 10^t peak under the 700/s dispatch capacity
        (points, us) = timed(
            discretize_curve, curve, total, 60.0, 700.0)
        points = [(t, c) for t, c in points if t < 60.0]  # spill excluded
        ts = np.array([t for t, _ in points])
        counts = np.array([c for _, c in points], dtype=np.float64)
        # Counts are per-tick integrals: the faithful reference samples the
        # scaled curve at tick MIDPOINTS (start-sampling adds a half-tick
        # phase shift that caps r at ~0.989 for sin).
        span = curve.hi - curve.lo
        dt = ts[1] - ts[0] if len(ts) > 1 else 0.0
        ref = np.array([
            curve(curve.lo + (t + dt / 2) / 60.0 * span) for t in ts])
        r = float(np.corrcoef(counts, ref)[0, 1])
        conserved = int(counts.sum()) == total
        ok = r > 0.99 and conserved
        all_ok &= ok
        rows.append(Row(
            f"table2/{curve.name}", us,
            f"pearson_r={r:.4f};mass_conserved={conserved}"))
    rows.append(Row("table2/claim_all_r_above_0.99", 0.0, f"ok={all_ok}"))
    return rows


# --------------------------------------------------------------------------- #
# Fig 11 — dropout: harmless under IID, destabilizing under non-IID
# --------------------------------------------------------------------------- #
def fig11_dropout() -> list[Row]:
    rows = []
    outcomes = {}
    for dist, split in (("iid", None), ("noniid", (0.7, 0.8, 0.2))):
        for p_drop in (0.0, 0.3, 0.7, 0.9):
            t0 = time.perf_counter()
            num_devices = 100

            def hook(rnd, new_params, counts, params, p=p_drop):
                rng = np.random.default_rng(1000 + rnd)
                keepm = rng.random(num_devices) >= p
                if not keepm.any():
                    keepm[rng.integers(num_devices)] = True
                w = (counts * keepm).astype(np.float64)
                w /= w.sum()
                return jax.tree.map(
                    lambda stack: jnp.einsum(
                        "c...,c->...", stack, jnp.asarray(w, stack.dtype)),
                    new_params)

            out = run_federated_ctr(
                num_devices=num_devices, rounds=6, seed=11,
                positive_rate_split=split, deviceflow_hook=hook)
            accs = [h["acc"] for h in out["history"]]
            stability = float(np.std(accs[2:]))
            outcomes[(dist, p_drop)] = (out["final_acc"], stability)
            rows.append(Row(
                f"fig11/{dist}/p{p_drop:g}",
                (time.perf_counter() - t0) * 1e6,
                f"final_acc={out['final_acc']:.4f};acc_std={stability:.4f}"))
    iid_spread = abs(outcomes[("iid", 0.0)][0] - outcomes[("iid", 0.9)][0])
    noniid_unstable = (outcomes[("noniid", 0.9)][1]
                       >= outcomes[("noniid", 0.0)][1])
    rows.append(Row(
        "fig11/claim_iid_robust_noniid_fragile", 0.0,
        f"iid_acc_spread={iid_spread:.4f};"
        f"noniid_std_increases={noniid_unstable}"))
    return rows


# --------------------------------------------------------------------------- #
# Quantized wire format — int8 UpdateBuffers through the columnar plane
# --------------------------------------------------------------------------- #
def quantized_wire() -> list[Row]:
    """int8 wire vs f32 through the full columnar plane at 10^5 devices.

    Same round as ``million_device_round`` — cohort-chunk ``UpdateBuffer``s
    enter as ``ArrivalBatch``es, flow sorter -> shelf -> dispatch -> fused
    aggregation — but run twice: once with f32 buffers, once with
    ``wire="int8"`` buffers whose scales fold into the fed_reduce weight
    vector (dequantize-and-reduce, no dense f32 stack).  Leaves are
    512-wide so the per-leaf scale column is amortized the way real model
    chunks amortize it.

    Rows: per-wire plane timing with ``bytes_per_round`` (the shelf's
    dispatched-byte delta for one round).  Claims: int8 cuts wire bytes
    >=3.8x, holds round throughput within 10% of f32, and — on a real
    federated CTR run through ``HybridSimulation(wire="int8")`` with error
    feedback — lands final-round loss within 1% of the f32 run.
    """
    from repro.core import ClientCountTrigger
    from repro.core.deviceflow import ArrivalBatch
    from repro.core.simulation import (
        DeviceTier,
        HybridSimulation,
        LogicalTier,
    )
    from repro.core.updates import UpdateBuffer

    dim, chunk = 512, 8192
    n = 100_000
    rows_out: list[Row] = []
    rates, bytes_round = {}, {}
    for wire in ("f32", "int8"):
        rng = np.random.default_rng(5)
        bufs = []
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            stacked = {"w": jnp.asarray(
                rng.standard_normal((hi - lo, dim)) * 1e-2, jnp.float32)}
            bufs.append((lo, UpdateBuffer.quantized_from_stacked(stacked)
                         if wire == "int8"
                         else UpdateBuffer.from_stacked(stacked)))
        svc = AggregationService({"w": jnp.zeros((dim,), jnp.float32)},
                                 trigger=ClientCountTrigger(n))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, AccumulatedStrategy(thresholds=(n,)))
        shelf = flow.shelf(0)
        rnd = [0]

        def one_round():
            base = shelf.total_bytes_dispatched
            flow.submit_batches([
                ArrivalBatch.from_buffer(
                    0, rnd[0], buf,
                    device_ids=np.arange(lo, lo + buf.num_rows))
                for lo, buf in bufs])
            flow.round_complete(0)
            flow.run()
            rnd[0] += 1
            return shelf.total_bytes_dispatched - base

        # The parity claim compares two separately-timed means; a handful of
        # samples puts CPU scheduling noise (pstd ~20%) straight into the
        # ratio, so accumulate a fixed wall-clock budget per wire format.
        bpr, stat = timed(one_round, warmup=2, repeats=3,
                          target_total_secs=2.0)
        dt = float(stat) / 1e6
        rates[wire], bytes_round[wire] = n / dt, bpr
        rows_out.append(Row(
            f"quantized_wire/plane_{wire}_{n}", stat,
            f"device_messages_per_s={n / dt:.0f};bytes_per_round={bpr};"
            f"chunks={len(bufs)};aggregations={len(svc.history)};"
            f"conservation_ok={flow.conservation_ok(0)}"))
        del bufs

    cut = bytes_round["f32"] / bytes_round["int8"]
    rows_out.append(Row(
        "quantized_wire/claim_byte_cut", 0.0,
        f"f32_bytes={bytes_round['f32']};int8_bytes={bytes_round['int8']};"
        f"cut={cut:.2f};ok={cut >= 3.8}"))
    parity = rates["int8"] / rates["f32"]
    rows_out.append(Row(
        "quantized_wire/claim_throughput_parity", 0.0,
        f"f32_rate={rates['f32']:.0f};int8_rate={rates['int8']:.0f};"
        f"ratio={parity:.3f};ok={parity >= 0.9}"))

    # Numerics drift: the same federated CTR run, f32 wire vs fused-int8
    # wire with device-resident error feedback.
    n_dev, rpd, dim_ctr, rounds = 64, 20, 64, 6
    data = make_federated_ctr(num_devices=n_dev, records_per_device=rpd,
                              dim=dim_ctr, seed=0)
    test = make_federated_ctr(num_devices=100, records_per_device=rpd,
                              dim=dim_ctr, seed=1)
    Xt, Yt = jnp.asarray(test.features), jnp.asarray(test.labels)
    local = ctr_lib.make_local_train_fn(lr=1e-2, epochs=4)
    X, Y, counts = data.stacked_shards(np.arange(n_dev), rpd)
    mask = (np.arange(rpd)[None] < counts[:, None]).astype(np.float32)
    batches = {"x": jnp.asarray(X), "y": jnp.asarray(Y),
               "mask": jnp.asarray(mask)}

    t0 = time.perf_counter()
    losses = {}
    for wire in ("f32", "int8"):
        svc = AggregationService(
            ctr_lib.lr_init(jax.random.PRNGKey(0), dim_ctr),
            trigger=SampleThresholdTrigger(int(counts.sum())))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
        sim = HybridSimulation(
            LogicalTier(local, cohort_size=n_dev // 2),
            DeviceTier(local, GRADES["High"], cohort_size=n_dev // 4),
            deviceflow=flow, zero_copy=True, wire=wire)
        for rnd_i in range(rounds):
            sim.run_round(0, rnd_i, svc.global_params, batches, counts,
                          n_dev, jax.random.PRNGKey(rnd_i))
            flow.run(1e12)
            svc.tick(flow.clock.now)
        losses[wire] = float(ctr_lib.bce_loss(svc.global_params, Xt, Yt))
    drift_pct = 100.0 * abs(losses["int8"] - losses["f32"]) / losses["f32"]
    rows_out.append(Row(
        "quantized_wire/claim_ef_drift",
        (time.perf_counter() - t0) * 1e6,
        f"f32_loss={losses['f32']:.6f};int8_loss={losses['int8']:.6f};"
        f"loss_drift_pct={drift_pct:.4f};ok={drift_pct <= 1.0}"))
    return rows_out


def _workers_bench_tiers(cohort=32, seed=3):
    """Module-level so spawn'ed pool workers can unpickle it by reference."""
    from repro.core.simulation import DeviceTier, LogicalTier

    local = ctr_lib.make_local_train_fn(lr=1e-2, epochs=2)
    return (LogicalTier(local, cohort_size=cohort),
            {"High": DeviceTier(local, GRADES["High"], seed=seed,
                                cohort_size=cohort)})


def workers_round() -> list[Row]:
    """Multi-process fleet execution vs the in-process columnar round.

    The same federated CTR round — cohort chunks -> struct-of-arrays
    ``ArrivalBatch``es -> shelf -> fused aggregation — runs once in-process
    and once per pool size, with chunk execution sharded across spawned
    worker processes and results returning through shared-memory segments.
    Each configuration runs the identical chunk plan, so final params and
    wire-byte counters must match the inline run bit-for-bit.

    Claim: at 4 workers on a >=4-core host the pooled round clears 2x the
    inline device-messages/s; on smaller hosts (CI containers pinned to 1-2
    cores) spawn+compile overhead dominates and the claim degrades to the
    equivalence gate — bit-identical params and exact byte accounting.
    """
    import os as _os

    from repro.core.simulation import HybridSimulation
    from repro.runtime.workers import WorkerSpec

    quick = common.QUICK
    n, rpd, dim, cohort = (256, 8, 32, 32) if quick else (1024, 8, 64, 64)
    pool_sizes = (2,) if quick else (2, 4)
    repeats = 2 if quick else 3
    try:
        cores = len(_os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        cores = _os.cpu_count() or 1

    data = make_federated_ctr(num_devices=n, records_per_device=rpd,
                              dim=dim, seed=0)
    params0 = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    X, Y, counts = data.stacked_shards(np.arange(n), rpd)
    mask = (np.arange(rpd)[None] < counts[:, None]).astype(np.float32)
    batches = {"x": jnp.asarray(X), "y": jnp.asarray(Y),
               "mask": jnp.asarray(mask)}
    num_logical = cohort  # one logical chunk, the rest device chunks

    rows_out: list[Row] = []
    results: dict[int, tuple] = {}
    for w in (0,) + pool_sizes:
        svc = AggregationService(
            params0, trigger=SampleThresholdTrigger(int(counts.sum())))
        flow = DeviceFlow(svc, seed=0)
        flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
        logical, tiers = _workers_bench_tiers(cohort)
        kw = ({} if w == 0 else dict(
            workers=w, worker_spec=WorkerSpec(
                _workers_bench_tiers, kwargs=dict(cohort=cohort))))
        sim = HybridSimulation(logical, tiers=tiers, deviceflow=flow, **kw)
        rnd = [0]

        def one_round():
            sim.run_round(0, rnd[0], svc.global_params, batches, counts,
                          num_logical, jax.random.PRNGKey(rnd[0]))
            flow.run(1e12)
            svc.tick(flow.clock.now)
            rnd[0] += 1

        # warmup covers worker spawn + per-worker cohort jit; every config
        # runs the same 1+repeats rounds so final params stay comparable.
        _, stat = timed(one_round, warmup=1, repeats=repeats)
        dt = float(stat) / 1e6
        shelf = flow.shelf(0)
        results[w] = (n / dt, jax.device_get(svc.global_params),
                      shelf.total_bytes_dispatched)
        stats = dict(sim.pool.stats) if sim.pool is not None else {}
        sim.close()
        label = "inline" if w == 0 else f"pool_w{w}"
        extra = (f";segments={stats['segments_created']}"
                 f";segment_reuses={stats['segment_reuses']}"
                 f";shipped_mb={stats['bytes_shipped'] / 1e6:.1f}"
                 if stats else "")
        rows_out.append(Row(
            f"workers_round/{label}_{n}", stat,
            f"worker_device_messages_per_s={n / dt:.0f};"
            f"aggregations={len(svc.history)}{extra}"))

    base_rate, base_params, base_bytes = results[0]
    bit_identical = all(
        results[w][2] == base_bytes
        and all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(results[w][1]),
                                jax.tree.leaves(base_params)))
        for w in pool_sizes)
    best = max(pool_sizes)
    speedup = results[best][0] / base_rate
    # The >=2x scale-up claim needs real cores to shard across; below that
    # the gate is correctness (the speedup still gets reported and diffed).
    gate_perf = cores >= 4 and best >= 4
    ok = bit_identical and (speedup >= 2.0 if gate_perf else True)
    rows_out.append(Row(
        "workers_round/claim_scaleup", 0.0,
        f"cores={cores};workers={best};speedup={speedup:.2f};"
        f"perf_gated={gate_perf};bit_identical={bit_identical};ok={ok}"))
    return rows_out


ALL_BENCHMARKS = (
    table1_device_metrics,
    fig6_hybrid_accuracy,
    fig7_allocation_time,
    fig8_scalability,
    fig8_device_tier_batched,
    multi_grade_round,
    round_pipeline,
    million_device_round,
    quantized_wire,
    workers_round,
    multi_task_schedule,
    multi_task_preemption,
    continuous_serving,
    fig9_traffic_impact,
    fig10_dispatch_fidelity,
    fig11_dropout,
)
