"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.roofline_report import all_cells, improvement_note  # noqa: E402


def dryrun_table(art: pathlib.Path, mesh: str) -> str:
    lines = [
        "| arch | shape | plan (tp×sp,dup) | compile s | args GB/dev "
        "| temp GB/dev | AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(art.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec["mesh"] != mesh:
            continue
        pl = rec["plan"]
        cc = rec["collective_op_counts"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {pl['tp']}×{pl['sp']},{pl['kv_dup']}"
            f"{',fsdp' if pl.get('fsdp') else ''} "
            f"| {rec['seconds']['compile']} "
            f"| {rec['memory']['argument_bytes'] / 1e9:.2f} "
            f"| {rec['memory']['temp_bytes'] / 1e9:.2f} "
            f"| {cc['all-gather']} | {cc['all-reduce']} "
            f"| {cc['reduce-scatter']} | {cc['all-to-all']} "
            f"| {cc['collective-permute']} |"
        )
    return "\n".join(lines)


def roofline_table(art: pathlib.Path, mesh: str) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL/HLO | roofline frac | to move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in all_cells(art):
        r = cell["rec"]
        if r["mesh"] != mesh:
            continue
        t = cell["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} "
            f"| {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| **{cell['dominant']}** | {cell['useful_ratio']:.3f} "
            f"| {cell['roofline_fraction']:.3f} "
            f"| {improvement_note(cell['dominant'], r['arch'], r['shape'])} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    art = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    which = sys.argv[2] if len(sys.argv) > 2 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod 16x16\n")
        print(dryrun_table(art, "16x16"))
        print("\n### multi-pod 2x16x16\n")
        print(dryrun_table(art, "2x16x16"))
    if which in ("all", "roofline"):
        print("\n### roofline (single-pod)\n")
        print(roofline_table(art, "16x16"))
