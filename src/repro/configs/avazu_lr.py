"""The paper's own experiment model: LR for CTR on (synthetic) Avazu."""
import dataclasses

@dataclasses.dataclass(frozen=True)
class CTRConfig:
    name: str = "avazu-lr"
    dim: int = 256
    lr: float = 1e-3          # paper §VI.A.1
    local_epochs: int = 10    # paper §VI.A.1

CONFIG = CTRConfig()
SMOKE_CONFIG = dataclasses.replace(CONFIG, dim=32)
