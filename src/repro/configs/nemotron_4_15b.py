"""nemotron-4-15b [dense] — arXiv:2402.16819 (GQA, squared-ReLU)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    mlp_activation="sq_relu",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="nemotron-4-15b-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512,
)
