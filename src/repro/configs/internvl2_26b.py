"""internvl2-26b [vlm] — arXiv:2404.16821 (InternViT frontend stub + InternLM2).

The InternViT vision tower is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings already projected to d_model.
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    mlp_activation="swiglu",
    frontend="vit_stub", frontend_tokens=256,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="internvl2-26b-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, frontend_tokens=8,
)
