"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE (16 experts top-2)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    mlp_activation="swiglu", num_experts=16, experts_per_token=2,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="phi3.5-moe-42b-a6.6b-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, num_experts=4, experts_per_token=2,
)
