"""seamless-m4t-medium [audio] — arXiv:2308.11596 (enc-dec, frontend stub).

Backbone only: 12 encoder + 12 decoder layers at the listed width; the speech
frontend is a STUB (``input_specs()`` provides precomputed frame embeddings).
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    mlp_activation="gelu", num_encoder_layers=12,
    frontend="audio_stub",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="seamless-m4t-medium-smoke",
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
)
