"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "phi3_medium_14b",
    "llama3_2_3b",
    "qwen2_7b",
    "nemotron_4_15b",
    "zamba2_1_2b",
    "mamba2_1_3b",
    "granite_moe_3b_a800m",
    "phi3_5_moe_42b_a6_6b",
    "internvl2_26b",
    "seamless_m4t_medium",
    "avazu_lr",  # the paper's own model (not an LM cell)
)

# Dashed aliases matching the assignment sheet.
ALIASES = {
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen2-7b": "qwen2_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def get_config(arch: str, *, smoke: bool = False):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def lm_arch_ids() -> tuple[str, ...]:
    return tuple(a for a in ARCH_IDS if a != "avazu_lr")
