"""granite-moe-3b-a800m [moe] — hf:ibm-granite (40 experts top-8)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    mlp_activation="swiglu", num_experts=40, experts_per_token=8,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="granite-moe-3b-a800m-smoke",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, num_experts=4, experts_per_token=2,
)
