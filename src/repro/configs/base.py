"""Model/shape configuration system.

One ``ModelConfig`` covers all assigned families (dense / MoE / SSM / hybrid /
enc-dec / VLM-backbone).  ``ShapeConfig`` defines the four assigned input
shapes.  ``MeshPlan`` records how an architecture maps the production mesh's
``model=16`` axis onto logical ``tp x sp`` sub-axes (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # MLP
    mlp_activation: str = "swiglu"  # swiglu | sq_relu | gelu
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # Hybrid (zamba2-style): one shared attention block applied every k layers
    hybrid_attn_every: int = 0
    # Encoder-decoder
    num_encoder_layers: int = 0
    # Modality frontend stub (vlm/audio): embeddings are precomputed inputs
    frontend: str | None = None  # vit_stub | audio_stub
    frontend_tokens: int = 256
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    scan_layers: bool = True  # homogeneous stacks lower via lax.scan
    attention_impl: str = "auto"  # auto | chunked | pallas | ref | einsum
    attention_kv_chunk: int = 1024
    fuse_qkv: bool = False  # beyond-paper perf: merged QKV / gate-up projections
    dtype: str = "bfloat16"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic memory path exists (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def num_params(self) -> int:
        """Exact parameter count (used for 6ND model-FLOPs and memory)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        embed = V * D + (0 if self.tie_embeddings else V * D)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * hd
        if self.mlp_activation == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.num_experts:
            mlp_total = self.num_experts * mlp + D * self.num_experts
        else:
            mlp_total = mlp
        norms = 2 * D
        if self.family == "ssm":
            per_layer = self._mamba_block_params() + D
            return embed + self.num_layers * per_layer + D
        if self.family == "hybrid":
            ssm_layers = self.num_layers * (self._mamba_block_params() + D)
            n_attn_applications = self.num_layers // max(self.hybrid_attn_every, 1)
            shared_attn = attn + mlp_total + norms  # ONE shared block (reused)
            return embed + ssm_layers + shared_attn + D
        per_layer = attn + mlp_total + norms
        total = embed + self.num_layers * per_layer + D
        if self.num_encoder_layers:
            enc_attn = attn  # encoder self-attention
            total += self.num_encoder_layers * (enc_attn + mlp_total + norms) + D
            total += self.num_layers * (attn + D)  # decoder cross-attn + its norm
        return total

    def _mamba_block_params(self) -> int:
        D, di = self.d_model, self.d_inner
        g, n, h = self.ssm_groups, self.ssm_state, self.ssm_heads
        conv_dim = di + 2 * g * n
        in_proj = D * (2 * di + 2 * g * n + h)  # split z/x/BC/dt, same total
        conv = conv_dim * self.ssm_conv_width + conv_dim
        extra = h * 3  # A_log, dt_bias, D skip
        out_proj = di * D + di  # + gated-norm weight
        return in_proj + conv + extra + out_proj

    def active_params(self) -> int:
        """Active parameters per token (MoE uses topk/E of expert weights)."""
        if not self.num_experts:
            return self.num_params()
        D, F = self.d_model, self.d_ff
        mlp = (3 if self.mlp_activation == "swiglu" else 2) * D * F
        inactive = (self.num_experts - self.experts_per_token) * mlp
        return self.num_params() - self.num_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    microbatches: int = 1  # gradient-accumulation steps (train only)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", microbatches=8),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Logical factoring of the production mesh for one architecture.

    The physical mesh is always ``(pod?, data=16, model=16)``.  ``tp * sp``
    must equal the model-axis size; ``tp`` shards heads / d_ff / experts /
    vocab, ``sp`` shards the sequence (context parallelism).  ``kv_dup`` is
    the Megatron-style KV-head duplication factor when ``tp > num_kv_heads``.
    """

    tp: int
    sp: int
    kv_dup: int = 1
    fsdp: bool = True  # shard params+opt state over the data axis for training

    def __post_init__(self):
        if self.tp * self.sp <= 0:
            raise ValueError("tp and sp must be positive")


def choose_mesh_plan(cfg: ModelConfig, model_axis: int = 16) -> MeshPlan:
    """Pick the largest tp | model_axis compatible with the head counts."""
    if cfg.family == "ssm":
        h = cfg.ssm_heads
        for tp in _descending_divisors(model_axis):
            if h % tp == 0:
                return MeshPlan(tp=tp, sp=model_axis // tp)
        return MeshPlan(tp=1, sp=model_axis)
    H, KV = cfg.num_heads, cfg.num_kv_heads
    for tp in _descending_divisors(model_axis):
        if H % tp != 0:
            continue
        if KV % tp == 0:
            return MeshPlan(tp=tp, sp=model_axis // tp, kv_dup=1)
        if tp % KV == 0:
            return MeshPlan(tp=tp, sp=model_axis // tp, kv_dup=tp // KV)
    raise ValueError(f"no valid tp factoring for {cfg.name} (H={H}, KV={KV})")


def _descending_divisors(n: int) -> list[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def padded_vocab(vocab_size: int, multiple: int = 2048) -> int:
    """Pad vocab so each tp shard is lane-aligned (multiple = tp*128)."""
    return int(math.ceil(vocab_size / multiple) * multiple)
