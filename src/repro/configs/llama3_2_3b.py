"""llama3.2-3b [dense] — hf:meta-llama (small llama3)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=128,
    mlp_activation="swiglu", rope_theta=500000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="llama3.2-3b-smoke",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
)
