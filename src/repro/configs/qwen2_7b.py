"""qwen2-7b [dense] — arXiv:2407.10671 (GQA, QKV bias)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    mlp_activation="swiglu", qkv_bias=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="qwen2-7b-smoke",
    num_layers=2, d_model=112, num_heads=7, num_kv_heads=1, head_dim=16,
    d_ff=224, vocab_size=512,
)
