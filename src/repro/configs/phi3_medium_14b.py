"""phi3-medium-14b [dense] — arXiv:2404.14219 (RoPE SwiGLU GQA)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352, head_dim=128,
    mlp_activation="swiglu",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="phi3-medium-14b-smoke",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512,
)
