"""zamba2-1.2b [hybrid] — arXiv:2411.15242 (Mamba2 + shared attn blocks)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    mlp_activation="swiglu",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    hybrid_attn_every=6, scan_layers=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="zamba2-1.2b-smoke",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    hybrid_attn_every=2, ssm_chunk=16,
)
