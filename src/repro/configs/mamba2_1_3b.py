"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD, attention-free)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=0,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="mamba2-1.3b-smoke",
    num_layers=3, d_model=64, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
)
