"""From-scratch optimizers (no optax): AdamW with f32 master weights, SGD.

Mixed-precision layout: working params bf16 (what the model consumes), master
copy + first/second moments in f32.  All state inherits the parameter
shardings (FSDP shards optimizer state over ``data`` — ZeRO style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decay)


def adamw_init(params: Params) -> dict:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig, grads: Params, opt_state: dict, params: Params
) -> tuple[Params, dict, dict]:
    """Returns (new bf16 params, new opt state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        master = master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        )
        return m, v, master

    flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                        opt_state["master"])
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(
        lambda mast, p: mast.astype(p.dtype), master, params
    )
    new_state = {"master": master, "m": m, "v": v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def sgd_update(grads: Params, params: Params, lr: float) -> Params:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
