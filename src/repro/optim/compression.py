"""Gradient/update compression for the federated client→cloud path.

The paper's DeviceFlow moves whole model updates; at LM scale the update
payload dominates edge bandwidth.  We provide the two standard distributed-
optimization tricks, both with exact round-trip APIs so DeviceFlow messages
can carry compressed payloads:

* **top-k sparsification with error feedback** — keep the k largest-magnitude
  entries per tensor; the residual is fed back into the next round's update
  (memory of the compressor keeps convergence);
* **int8 quantization** — symmetric per-tensor scaling.

Both remain *host transforms*.  :func:`topk_compress` is the per-message
scalar form; :func:`topk_compress_rows` is its columnar (stacked) form — one
vectorized per-row top-k over a whole cohort chunk, so compressed rounds
ride the columnar message plane (``HybridSimulation(payload_transform=...)``)
instead of bypassing it.  The *fused* wire-level path — int8 quantization
folded into the cohort jit with dequantize-and-reduce aggregation — lives in
``core.updates`` (``UpdateBuffer(wire="int8")``, ``quantize_rows``) and
``kernels.fed_reduce`` and never round-trips through the host at all.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


@dataclasses.dataclass(frozen=True)
class TopKState:
    residual: Params  # error-feedback memory


def topk_init(params: Params) -> TopKState:
    return TopKState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


@jax.jit
def _nnz_and_total(tree: Params) -> tuple[jax.Array, jax.Array]:
    # One fused reduction over every leaf — a single host sync for the
    # stats, instead of one blocking int() per leaf.
    leaves = jax.tree.leaves(tree)
    nz = sum(jnp.count_nonzero(l) for l in leaves)
    total = sum(l.size for l in leaves)
    return nz, jnp.asarray(total)


def topk_compress(
    update: Params, state: TopKState, *, fraction: float = 0.01
) -> tuple[Params, TopKState, dict]:
    """Returns (sparse update (dense layout, zeros elsewhere), state, stats)."""

    def one(u, r):
        uf = u.astype(jnp.float32) + r
        flat = uf.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(uf) >= thresh
        kept = jnp.where(mask, uf, 0.0)
        return kept.astype(u.dtype), (uf - kept)

    pairs = jax.tree.map(one, update, state.residual)
    kept = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    nz, total = map(int, jax.device_get(_nnz_and_total(kept)))
    return kept, TopKState(residual=resid), {
        "nonzero": nz, "total": total,
        "compression_ratio": total / max(nz, 1),
    }


@functools.partial(jax.jit, static_argnames=("fraction",))
def _topk_rows(leaves2d: tuple, residuals, fraction: float):
    # Vectorized per-row top-k over (rows, size) leaves: one lax.top_k per
    # leaf covers every device in the chunk.  ``residuals`` is None (no
    # error-feedback memory yet) or one f32 (rows, size) array per leaf.
    kept, new_res = [], []
    nnz_rows = None
    for k_idx, leaf in enumerate(leaves2d):
        uf = leaf.astype(jnp.float32)
        if residuals is not None:
            uf = uf + residuals[k_idx]
        k = max(1, int(uf.shape[1] * fraction))
        thresh = jax.lax.top_k(jnp.abs(uf), k)[0][:, -1:]
        keep = jnp.where(jnp.abs(uf) >= thresh, uf, 0.0)
        kept.append(keep.astype(leaf.dtype))
        new_res.append(uf - keep)
        nnz = jnp.count_nonzero(keep, axis=1)
        nnz_rows = nnz if nnz_rows is None else nnz_rows + nnz
    return tuple(kept), tuple(new_res), nnz_rows


def topk_compress_rows(
    stacked: Params, residual: "tuple | None" = None, *,
    fraction: float = 0.01,
) -> tuple[Params, tuple, np.ndarray]:
    """Columnar :func:`topk_compress`: per-row top-k over a *stacked* update
    (pytree leaves shaped ``(rows, ...)``, one row per device).

    Returns ``(kept stacked tree, residual, per-row nonzero counts)``.
    ``residual`` is the error-feedback memory as a tuple of f32
    ``(rows, size)`` arrays — pass the returned tuple back on the same
    chunk's next round (``None`` starts from zero).  The nonzero counts are
    what a sparse encoding ships per row (value + index pairs), i.e. the
    per-row wire size is ``counts * 8``.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    shapes = [tuple(l.shape) for l in leaves]
    leaves2d = tuple(l.reshape(l.shape[0], -1) for l in leaves)
    if residual is not None and not (
            len(residual) == len(leaves2d)
            and all(tuple(r.shape) == tuple(l.shape)
                    for r, l in zip(residual, leaves2d))):
        residual = None  # layout changed: restart the compressor memory
    kept2d, new_res, nnz_rows = _topk_rows(leaves2d, residual, fraction)
    kept = jax.tree_util.tree_unflatten(
        treedef, [k.reshape(s) for k, s in zip(kept2d, shapes)])
    return kept, tuple(new_res), np.asarray(nnz_rows)


def int8_quantize(update: Params) -> tuple[Params, Params]:
    """Returns (int8 tree, per-tensor scales)."""

    def one(u):
        uf = u.astype(jnp.float32)
        scale = jnp.maximum(jnp.abs(uf).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(uf / scale), -127, 127).astype(jnp.int8)
        return q, scale

    pairs = jax.tree.map(one, update)
    q = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return q, s


def int8_dequantize(q: Params, scales: Params, like: Params) -> Params:
    return jax.tree.map(
        lambda qq, ss, p: (qq.astype(jnp.float32) * ss).astype(p.dtype),
        q, scales, like,
    )


def payload_bytes(tree: Params) -> int:
    """Wire bytes of a payload tree — what actually crosses the wire.

    A quantized payload is the ``(q, scales)`` *pair*; pass the pair and the
    scale bytes are counted alongside the int8 values (a bare ``q`` tree
    undercounts the wire by one scale per tensor).  Leaves without an array
    protocol (Python scalars — e.g. scales pulled through ``float()``) are
    counted at their array footprint instead of being dropped.
    """
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "size") and hasattr(x, "dtype"):
            total += int(x.size) * np.dtype(x.dtype).itemsize
        else:
            total += np.asarray(x).nbytes
    return total
