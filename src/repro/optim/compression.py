"""Gradient/update compression for the federated client→cloud path.

The paper's DeviceFlow moves whole model updates; at LM scale the update
payload dominates edge bandwidth.  We provide the two standard distributed-
optimization tricks, both with exact round-trip APIs so DeviceFlow messages
can carry compressed payloads:

* **top-k sparsification with error feedback** — keep the k largest-magnitude
  entries per tensor; the residual is fed back into the next round's update
  (memory of the compressor keeps convergence);
* **int8 quantization** — symmetric per-tensor scaling.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class TopKState:
    residual: Params  # error-feedback memory


def topk_init(params: Params) -> TopKState:
    return TopKState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def topk_compress(
    update: Params, state: TopKState, *, fraction: float = 0.01
) -> tuple[Params, TopKState, dict]:
    """Returns (sparse update (dense layout, zeros elsewhere), state, stats)."""

    def one(u, r):
        uf = u.astype(jnp.float32) + r
        flat = uf.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(uf) >= thresh
        kept = jnp.where(mask, uf, 0.0)
        return kept.astype(u.dtype), (uf - kept)

    pairs = jax.tree.map(one, update, state.residual)
    kept = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    nz = sum(int(jnp.count_nonzero(x)) for x in jax.tree.leaves(kept))
    total = sum(x.size for x in jax.tree.leaves(kept))
    return kept, TopKState(residual=resid), {
        "nonzero": nz, "total": total,
        "compression_ratio": total / max(nz, 1),
    }


def int8_quantize(update: Params) -> tuple[Params, Params]:
    """Returns (int8 tree, per-tensor scales)."""

    def one(u):
        uf = u.astype(jnp.float32)
        scale = jnp.maximum(jnp.abs(uf).max(), 1e-12) / 127.0
        q = jnp.clip(jnp.round(uf / scale), -127, 127).astype(jnp.int8)
        return q, scale

    pairs = jax.tree.map(one, update)
    q = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return q, s


def int8_dequantize(q: Params, scales: Params, like: Params) -> Params:
    return jax.tree.map(
        lambda qq, ss, p: (qq.astype(jnp.float32) * ss).astype(p.dtype),
        q, scales, like,
    )


def payload_bytes(tree: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
