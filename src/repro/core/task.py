"""Task design specification (paper §III.A).

A *task* is SimDC's core operational unit: a unique ``task_id``, a single
*operator flow* (an ordered sequence of named operators that every simulated
device executes uniformly), per-grade device counts, the number of rounds
(repetitions of the operator flow), requested resources, and a scheduling
priority.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Mapping, Sequence

_TASK_COUNTER = itertools.count()

# Registry of named operators usable inside an operator flow.  Operators are
# pure callables ``op(state, ctx) -> state`` so flows are replayable and
# checkpointable.
_OPERATOR_REGISTRY: dict[str, Callable[..., Any]] = {}


def register_operator(name: str):
    """Decorator registering an operator implementation under ``name``."""

    def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
        if name in _OPERATOR_REGISTRY:
            raise ValueError(f"operator {name!r} already registered")
        _OPERATOR_REGISTRY[name] = fn
        return fn

    return deco


def get_operator(name: str) -> Callable[..., Any]:
    try:
        return _OPERATOR_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"operator {name!r} not registered; known: {sorted(_OPERATOR_REGISTRY)}"
        ) from None


def clear_operator_registry() -> None:  # test hook
    _OPERATOR_REGISTRY.clear()


@dataclasses.dataclass(frozen=True)
class OperatorFlow:
    """An ordered sequence of operator names, executed uniformly per device."""

    operators: tuple[str, ...]

    def __post_init__(self):
        if not self.operators:
            raise ValueError("operator flow must contain at least one operator")

    def resolve(self) -> tuple[Callable[..., Any], ...]:
        return tuple(get_operator(n) for n in self.operators)


@dataclasses.dataclass(frozen=True)
class GradeSpec:
    """Per-grade simulation demand within a task (paper §IV.B symbols)."""

    grade: str
    num_devices: int  # N_i — total devices of this grade to simulate
    benchmarking_devices: int = 0  # q_i — physical devices reserved for measurement
    logical_bundles: int = 0  # f_i — resource bundles requested in Logical Simulation
    bundles_per_device: int = 1  # k_i — bundles needed to emulate ONE device
    physical_devices: int = 0  # m_i — physical phones requested in Device Simulation

    def __post_init__(self):
        if self.num_devices < 0 or self.benchmarking_devices < 0:
            raise ValueError("device counts must be non-negative")
        if self.benchmarking_devices > self.num_devices:
            raise ValueError("q_i cannot exceed N_i")
        if self.bundles_per_device <= 0:
            raise ValueError("k_i must be positive")

    @property
    def allocatable_devices(self) -> int:
        """N_i - q_i — devices the §IV.B allocator may split across tiers
        (the q_i benchmarking devices are reserved for measurement)."""
        return self.num_devices - self.benchmarking_devices

    def with_resources(self, logical_bundles: int,
                       physical_devices: int) -> "GradeSpec":
        """This grade under an elastic resource grant: same devices to
        simulate, granted (instead of requested) tier resources.  The event
        engine re-solves allocations against these effective specs whenever
        a task's grant changes mid-run."""
        return dataclasses.replace(
            self, logical_bundles=logical_bundles,
            physical_devices=physical_devices)


@dataclasses.dataclass
class Task:
    """A SimDC task (paper §III.A)."""

    flow: OperatorFlow
    grades: tuple[GradeSpec, ...]
    rounds: int = 1
    priority: int = 0  # higher = more urgent (expected benefit proxy)
    deviceflow_strategy: Any | None = None  # strategy object from core.strategies
    metadata: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    task_id: int = dataclasses.field(default_factory=lambda: next(_TASK_COUNTER))

    def __post_init__(self):
        if self.rounds <= 0:
            raise ValueError("rounds must be positive")
        if not self.grades:
            raise ValueError("task must request at least one device grade")
        seen = set()
        for g in self.grades:
            if g.grade in seen:
                raise ValueError(f"duplicate grade {g.grade!r} in task")
            seen.add(g.grade)

    @property
    def total_devices(self) -> int:
        return sum(g.num_devices for g in self.grades)

    def demand(self) -> dict[str, tuple[int, int]]:
        """Resource demand per grade: (logical bundles, physical devices)."""
        return {g.grade: (g.logical_bundles, g.physical_devices) for g in self.grades}

    def effective_grades(
        self, grant: Mapping[str, tuple[int, int]]
    ) -> tuple[GradeSpec, ...]:
        """Grade specs under a (possibly clamped) resource grant.

        Grades absent from ``grant`` keep their requested resources.  This is
        how the event engine expresses elastic allocation: a task admitted
        with less than its full demand is solved against the resources it
        actually holds, and re-solved when the grant changes.
        """
        out = []
        for g in self.grades:
            bundles, phones = grant.get(
                g.grade, (g.logical_bundles, g.physical_devices))
            out.append(g.with_resources(bundles, phones))
        return tuple(out)


class TaskQueue:
    """FIFO-with-priority queue of submitted tasks (paper: *Task Queue*).

    Preempted (paused) tasks re-enter through the same ``submit`` path: they
    keep their original ``task_id``, so ``pending`` ranks a resumed task
    exactly where its priority and submission order put it the first time —
    a pause changes *when* a task runs, never its place in line.
    """

    def __init__(self) -> None:
        self._tasks: list[Task] = []

    def submit(self, task: Task) -> int:
        if task.task_id in self:
            # A duplicate would double-admit and double-freeze resources
            # (e.g. pausing a task that was never removed from the queue).
            raise ValueError(f"task {task.task_id} already queued")
        self._tasks.append(task)
        return task.task_id

    def __len__(self) -> int:
        return len(self._tasks)

    def __contains__(self, task_id: int) -> bool:
        return any(t.task_id == task_id for t in self._tasks)

    def pending(self) -> Sequence[Task]:
        # Stable order: priority desc, then submission order (task_id asc).
        return sorted(self._tasks, key=lambda t: (-t.priority, t.task_id))

    def remove(self, task_id: int) -> Task:
        for i, t in enumerate(self._tasks):
            if t.task_id == task_id:
                return self._tasks.pop(i)
        raise KeyError(f"task {task_id} not in queue")
