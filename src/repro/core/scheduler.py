"""Task Manager + Resource Manager (paper §III.B) + event-driven engine.

* ``ResourceManager`` — tracks the hybrid pool (logical bundles per grade and
  physical phones per grade), supports query/freeze/release and dynamic
  scale-up/down; ``subscribe`` notifies listeners (the event engine) of pool
  changes so allocations can be re-solved mid-task.
* ``TaskScheduler`` — greedy: repeatedly admit the highest-benefit task whose
  demand fits the free pool (benefit = scheduling priority, ties broken by
  submission order).
* ``TaskRunner`` — serial reference executor: solves the hybrid-allocation
  ILP (``core.allocation``) and drives one task's rounds to completion.  With
  a ``clock`` it also charges simulated time per round, which makes it the
  *serial baseline* the ``multi_task_schedule`` benchmark gates against.
* ``TaskEngine`` — the event-driven multi-task round engine (paper §IV.B's
  time-shared resource pool): per-task round events interleave on a shared
  ``VirtualClock`` instead of draining tasks back to back, queued tasks are
  admitted at event boundaries, and a task's allocation is re-solved when
  ``ResourceManager.scale`` changes the pool mid-task (elastic
  re-allocation, vs the paper's static split).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable, Mapping

from repro.core import allocation as alloc
from repro.core.deviceflow import VirtualClock
from repro.core.task import Task, TaskQueue


class TaskState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"


@dataclasses.dataclass
class ResourcePool:
    """Free resources per grade: (logical bundles, physical phones)."""

    logical_bundles: dict[str, int]
    physical_devices: dict[str, int]

    def copy(self) -> "ResourcePool":
        return ResourcePool(dict(self.logical_bundles), dict(self.physical_devices))


class ResourceManager:
    def __init__(self, pool: ResourcePool):
        self._total = pool.copy()
        self._free = pool.copy()
        self._frozen: dict[int, dict[str, tuple[int, int]]] = {}
        self._listeners: list[Callable[[], None]] = []

    # -- query ---------------------------------------------------------------
    def free(self) -> ResourcePool:
        return self._free.copy()

    def fits(self, demand: dict[str, tuple[int, int]]) -> bool:
        for grade, (bundles, phones) in demand.items():
            if self._free.logical_bundles.get(grade, 0) < bundles:
                return False
            if self._free.physical_devices.get(grade, 0) < phones:
                return False
        return True

    def frozen(self, task_id: int) -> dict[str, tuple[int, int]] | None:
        """The grant currently frozen for ``task_id`` (None if none)."""
        got = self._frozen.get(task_id)
        return dict(got) if got is not None else None

    # -- freeze / release -------------------------------------------------------
    def freeze(self, task_id: int, demand: dict[str, tuple[int, int]]) -> None:
        if not self.fits(demand):
            raise ValueError(f"demand for task {task_id} does not fit free pool")
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) - bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) - phones
            )
        self._frozen[task_id] = dict(demand)

    def release(self, task_id: int) -> None:
        demand = self._frozen.pop(task_id, None)
        if demand is None:
            return
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) + bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) + phones
            )

    def refreeze(self, task_id: int, demand: dict[str, tuple[int, int]]) -> None:
        """Atomically replace a task's frozen grant (elastic re-allocation).

        Rolls back to the old grant if the new one does not fit.
        """
        old = self._frozen.get(task_id)
        if old is None:
            raise KeyError(f"task {task_id} holds no frozen resources")
        self.release(task_id)
        try:
            self.freeze(task_id, demand)
        except ValueError:
            self.freeze(task_id, old)
            raise

    # -- elastic scaling (paper: "dynamic scaling up or down") ------------------
    def subscribe(self, fn: Callable[[], None]) -> None:
        """Register a pool-change listener (fired after every ``scale``)."""
        self._listeners.append(fn)

    def scale(self, grade: str, *, bundles_delta: int = 0, phones_delta: int = 0) -> None:
        """Add/remove capacity.  Removal never takes frozen resources."""
        for field, delta in (
            ("logical_bundles", bundles_delta),
            ("physical_devices", phones_delta),
        ):
            free = getattr(self._free, field)
            total = getattr(self._total, field)
            if delta < 0 and free.get(grade, 0) + delta < 0:
                raise ValueError(
                    f"cannot remove {-delta} {field} of grade {grade}: "
                    f"only {free.get(grade, 0)} free"
                )
            free[grade] = free.get(grade, 0) + delta
            total[grade] = total.get(grade, 0) + delta
        for fn in self._listeners:
            fn()


@dataclasses.dataclass
class ScheduledTask:
    task: Task
    allocation: alloc.AllocationResult
    state: TaskState = TaskState.QUEUED


class TaskScheduler:
    """Greedy scheduler (paper: maximize expected benefit under resources)."""

    def __init__(self, resources: ResourceManager):
        self.resources = resources

    def select(self, queue: TaskQueue) -> list[Task]:
        """Admit tasks in priority order while their demand fits."""
        admitted = []
        for task in queue.pending():
            demand = task.demand()
            if self.resources.fits(demand):
                self.resources.freeze(task.task_id, demand)
                queue.remove(task.task_id)
                admitted.append(task)
        return admitted


def _normalize_runtimes(runtimes) -> Callable[[Task], list[alloc.GradeRuntime]]:
    return runtimes.for_task if hasattr(runtimes, "for_task") else runtimes


def _run_tiers(tier_runners: Mapping[str, Callable[..., Any]], task: Task,
               allocation: alloc.AllocationResult, round_idx: int) -> None:
    """Execute one round's per-grade split through the tier callables."""
    for ga in allocation.per_grade:
        if ga.logical_devices:
            tier_runners["logical"](task, ga.grade, ga.logical_devices, round_idx)
        if ga.physical_devices:
            tier_runners["device"](task, ga.grade, ga.physical_devices, round_idx)


# RoundRunner contract: (task, round_idx, allocation, t) -> measured round
# duration in virtual seconds, or None to fall back to allocation.makespan.
RoundRunner = Callable[[Task, int, alloc.AllocationResult, float],
                       "float | None"]


class TaskRunner:
    """Serial reference executor for admitted tasks.

    ``runtimes`` supplies the per-grade ``GradeRuntime``s the allocator runs
    on: either a callable ``task -> list[GradeRuntime]`` or any object with a
    ``for_task`` method — e.g. a ``calibration.RuntimeCalibrator``, so the
    scheduler allocates on *measured* fleet durations instead of hand-coded
    constants.

    Round execution is either ``tier_runners`` (a map of tier name
    ("logical"/"device") to ``run(task, grade, num_devices, round_idx)``) or
    a ``round_runner`` callable ``(task, round_idx, allocation, t) ->
    duration_s | None`` shared with ``TaskEngine`` — so the serial baseline
    and the event engine execute rounds through identical code.

    With a ``clock``, each round advances the shared ``VirtualClock`` by the
    round's (measured or estimated) duration, so a serial drain reports a
    *simulated makespan* directly comparable to the event engine's.  This is
    deliberately the run-to-completion baseline: one task drains fully
    before the next starts.
    """

    def __init__(
        self,
        resources: ResourceManager,
        runtimes: Callable[[Task], list[alloc.GradeRuntime]],
        tier_runners: dict[str, Callable[..., list[Any]]] | None = None,
        *,
        round_runner: RoundRunner | None = None,
        clock: VirtualClock | None = None,
        on_round_complete: Callable[[Task, int], None] | None = None,
    ):
        if tier_runners is None and round_runner is None:
            raise ValueError("pass tier_runners or round_runner")
        self.resources = resources
        self.runtimes = _normalize_runtimes(runtimes)
        self.tier_runners = tier_runners
        self.round_runner = round_runner
        self.clock = clock
        self.on_round_complete = on_round_complete
        self.records: dict[int, ScheduledTask] = {}

    def run(self, task: Task) -> ScheduledTask:
        rts = self.runtimes(task)
        result = alloc.solve_allocation(list(task.grades), rts)
        rec = ScheduledTask(task=task, allocation=result, state=TaskState.RUNNING)
        self.records[task.task_id] = rec
        try:
            for round_idx in range(task.rounds):
                duration = None
                if self.round_runner is not None:
                    t = self.clock.now if self.clock is not None else 0.0
                    duration = self.round_runner(task, round_idx, result, t)
                else:
                    _run_tiers(self.tier_runners, task, result, round_idx)
                if self.on_round_complete is not None:
                    self.on_round_complete(task, round_idx)
                if self.clock is not None:
                    self.clock.advance(
                        duration if duration is not None else result.makespan)
            rec.state = TaskState.COMPLETED
        except Exception:
            rec.state = TaskState.FAILED
            raise
        finally:
            self.resources.release(task.task_id)
        return rec


# --------------------------------------------------------------------------- #
# Event-driven multi-task engine
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class TaskExecution:
    """Live state of one admitted task inside ``TaskEngine``."""

    task: Task
    grant: dict[str, tuple[int, int]]  # resources currently frozen for it
    allocation: alloc.AllocationResult
    state: TaskState = TaskState.RUNNING
    rounds_done: int = 0
    started_t: float = 0.0
    next_event_t: float | None = None
    finished_t: float | None = None
    reallocations: int = 0  # elastic grant upgrades applied mid-task
    generation: int = 0  # invalidates stale scheduled events

    @property
    def full_grant(self) -> bool:
        return self.grant == self.task.demand()


class StrandedTasksError(RuntimeError):
    """Raised by ``TaskManager.drain(strict=True)`` when tasks are left in
    the queue (nothing fits, or ``max_cycles`` ran out)."""

    def __init__(self, stranded: list[Task], reason: str):
        self.stranded = stranded
        self.reason = reason
        super().__init__(
            f"{len(stranded)} task(s) stranded in queue ({reason}): "
            f"{[t.task_id for t in stranded]}")


class DrainResult(list):
    """``TaskManager.drain`` result: the completed ``ScheduledTask``s (list
    behavior preserved) plus explicit stranded-task reporting — a drain that
    leaves work in the queue is no longer indistinguishable from success."""

    def __init__(self, done: Iterable = (), stranded: Iterable[Task] = (),
                 reason: str | None = None):
        super().__init__(done)
        self.stranded: list[Task] = list(stranded)
        self.stranded_reason = reason if self.stranded else None


class TaskEngine:
    """Event-driven multi-task round engine on a shared ``VirtualClock``.

    Instead of draining each admitted task to completion (``TaskRunner``),
    every admitted task schedules its next *round event* on the clock; rounds
    of different tasks interleave in virtual-time order, so several tasks'
    ``RoundPlan``s time-share the same resource pool — the contention regime
    run-to-completion scheduling structurally cannot express.

    * **Admission at event boundaries** — whenever a task completes (or the
      pool changes), queued tasks are re-checked in priority order and
      admitted if a feasible grant exists.
    * **Elastic grants** — with ``elastic=True`` a task whose full demand
      does not fit may be admitted with its demand *clamped to the free
      pool* (any grant whose effective allocation is solvable); when
      resources free up — a task finishing, or ``ResourceManager.scale``
      growing the pool — running tasks top their grants back up toward the
      full request and their allocation is re-solved for the remaining
      rounds (``TaskExecution.reallocations`` counts the upgrades).
    * **Measured durations drive event timestamps** — round execution goes
      through the same ``round_runner``/``tier_runners`` contracts as
      ``TaskRunner``; a ``round_runner`` returning a measured duration (e.g.
      ``FederatedRoundOutcome.makespan_s``) times the next event, otherwise
      the allocation's estimated makespan does.  With both executors omitted
      the engine runs a pure virtual-time schedule (useful for scheduling
      studies and tests).  Passing a ``RuntimeCalibrator`` as ``runtimes``
      plus a ``duration_rng`` draws *sampled* observed runtimes per round,
      so event timestamps carry measured round-to-round jitter.

    Share the clock with a ``DeviceFlow`` (``clock=flow.clock``) and round
    events interleave with dispatch/delivery events on one timeline.
    """

    def __init__(
        self,
        resources: ResourceManager,
        runtimes: Callable[[Task], list[alloc.GradeRuntime]],
        tier_runners: dict[str, Callable[..., list[Any]]] | None = None,
        *,
        round_runner: RoundRunner | None = None,
        clock: VirtualClock | None = None,
        elastic: bool = True,
        duration_rng=None,
        on_round_complete: Callable[[Task, int], None] | None = None,
        on_task_complete: Callable[[TaskExecution], None] | None = None,
    ):
        self.resources = resources
        self.runtimes = _normalize_runtimes(runtimes)
        self._calibrator = (runtimes if hasattr(runtimes, "sample_for_task")
                            else None)
        self.duration_rng = duration_rng
        self.tier_runners = tier_runners
        self.round_runner = round_runner
        self.clock = clock or VirtualClock()
        self.elastic = elastic
        self.on_round_complete = on_round_complete
        self.on_task_complete = on_task_complete
        self.queue = TaskQueue()
        self.executions: dict[int, TaskExecution] = {}
        self.completed: list[TaskExecution] = []
        resources.subscribe(self._on_pool_change)

    # -- submission ---------------------------------------------------------
    def submit(self, task: Task) -> int:
        tid = self.queue.submit(task)
        self.clock.schedule(self.clock.now, self._admit)
        return tid

    # -- allocation ---------------------------------------------------------
    def _round_runtimes(self, task: Task) -> list[alloc.GradeRuntime]:
        if self._calibrator is not None and self.duration_rng is not None:
            return self._calibrator.sample_for_task(task, self.duration_rng)
        return self.runtimes(task)

    def _solve(self, task: Task,
               grant: Mapping[str, tuple[int, int]]) -> alloc.AllocationResult:
        return alloc.solve_allocation(
            list(task.effective_grades(grant)), self._round_runtimes(task))

    def _grant_for(self, task: Task) -> dict[str, tuple[int, int]] | None:
        demand = task.demand()
        if self.resources.fits(demand):
            return demand
        if not self.elastic:
            return None
        free = self.resources.free()
        clamped = {
            g: (min(b, free.logical_bundles.get(g, 0)),
                min(p, free.physical_devices.get(g, 0)))
            for g, (b, p) in demand.items()
        }
        if not any(b or p for b, p in clamped.values()):
            return None
        return clamped

    # -- event handlers ------------------------------------------------------
    def _admit(self) -> None:
        """Admit every queued task (priority order) with a feasible grant."""
        for task in list(self.queue.pending()):
            grant = self._grant_for(task)
            if grant is None:
                continue
            try:
                allocation = self._solve(task, grant)
            except ValueError:  # grant infeasible (a grade got no resources)
                continue
            self.resources.freeze(task.task_id, grant)
            self.queue.remove(task.task_id)
            ex = TaskExecution(task=task, grant=grant, allocation=allocation,
                               started_t=self.clock.now)
            self.executions[task.task_id] = ex
            self._schedule(ex, self.clock.now, self._round_event)

    def _rebalance(self) -> None:
        """Top running tasks' grants back up toward their full demand and
        re-solve their allocations (elastic re-allocation).  The in-flight
        round keeps its already-scheduled completion time; the new split
        applies from the next round."""
        if not self.elastic:
            return
        running = sorted(
            (ex for ex in self.executions.values()
             if ex.state is TaskState.RUNNING and not ex.full_grant),
            key=lambda ex: (-ex.task.priority, ex.task.task_id))
        for ex in running:
            free = self.resources.free()
            demand = ex.task.demand()
            upgraded = {
                g: (min(rb, ex.grant[g][0] + free.logical_bundles.get(g, 0)),
                    min(rp, ex.grant[g][1] + free.physical_devices.get(g, 0)))
                for g, (rb, rp) in demand.items()
            }
            if upgraded == ex.grant:
                continue
            try:
                allocation = self._solve(ex.task, upgraded)
            except ValueError:
                continue
            self.resources.refreeze(ex.task.task_id, upgraded)
            ex.grant = upgraded
            ex.allocation = allocation
            ex.reallocations += 1

    def _on_pool_change(self) -> None:
        # Deferred to an event so mid-round scale() calls take effect at the
        # next event boundary, like every other engine state change.
        self.clock.schedule(self.clock.now, self._pool_change_event)

    def _pool_change_event(self) -> None:
        self._rebalance()
        self._admit()

    def _schedule(self, ex: TaskExecution, t: float, handler) -> None:
        ex.generation += 1
        gen = ex.generation
        ex.next_event_t = t
        tid = ex.task.task_id
        self.clock.schedule(t, lambda: handler(tid, gen))

    def _round_event(self, tid: int, gen: int) -> None:
        ex = self.executions.get(tid)
        if ex is None or ex.generation != gen or ex.state is not TaskState.RUNNING:
            return  # stale event (task rescheduled/failed meanwhile)
        round_idx = ex.rounds_done
        t = self.clock.now
        duration = None
        try:
            if self.round_runner is not None:
                duration = self.round_runner(ex.task, round_idx, ex.allocation, t)
            elif self.tier_runners is not None:
                _run_tiers(self.tier_runners, ex.task, ex.allocation, round_idx)
        except Exception:
            ex.state = TaskState.FAILED
            ex.next_event_t = None
            self.resources.release(tid)
            raise
        if duration is None:
            duration = ex.allocation.makespan
        ex.rounds_done += 1
        if self.on_round_complete is not None:
            self.on_round_complete(ex.task, round_idx)
        if ex.rounds_done >= ex.task.rounds:
            # The task occupies its resources until the last round's slowest
            # device reports — release at t + duration, not at dispatch.
            self._schedule(ex, t + duration, self._completion_event)
        else:
            self._schedule(ex, t + duration, self._round_event)

    def _completion_event(self, tid: int, gen: int) -> None:
        ex = self.executions.get(tid)
        if ex is None or ex.generation != gen or ex.state is not TaskState.RUNNING:
            return
        ex.state = TaskState.COMPLETED
        ex.finished_t = self.clock.now
        ex.next_event_t = None
        self.resources.release(tid)
        self.completed.append(ex)
        if self.on_task_complete is not None:
            self.on_task_complete(ex)
        # Event boundary: freed resources may fit queued tasks or top up
        # running elastic grants.
        self._rebalance()
        self._admit()

    # -- driving -------------------------------------------------------------
    def run_until(self, t_end: float = float("inf")) -> list[TaskExecution]:
        """Drive the clock; returns tasks completed so far."""
        self.clock.run_until(t_end)
        return self.completed

    def drain(self) -> DrainResult:
        """Run until the event heap empties; reports stranded tasks."""
        self.run_until()
        stranded = list(self.queue.pending())
        return DrainResult(self.completed, stranded,
                           "nothing-fits" if stranded else None)

    @property
    def makespan(self) -> float:
        """Virtual time of the latest task completion so far."""
        return max((ex.finished_t for ex in self.completed
                    if ex.finished_t is not None), default=0.0)

    # -- checkpointing --------------------------------------------------------
    def state_dict(self) -> dict:
        """Resume-safe engine state (JSON-friendly; no Task objects).

        Captures the queue order, every live execution's grant/progress and
        its next scheduled event time, and the clock.  Tasks themselves are
        *not* serialized — like ``DeviceFlow.load_state_dict`` after
        ``register_task``, the caller re-supplies the ``Task`` objects on
        restore.
        """
        def enc(ex: TaskExecution) -> dict:
            return {
                "task_id": ex.task.task_id,
                "grant": {g: list(bp) for g, bp in ex.grant.items()},
                "state": ex.state.value,
                "rounds_done": ex.rounds_done,
                "started_t": ex.started_t,
                "next_event_t": ex.next_event_t,
                "finished_t": ex.finished_t,
                "reallocations": ex.reallocations,
            }

        return {
            "now": self.clock.now,
            "queue": [t.task_id for t in self.queue.pending()],
            "executions": [enc(ex) for ex in self.executions.values()],
        }

    def load_state_dict(self, state: Mapping,
                        tasks: Iterable[Task]) -> None:
        """Rebuild engine state from ``state_dict`` output.

        ``tasks`` supplies the Task objects referenced by the saved state
        (any iterable; matched by ``task_id``).  Requires a fresh engine on
        a fresh ``ResourceManager`` (grants are re-frozen here).  Pending
        round events are rescheduled at their saved timestamps, so a
        restored run continues on the exact same virtual timeline —
        *provided the runtimes provider is restored too*: allocations are
        re-solved here, so a ``RuntimeCalibrator`` must have its
        observations reloaded first (``RuntimeCalibrator.load_state_dict``)
        and a ``duration_rng`` engine's sampled event times are not
        reproducible across a restore (the generator state is not saved).
        """
        by_id = {t.task_id: t for t in tasks}
        self.clock.now = float(state["now"])
        for tid in state["queue"]:
            self.queue.submit(by_id[int(tid)])
        for enc in state["executions"]:
            tid = int(enc["task_id"])
            task = by_id[tid]
            grant = {g: (int(bp[0]), int(bp[1]))
                     for g, bp in enc["grant"].items()}
            ex = TaskExecution(
                task=task, grant=grant,
                allocation=self._solve(task, grant),
                state=TaskState(enc["state"]),
                rounds_done=int(enc["rounds_done"]),
                started_t=float(enc["started_t"]),
                finished_t=(None if enc["finished_t"] is None
                            else float(enc["finished_t"])),
                reallocations=int(enc["reallocations"]),
            )
            self.executions[tid] = ex
            if ex.state is TaskState.RUNNING:
                self.resources.freeze(tid, grant)
                if enc["next_event_t"] is not None:
                    t = float(enc["next_event_t"])
                    handler = (self._completion_event
                               if ex.rounds_done >= task.rounds
                               else self._round_event)
                    self._schedule(ex, t, handler)
            elif ex.state is TaskState.COMPLETED:
                self.completed.append(ex)
        self.clock.schedule(self.clock.now, self._admit)


class TaskManager:
    """Facade: queue + scheduler + runner (paper's *Task Manager* service).

    ``drain`` is the serial run-to-completion path — kept as the measured
    baseline; use a ``TaskEngine`` on a shared clock for event-driven
    multi-task rounds.
    """

    def __init__(self, resources: ResourceManager, runner: TaskRunner):
        self.queue = TaskQueue()
        self.scheduler = TaskScheduler(resources)
        self.runner = runner

    def submit(self, task: Task) -> int:
        return self.queue.submit(task)

    def step(self) -> list[ScheduledTask]:
        """One scheduling cycle: admit what fits, run to completion."""
        done = []
        for task in self.scheduler.select(self.queue):
            done.append(self.runner.run(task))
        return done

    def drain(self, max_cycles: int = 1000, *, strict: bool = False
              ) -> DrainResult:
        """Run scheduling cycles until the queue empties.

        Previously a non-empty queue at exit (nothing fits, or
        ``max_cycles`` exhausted) looked identical to success; the result
        now reports ``stranded`` tasks and ``stranded_reason`` explicitly,
        and ``strict=True`` raises ``StrandedTasksError`` instead.
        """
        done: list[ScheduledTask] = []
        reason = None
        for _ in range(max_cycles):
            if not len(self.queue):
                break
            got = self.step()
            if not got:  # nothing fits — resources exhausted for now
                reason = "nothing-fits"
                break
            done.extend(got)
        else:
            if len(self.queue):
                reason = "max-cycles-exhausted"
        out = DrainResult(done, self.queue.pending(), reason)
        if strict and out.stranded:
            raise StrandedTasksError(out.stranded, reason or "unknown")
        return out
