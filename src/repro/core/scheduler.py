"""Task Manager + Resource Manager (paper §III.B).

* ``ResourceManager`` — tracks the hybrid pool (logical bundles per grade and
  physical phones per grade), supports query/freeze/release and dynamic
  scale-up/down.
* ``TaskScheduler`` — greedy: repeatedly admit the highest-benefit task whose
  demand fits the free pool (benefit = scheduling priority, ties broken by
  submission order).
* ``TaskRunner`` — executes a scheduled task: solves the hybrid-allocation ILP
  (``core.allocation``), splits devices across the tiers, and drives rounds.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

from repro.core import allocation as alloc
from repro.core.task import Task, TaskQueue


class TaskState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"


@dataclasses.dataclass
class ResourcePool:
    """Free resources per grade: (logical bundles, physical phones)."""

    logical_bundles: dict[str, int]
    physical_devices: dict[str, int]

    def copy(self) -> "ResourcePool":
        return ResourcePool(dict(self.logical_bundles), dict(self.physical_devices))


class ResourceManager:
    def __init__(self, pool: ResourcePool):
        self._total = pool.copy()
        self._free = pool.copy()
        self._frozen: dict[int, dict[str, tuple[int, int]]] = {}

    # -- query ---------------------------------------------------------------
    def free(self) -> ResourcePool:
        return self._free.copy()

    def fits(self, demand: dict[str, tuple[int, int]]) -> bool:
        for grade, (bundles, phones) in demand.items():
            if self._free.logical_bundles.get(grade, 0) < bundles:
                return False
            if self._free.physical_devices.get(grade, 0) < phones:
                return False
        return True

    # -- freeze / release -------------------------------------------------------
    def freeze(self, task_id: int, demand: dict[str, tuple[int, int]]) -> None:
        if not self.fits(demand):
            raise ValueError(f"demand for task {task_id} does not fit free pool")
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) - bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) - phones
            )
        self._frozen[task_id] = dict(demand)

    def release(self, task_id: int) -> None:
        demand = self._frozen.pop(task_id, None)
        if demand is None:
            return
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) + bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) + phones
            )

    # -- elastic scaling (paper: "dynamic scaling up or down") ------------------
    def scale(self, grade: str, *, bundles_delta: int = 0, phones_delta: int = 0) -> None:
        """Add/remove capacity.  Removal never takes frozen resources."""
        for field, delta in (
            ("logical_bundles", bundles_delta),
            ("physical_devices", phones_delta),
        ):
            free = getattr(self._free, field)
            total = getattr(self._total, field)
            if delta < 0 and free.get(grade, 0) + delta < 0:
                raise ValueError(
                    f"cannot remove {-delta} {field} of grade {grade}: "
                    f"only {free.get(grade, 0)} free"
                )
            free[grade] = free.get(grade, 0) + delta
            total[grade] = total.get(grade, 0) + delta


@dataclasses.dataclass
class ScheduledTask:
    task: Task
    allocation: alloc.AllocationResult
    state: TaskState = TaskState.QUEUED


class TaskScheduler:
    """Greedy scheduler (paper: maximize expected benefit under resources)."""

    def __init__(self, resources: ResourceManager):
        self.resources = resources

    def select(self, queue: TaskQueue) -> list[Task]:
        """Admit tasks in priority order while their demand fits."""
        admitted = []
        for task in queue.pending():
            demand = task.demand()
            if self.resources.fits(demand):
                self.resources.freeze(task.task_id, demand)
                queue.remove(task.task_id)
                admitted.append(task)
        return admitted


class TaskRunner:
    """Executes admitted tasks against the hybrid tiers.

    ``runtimes`` supplies the per-grade ``GradeRuntime``s the allocator runs
    on: either a callable ``task -> list[GradeRuntime]`` or any object with a
    ``for_task`` method — e.g. a ``calibration.RuntimeCalibrator``, so the
    scheduler allocates on *measured* fleet durations instead of hand-coded
    constants.

    ``tier_runners`` maps tier name ("logical"/"device") to a callable
    ``run(task, grade, num_devices, round_idx) -> list[result]``; the runner
    stays agnostic of what the tiers compute (operator flows are resolved by
    the tiers themselves).
    """

    def __init__(
        self,
        resources: ResourceManager,
        runtimes: Callable[[Task], list[alloc.GradeRuntime]],
        tier_runners: dict[str, Callable[..., list[Any]]],
        *,
        on_round_complete: Callable[[Task, int], None] | None = None,
    ):
        self.resources = resources
        self.runtimes = (runtimes.for_task
                         if hasattr(runtimes, "for_task") else runtimes)
        self.tier_runners = tier_runners
        self.on_round_complete = on_round_complete
        self.records: dict[int, ScheduledTask] = {}

    def run(self, task: Task) -> ScheduledTask:
        rts = self.runtimes(task)
        result = alloc.solve_allocation(list(task.grades), rts)
        rec = ScheduledTask(task=task, allocation=result, state=TaskState.RUNNING)
        self.records[task.task_id] = rec
        try:
            for round_idx in range(task.rounds):
                for ga in result.per_grade:
                    if ga.logical_devices:
                        self.tier_runners["logical"](
                            task, ga.grade, ga.logical_devices, round_idx
                        )
                    if ga.physical_devices:
                        self.tier_runners["device"](
                            task, ga.grade, ga.physical_devices, round_idx
                        )
                if self.on_round_complete is not None:
                    self.on_round_complete(task, round_idx)
            rec.state = TaskState.COMPLETED
        except Exception:
            rec.state = TaskState.FAILED
            raise
        finally:
            self.resources.release(task.task_id)
        return rec


class TaskManager:
    """Facade: queue + scheduler + runner (paper's *Task Manager* service)."""

    def __init__(self, resources: ResourceManager, runner: TaskRunner):
        self.queue = TaskQueue()
        self.scheduler = TaskScheduler(resources)
        self.runner = runner

    def submit(self, task: Task) -> int:
        return self.queue.submit(task)

    def step(self) -> list[ScheduledTask]:
        """One scheduling cycle: admit what fits, run to completion."""
        done = []
        for task in self.scheduler.select(self.queue):
            done.append(self.runner.run(task))
        return done

    def drain(self, max_cycles: int = 1000) -> list[ScheduledTask]:
        out = []
        for _ in range(max_cycles):
            if not len(self.queue):
                break
            got = self.step()
            if not got:  # nothing fits — resources exhausted for now
                break
            out.extend(got)
        return out
