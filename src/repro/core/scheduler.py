"""Task Manager + Resource Manager (paper §III.B) + event-driven engine.

* ``ResourceManager`` — tracks the hybrid pool (logical bundles per grade and
  physical phones per grade), supports query/freeze/release and dynamic
  scale-up/down; ``subscribe`` notifies listeners (the event engine) of pool
  changes so allocations can be re-solved mid-task.
* ``TaskScheduler`` — greedy: repeatedly admit the highest-benefit task whose
  demand fits the free pool (benefit = scheduling priority, ties broken by
  submission order).
* ``TaskRunner`` — serial reference executor: solves the hybrid-allocation
  ILP (``core.allocation``) and drives one task's rounds to completion.  With
  a ``clock`` it also charges simulated time per round, which makes it the
  *serial baseline* the ``multi_task_schedule`` benchmark gates against.
* ``TaskEngine`` — the event-driven multi-task round engine (paper §IV.B's
  time-shared resource pool): per-task round events interleave on a shared
  ``VirtualClock`` instead of draining tasks back to back, queued tasks are
  admitted at event boundaries, and a task's allocation is re-solved when
  ``ResourceManager.scale`` changes the pool mid-task (elastic
  re-allocation, vs the paper's static split).  With ``preemptive=True`` a
  higher-priority arrival — or a ``scale(reclaim=True)`` pool shrink — may
  *refreeze down* lower-priority running grants at their next round-event
  boundary (pausing a task back to the queue when its grant clamps to
  zero), so priority expresses reclamation, not just admission order.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable, Mapping

from repro.core import allocation as alloc
from repro.core.deviceflow import VirtualClock
from repro.core.task import Task, TaskQueue


class TaskState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PAUSED = "paused"  # preempted to the queue; resumes with progress kept
    COMPLETED = "completed"
    FAILED = "failed"


@dataclasses.dataclass
class ResourcePool:
    """Free resources per grade: (logical bundles, physical phones)."""

    logical_bundles: dict[str, int]
    physical_devices: dict[str, int]

    def copy(self) -> "ResourcePool":
        return ResourcePool(dict(self.logical_bundles), dict(self.physical_devices))


class ResourceManager:
    def __init__(self, pool: ResourcePool):
        self._total = pool.copy()
        self._free = pool.copy()
        self._frozen: dict[int, dict[str, tuple[int, int]]] = {}
        self._listeners: list[Callable[[], None]] = []

    # -- query ---------------------------------------------------------------
    def free(self) -> ResourcePool:
        return self._free.copy()

    def total(self) -> ResourcePool:
        return self._total.copy()

    def deficit(self, grade: str) -> tuple[int, int]:
        """How far the free pool is below zero for ``grade``.

        Non-zero only after ``scale(..., reclaim=True)`` removed capacity
        that running tasks still hold; pool listeners (the ``TaskEngine``)
        pay it down by shrinking grants at round-event boundaries.
        """
        return (max(0, -self._free.logical_bundles.get(grade, 0)),
                max(0, -self._free.physical_devices.get(grade, 0)))

    def fits(self, demand: dict[str, tuple[int, int]]) -> bool:
        # Per component, and only where something is actually requested: a
        # zero component takes nothing, so it fits even while that
        # component's free pool is in deficit (``scale(reclaim=True)``).
        for grade, (bundles, phones) in demand.items():
            if bundles > 0 and self._free.logical_bundles.get(grade, 0) < bundles:
                return False
            if phones > 0 and self._free.physical_devices.get(grade, 0) < phones:
                return False
        return True

    def frozen(self, task_id: int) -> dict[str, tuple[int, int]] | None:
        """The grant currently frozen for ``task_id`` (None if none)."""
        got = self._frozen.get(task_id)
        return dict(got) if got is not None else None

    # -- freeze / release -------------------------------------------------------
    def freeze(self, task_id: int, demand: dict[str, tuple[int, int]]) -> None:
        if not self.fits(demand):
            raise ValueError(f"demand for task {task_id} does not fit free pool")
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) - bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) - phones
            )
        self._frozen[task_id] = dict(demand)

    def release(self, task_id: int) -> None:
        demand = self._frozen.pop(task_id, None)
        if demand is None:
            return
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) + bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) + phones
            )

    def refreeze(self, task_id: int, demand: dict[str, tuple[int, int]]) -> None:
        """Atomically replace a task's frozen grant (elastic re-allocation).

        Validates against the pool *as it would look after releasing the old
        grant* and raises without mutating anything when the new grant does
        not fit — a release-then-rollback would itself fail whenever the
        free pool is in deficit (``scale(reclaim=True)``), stranding the
        task's resources half-released.
        """
        old = self._frozen.get(task_id)
        if old is None:
            raise KeyError(f"task {task_id} holds no frozen resources")
        for grade, (bundles, phones) in demand.items():
            old_b, old_p = old.get(grade, (0, 0))
            # Validate per component, and only the GROWING ones: a component
            # at or below its old value releases capacity and is always
            # legal — even while that component's free pool is in deficit
            # (paying a deficit down must not be blocked by the deficit).
            if (bundles > old_b
                    and self._free.logical_bundles.get(grade, 0)
                    < bundles - old_b) or (
                    phones > old_p
                    and self._free.physical_devices.get(grade, 0)
                    < phones - old_p):
                raise ValueError(
                    f"refreeze for task {task_id} does not fit free pool")
        self.release(task_id)
        for grade, (bundles, phones) in demand.items():
            self._free.logical_bundles[grade] = (
                self._free.logical_bundles.get(grade, 0) - bundles
            )
            self._free.physical_devices[grade] = (
                self._free.physical_devices.get(grade, 0) - phones
            )
        self._frozen[task_id] = dict(demand)

    # -- elastic scaling (paper: "dynamic scaling up or down") ------------------
    def subscribe(self, fn: Callable[[], None]) -> None:
        """Register a pool-change listener (fired after every ``scale``)."""
        self._listeners.append(fn)

    def scale(self, grade: str, *, bundles_delta: int = 0,
              phones_delta: int = 0, reclaim: bool = False) -> None:
        """Add/remove capacity.

        Removal never takes frozen resources — unless ``reclaim=True``,
        which lets the free pool go *negative*: the shortfall is a recorded
        ``deficit`` that pool listeners (the ``TaskEngine``) pay down by
        refreezing running grants *down* at their next round-event boundary.
        ``free + frozen == total`` holds throughout either way.

        Both fields are validated before either is mutated (a rejected
        shrink must not leave the free/total pools inconsistent), and a
        zero-delta call is a no-op that does not fire listeners (no spurious
        re-solves).
        """
        deltas = (("logical_bundles", bundles_delta),
                  ("physical_devices", phones_delta))
        if bundles_delta == 0 and phones_delta == 0:
            return
        for field, delta in deltas:
            limit = getattr(self._total if reclaim else self._free, field)
            if delta < 0 and limit.get(grade, 0) + delta < 0:
                raise ValueError(
                    f"cannot remove {-delta} {field} of grade {grade}: "
                    f"only {limit.get(grade, 0)} "
                    f"{'total' if reclaim else 'free'}"
                )
        for field, delta in deltas:
            free = getattr(self._free, field)
            total = getattr(self._total, field)
            free[grade] = free.get(grade, 0) + delta
            total[grade] = total.get(grade, 0) + delta
        for fn in self._listeners:
            fn()


@dataclasses.dataclass
class ScheduledTask:
    task: Task
    allocation: alloc.AllocationResult
    state: TaskState = TaskState.QUEUED


class TaskScheduler:
    """Greedy scheduler (paper: maximize expected benefit under resources)."""

    def __init__(self, resources: ResourceManager):
        self.resources = resources

    def select(self, queue: TaskQueue) -> list[Task]:
        """Admit tasks in priority order while their demand fits."""
        admitted = []
        for task in queue.pending():
            demand = task.demand()
            if self.resources.fits(demand):
                self.resources.freeze(task.task_id, demand)
                queue.remove(task.task_id)
                admitted.append(task)
        return admitted


def _normalize_runtimes(runtimes) -> Callable[[Task], list[alloc.GradeRuntime]]:
    return runtimes.for_task if hasattr(runtimes, "for_task") else runtimes


def _run_tiers(tier_runners: Mapping[str, Callable[..., Any]], task: Task,
               allocation: alloc.AllocationResult, round_idx: int) -> None:
    """Execute one round's per-grade split through the tier callables."""
    for ga in allocation.per_grade:
        if ga.logical_devices:
            tier_runners["logical"](task, ga.grade, ga.logical_devices, round_idx)
        if ga.physical_devices:
            tier_runners["device"](task, ga.grade, ga.physical_devices, round_idx)


# RoundRunner contract: (task, round_idx, allocation, t) -> measured round
# duration in virtual seconds, or None to fall back to allocation.makespan.
RoundRunner = Callable[[Task, int, alloc.AllocationResult, float],
                       "float | None"]


class TaskRunner:
    """Serial reference executor for admitted tasks.

    ``runtimes`` supplies the per-grade ``GradeRuntime``s the allocator runs
    on: either a callable ``task -> list[GradeRuntime]`` or any object with a
    ``for_task`` method — e.g. a ``calibration.RuntimeCalibrator``, so the
    scheduler allocates on *measured* fleet durations instead of hand-coded
    constants.

    Round execution is either ``tier_runners`` (a map of tier name
    ("logical"/"device") to ``run(task, grade, num_devices, round_idx)``) or
    a ``round_runner`` callable ``(task, round_idx, allocation, t) ->
    duration_s | None`` shared with ``TaskEngine`` — so the serial baseline
    and the event engine execute rounds through identical code.

    With a ``clock``, each round advances the shared ``VirtualClock`` by the
    round's (measured or estimated) duration, so a serial drain reports a
    *simulated makespan* directly comparable to the event engine's.  This is
    deliberately the run-to-completion baseline: one task drains fully
    before the next starts.
    """

    def __init__(
        self,
        resources: ResourceManager,
        runtimes: Callable[[Task], list[alloc.GradeRuntime]],
        tier_runners: dict[str, Callable[..., list[Any]]] | None = None,
        *,
        round_runner: RoundRunner | None = None,
        clock: VirtualClock | None = None,
        on_round_complete: Callable[[Task, int], None] | None = None,
    ):
        if tier_runners is None and round_runner is None:
            raise ValueError("pass tier_runners or round_runner")
        self.resources = resources
        self.runtimes = _normalize_runtimes(runtimes)
        self.tier_runners = tier_runners
        self.round_runner = round_runner
        self.clock = clock
        self.on_round_complete = on_round_complete
        self.records: dict[int, ScheduledTask] = {}

    def run(self, task: Task) -> ScheduledTask:
        rts = self.runtimes(task)
        result = alloc.solve_allocation(list(task.grades), rts)
        rec = ScheduledTask(task=task, allocation=result, state=TaskState.RUNNING)
        self.records[task.task_id] = rec
        try:
            for round_idx in range(task.rounds):
                duration = None
                if self.round_runner is not None:
                    t = self.clock.now if self.clock is not None else 0.0
                    duration = self.round_runner(task, round_idx, result, t)
                else:
                    _run_tiers(self.tier_runners, task, result, round_idx)
                if self.on_round_complete is not None:
                    self.on_round_complete(task, round_idx)
                if self.clock is not None:
                    self.clock.advance(
                        duration if duration is not None else result.makespan)
            rec.state = TaskState.COMPLETED
        except Exception:
            rec.state = TaskState.FAILED
            raise
        finally:
            self.resources.release(task.task_id)
        return rec


# --------------------------------------------------------------------------- #
# Event-driven multi-task engine
# --------------------------------------------------------------------------- #
def _encode_allocation(a: alloc.AllocationResult) -> dict:
    return {"makespan": a.makespan,
            "per_grade": [dataclasses.asdict(g) for g in a.per_grade]}


def _decode_allocation(d: Mapping) -> alloc.AllocationResult:
    return alloc.AllocationResult(
        makespan=float(d["makespan"]),
        per_grade=tuple(
            alloc.GradeAllocation(
                grade=g["grade"],
                logical_devices=int(g["logical_devices"]),
                physical_devices=int(g["physical_devices"]),
                logical_time=float(g["logical_time"]),
                physical_time=float(g["physical_time"]))
            for g in d["per_grade"]))


@dataclasses.dataclass
class TaskExecution:
    """Live state of one admitted task inside ``TaskEngine``."""

    task: Task
    grant: dict[str, tuple[int, int]]  # resources currently frozen for it
    allocation: alloc.AllocationResult
    state: TaskState = TaskState.RUNNING
    rounds_done: int = 0
    started_t: float = 0.0
    submitted_t: float = 0.0
    next_event_t: float | None = None
    finished_t: float | None = None
    reallocations: int = 0  # elastic grant changes applied mid-task (both ways)
    preemptions: int = 0  # times this task was shrunk or paused by preemption
    # Reclamation marked by a higher-priority arrival / pool shrink; applied
    # (refreeze-down or pause) at this task's next round-event boundary.
    pending_shrink: dict[str, tuple[int, int]] | None = None
    # Admission cost-model audit trail (``preemption_cost_model=True``): one
    # entry per judged preemption attempt against this task —
    # {"t", "preemptor", "benefit_s", "cost_s", "preempted"}.
    preemption_decisions: list[dict] = dataclasses.field(default_factory=list)
    paused_t: float | None = None  # when the current pause began
    queued_s: float = 0.0  # total virtual time spent waiting in the queue
    running_s: float = 0.0  # total virtual time spent RUNNING (grant held)
    grant_seconds: float = 0.0  # ∫ (grant / full demand) dt while RUNNING
    accrued_t: float = 0.0  # last time the two integrals above were updated
    generation: int = 0  # invalidates stale scheduled events

    @property
    def full_grant(self) -> bool:
        return self.grant == self.task.demand()

    @property
    def queueing_delay_s(self) -> float:
        """Total virtual time spent waiting: submission→first start plus
        every preemption pause (the fairness metric preemptive scheduling
        trades against low-priority progress)."""
        return self.queued_s

    @property
    def grant_utilization(self) -> float:
        """Time-averaged fraction of the full demand actually held while
        running (1.0 = never clamped or shrunk)."""
        return self.grant_seconds / self.running_s if self.running_s > 0 else 0.0


class StrandedTasksError(RuntimeError):
    """Raised by ``TaskManager.drain(strict=True)`` when tasks are left in
    the queue (nothing fits, or ``max_cycles`` ran out)."""

    def __init__(self, stranded: list[Task], reason: str):
        self.stranded = stranded
        self.reason = reason
        super().__init__(
            f"{len(stranded)} task(s) stranded in queue ({reason}): "
            f"{[t.task_id for t in stranded]}")


class DrainResult(list):
    """``TaskManager.drain`` result: the completed ``ScheduledTask``s (list
    behavior preserved) plus explicit stranded-task reporting — a drain that
    leaves work in the queue is no longer indistinguishable from success."""

    def __init__(self, done: Iterable = (), stranded: Iterable[Task] = (),
                 reason: str | None = None):
        super().__init__(done)
        self.stranded: list[Task] = list(stranded)
        self.stranded_reason = reason if self.stranded else None


class TaskEngine:
    """Event-driven multi-task round engine on a shared ``VirtualClock``.

    Instead of draining each admitted task to completion (``TaskRunner``),
    every admitted task schedules its next *round event* on the clock; rounds
    of different tasks interleave in virtual-time order, so several tasks'
    ``RoundPlan``s time-share the same resource pool — the contention regime
    run-to-completion scheduling structurally cannot express.

    * **Admission at event boundaries** — whenever a task completes (or the
      pool changes), queued tasks are re-checked in priority order and
      admitted if a feasible grant exists.
    * **Elastic grants** — with ``elastic=True`` a task whose full demand
      does not fit may be admitted with its demand *clamped to the free
      pool* (any grant whose effective allocation is solvable); when
      resources free up — a task finishing, or ``ResourceManager.scale``
      growing the pool — running tasks top their grants back up toward the
      full request and their allocation is re-solved for the remaining
      rounds (``TaskExecution.reallocations`` counts the upgrades).
    * **Measured durations drive event timestamps** — round execution goes
      through the same ``round_runner``/``tier_runners`` contracts as
      ``TaskRunner``; a ``round_runner`` returning a measured duration (e.g.
      ``FederatedRoundOutcome.makespan_s``) times the next event, otherwise
      the allocation's estimated makespan does.  With both executors omitted
      the engine runs a pure virtual-time schedule (useful for scheduling
      studies and tests).  Passing a ``RuntimeCalibrator`` as ``runtimes``
      plus a ``duration_rng`` draws *sampled* observed runtimes per round,
      so event timestamps carry measured round-to-round jitter.
    * **Preemption** (``preemptive=True``) — a queued task whose demand does
      not fit may *reclaim* resources from strictly-lower-priority running
      tasks: victims are marked with a ``pending_shrink`` that applies at
      their next round-event boundary — the grant is refrozen *down* (the
      remaining rounds re-solved on the shrunken ``effective_grades``, and
      re-timed via ``RuntimeCalibrator.sample_for_task`` when a
      ``duration_rng`` is set) or, when clamped to zero, the task is PAUSED
      back to the queue with its round progress kept.  ``scale(...,
      reclaim=True)`` pool shrinks are paid down the same way (victims in
      ascending priority order), so the traffic controller's "dynamic
      scaling down" works even when the whole pool is frozen.  Every shrink
      and regrow counts in ``TaskExecution.reallocations``; per-task
      ``queueing_delay_s`` / ``grant_utilization`` quantify what preemption
      costs the victims.

    Share the clock with a ``DeviceFlow`` (``clock=flow.clock``) and round
    events interleave with dispatch/delivery events on one timeline.
    """

    def __init__(
        self,
        resources: ResourceManager,
        runtimes: Callable[[Task], list[alloc.GradeRuntime]],
        tier_runners: dict[str, Callable[..., list[Any]]] | None = None,
        *,
        round_runner: RoundRunner | None = None,
        clock: VirtualClock | None = None,
        elastic: bool = True,
        preemptive: bool = False,
        preemption_cost_model: bool = False,
        duration_rng=None,
        on_round_complete: Callable[[Task, int], None] | None = None,
        on_task_complete: Callable[[TaskExecution], None] | None = None,
    ):
        self.resources = resources
        self.runtimes = _normalize_runtimes(runtimes)
        self._calibrator = (runtimes if hasattr(runtimes, "sample_for_task")
                            else None)
        self.duration_rng = duration_rng
        self.tier_runners = tier_runners
        self.round_runner = round_runner
        self.clock = clock or VirtualClock()
        self.elastic = elastic
        self.preemptive = preemptive
        self.preemption_cost_model = preemption_cost_model
        self.on_round_complete = on_round_complete
        self.on_task_complete = on_task_complete
        self.queue = TaskQueue()
        self.executions: dict[int, TaskExecution] = {}
        self.completed: list[TaskExecution] = []
        self._submitted_t: dict[int, float] = {}
        # Deferred arrivals not yet on the queue: task -> arrival time.
        # Tracked (not just scheduled) so state_dict can serialize them —
        # clock callbacks themselves never survive a checkpoint.
        self._pending_arrivals: dict[int, tuple[Task, float]] = {}
        resources.subscribe(self._on_pool_change)

    # -- submission ---------------------------------------------------------
    def submit(self, task: Task, *, at: float | None = None) -> int:
        """Queue ``task``; with ``at`` the submission itself becomes a clock
        event (an *arrival*), so queueing delay is measured from then."""
        if at is not None and at > self.clock.now:
            self._pending_arrivals[task.task_id] = (task, float(at))
            self.clock.schedule(at, lambda: self._arrive(task.task_id))
            return task.task_id
        self._submitted_t.setdefault(task.task_id, self.clock.now)
        tid = self.queue.submit(task)
        self.clock.schedule(self.clock.now, self._admit)
        return tid

    def _arrive(self, tid: int) -> None:
        got = self._pending_arrivals.pop(tid, None)
        if got is not None:  # None: stale callback (restored elsewhere)
            self.submit(got[0])

    # -- allocation ---------------------------------------------------------
    def _round_runtimes(self, task: Task) -> list[alloc.GradeRuntime]:
        if self._calibrator is not None and self.duration_rng is not None:
            return self._calibrator.sample_for_task(task, self.duration_rng)
        return self.runtimes(task)

    def _solve(self, task: Task,
               grant: Mapping[str, tuple[int, int]]) -> alloc.AllocationResult:
        return alloc.solve_allocation(
            list(task.effective_grades(grant)), self._round_runtimes(task))

    def _grant_for(self, task: Task) -> dict[str, tuple[int, int]] | None:
        demand = task.demand()
        if self.resources.fits(demand):
            return demand
        if not self.elastic:
            return None
        free = self.resources.free()
        clamped = {
            # max(0): a reclaim deficit makes free components NEGATIVE — a
            # grant must never carry one (it would silently absorb the
            # deficit and oversubscribe the pool).
            g: (max(0, min(b, free.logical_bundles.get(g, 0))),
                max(0, min(p, free.physical_devices.get(g, 0))))
            for g, (b, p) in demand.items()
        }
        if not any(b or p for b, p in clamped.values()):
            return None
        return clamped

    # -- accounting ----------------------------------------------------------
    def _grant_frac(self, ex: TaskExecution) -> float:
        """Fraction of the task's full demand currently held (mean across
        the requested resource components)."""
        fracs = []
        for g, (rb, rp) in ex.task.demand().items():
            gb, gp = ex.grant.get(g, (0, 0))
            if rb:
                fracs.append(gb / rb)
            if rp:
                fracs.append(gp / rp)
        return sum(fracs) / len(fracs) if fracs else 1.0

    def _accrue(self, ex: TaskExecution) -> None:
        """Fold elapsed virtual time into the running/utilization integrals.

        Must be called *before* any grant or state change so the closing
        interval is weighted by the grant that was actually held."""
        now = self.clock.now
        dt = now - ex.accrued_t
        if ex.state is TaskState.RUNNING and dt > 0:
            ex.running_s += dt
            ex.grant_seconds += self._grant_frac(ex) * dt
        ex.accrued_t = now

    # -- event handlers ------------------------------------------------------
    def _admit(self) -> None:
        """Admit every queued task (priority order) with a feasible grant.

        PAUSED tasks ride the queue like fresh submissions (same priority
        ordering) and *resume* their existing execution — round progress,
        reallocation counts, and delay accounting carry over.  In
        ``preemptive`` mode, tasks still queued afterwards may mark
        refreeze-down shrinks on lower-priority running tasks.
        """
        now = self.clock.now
        for task in list(self.queue.pending()):
            tid = task.task_id
            paused = self.executions.get(tid)
            if paused is not None and paused.state is not TaskState.PAUSED:
                continue  # stale queue entry for a live/finished execution
            grant = self._grant_for(task)
            if grant is None:
                continue
            try:
                allocation = self._solve(task, grant)
            except ValueError:  # grant infeasible (a grade got no resources)
                continue
            self.resources.freeze(tid, grant)
            self.queue.remove(tid)
            if paused is not None:  # resume a preempted task
                ex = paused
                ex.queued_s += now - (ex.paused_t if ex.paused_t is not None
                                      else now)
                ex.paused_t = None
                ex.state = TaskState.RUNNING
                ex.grant = grant
                ex.allocation = allocation
                ex.reallocations += 1  # the regrow is a recorded re-allocation
                ex.accrued_t = now
            else:
                sub_t = self._submitted_t.get(tid, now)
                ex = TaskExecution(task=task, grant=grant,
                                   allocation=allocation, started_t=now,
                                   submitted_t=sub_t, queued_s=now - sub_t,
                                   accrued_t=now)
                self.executions[tid] = ex
            self._schedule(ex, now, self._round_event)
        if self.preemptive:
            for task in list(self.queue.pending()):
                self._mark_preemption(task)
            # A high-priority task elastically admitted on a *partial* grant
            # still deserves its remainder: reclaim it from lower-priority
            # running tasks too (its own held grant counts toward demand).
            for ex in sorted((e for e in self.executions.values()
                              if e.state is TaskState.RUNNING
                              and not e.full_grant),
                             key=lambda e: (-e.task.priority, e.task.task_id)):
                self._mark_preemption(ex.task, held=ex.grant)

    def _pending_totals(self) -> dict[str, list[int]]:
        """Per-grade reclamation already marked but not yet applied."""
        tot: dict[str, list[int]] = {}
        for ex in self.executions.values():
            if ex.state is TaskState.RUNNING and ex.pending_shrink:
                for g, (b, p) in ex.pending_shrink.items():
                    cur = tot.setdefault(g, [0, 0])
                    cur[0] += b
                    cur[1] += p
        return tot

    def _mark_shrinks(self, deficit: dict[str, list[int]],
                      victims: Iterable[TaskExecution],
                      judge: Callable[[TaskExecution,
                                       dict[str, tuple[int, int]]],
                                      bool] | None = None) -> None:
        """Spread ``deficit`` across ``victims`` as pending shrinks (applied
        at each victim's next round-event boundary).  ``judge`` — the
        preemption admission cost model — may veto a victim's marked take;
        the vetoed share stays in the deficit for later victims (or goes
        unmet: partial preemption is still progress)."""
        for ex in victims:
            if not deficit:
                return
            take: dict[str, tuple[int, int]] = {}
            for g in list(deficit):
                db, dp = deficit[g]
                gb, gp = ex.grant.get(g, (0, 0))
                pb, pp = (ex.pending_shrink or {}).get(g, (0, 0))
                tb, tp = min(gb - pb, db), min(gp - pp, dp)
                if tb or tp:
                    take[g] = (tb, tp)
            if not take:
                continue
            if judge is not None and not judge(ex, take):
                continue
            for g, (tb, tp) in take.items():
                db, dp = deficit[g]
                db, dp = db - tb, dp - tp
                if db <= 0 and dp <= 0:
                    deficit.pop(g)
                else:
                    deficit[g] = [db, dp]
            merged = dict(ex.pending_shrink or {})
            for g, (tb, tp) in take.items():
                ob, op = merged.get(g, (0, 0))
                merged[g] = (ob + tb, op + tp)
            ex.pending_shrink = merged

    def _mark_preemption(self, task: Task,
                         held: Mapping[str, tuple[int, int]] | None = None,
                         ) -> None:
        """Mark enough lower-priority running grants for reclamation that
        ``task``'s full demand would fit (what can't be covered stays
        unmarked — partial preemption is still progress under elastic
        admission).  ``held`` is the task's own current grant when it is
        already running on a partial one."""
        held = held or {}
        free = self.resources.free()
        pending = self._pending_totals()
        deficit: dict[str, list[int]] = {}
        for g, (b, p) in task.demand().items():
            hb, hp = held.get(g, (0, 0))
            db = (b - hb - free.logical_bundles.get(g, 0)
                  - pending.get(g, [0, 0])[0])
            dp = (p - hp - free.physical_devices.get(g, 0)
                  - pending.get(g, [0, 0])[1])
            if db > 0 or dp > 0:
                deficit[g] = [max(db, 0), max(dp, 0)]
        if not deficit:
            return
        victims = sorted(
            (ex for ex in self.executions.values()
             if ex.state is TaskState.RUNNING
             and ex.task.task_id != task.task_id
             and ex.task.priority < task.priority),
            key=lambda ex: (ex.task.priority, -ex.started_t, -ex.task.task_id))
        judge = (self._preemption_judge(task, victims)
                 if self.preemption_cost_model else None)
        self._mark_shrinks(deficit, victims, judge)

    # -- preemption admission cost model -------------------------------------
    def _preemption_judge(self, task: Task, victims: list[TaskExecution]):
        """Admission cost model (``preemption_cost_model=True``): preempt a
        victim only when the preemptor's priority-weighted benefit exceeds
        the victim's priority-weighted re-timed lost work.

        *Benefit* — the wait the preemptor avoids: without preemption it
        queues until the earliest natural completion among the candidate
        victims, weighted by its priority.  *Cost* — what the victim loses:
        its remaining rounds re-timed on the shrunken grant (solved through
        the allocator), or, for a full pause, the span it sits paused (the
        preemptor's own estimated runtime), weighted by the victim's
        priority.  Every judged attempt is logged on the victim's
        ``TaskExecution.preemption_decisions``.
        """
        waits = [max(ex.task.rounds - ex.rounds_done, 0)
                 * ex.allocation.makespan for ex in victims]
        wait_s = min((w for w in waits if w > 0), default=0.0)
        benefit = max(task.priority, 1) * wait_s

        def judge(ex: TaskExecution,
                  take: dict[str, tuple[int, int]]) -> bool:
            cost = self._shrink_cost_s(task, ex, take)
            ok = benefit > cost
            ex.preemption_decisions.append({
                "t": self.clock.now, "preemptor": task.task_id,
                "benefit_s": benefit, "cost_s": cost, "preempted": ok})
            return ok

        return judge

    def _shrink_cost_s(self, task: Task, ex: TaskExecution,
                       take: dict[str, tuple[int, int]]) -> float:
        """Victim's re-timed lost work if ``take`` is reclaimed from it."""
        remaining = max(ex.task.rounds - ex.rounds_done, 0)
        old_span = ex.allocation.makespan
        pending = ex.pending_shrink or {}
        new_grant = {
            g: (max(0, b - pending.get(g, (0, 0))[0]
                    - take.get(g, (0, 0))[0]),
                max(0, p - pending.get(g, (0, 0))[1]
                    - take.get(g, (0, 0))[1]))
            for g, (b, p) in ex.grant.items()
        }
        weight = max(ex.task.priority, 1)
        if any(b or p for b, p in new_grant.values()):
            try:
                new_span = self._solve(ex.task, new_grant).makespan
                return weight * remaining * max(new_span - old_span, 0.0)
            except ValueError:
                pass  # infeasible shrink — the victim would pause instead
        # Full pause: the victim's lost work is the span it sits paused,
        # i.e. the preemptor's own estimated runtime on its full demand.
        try:
            pre_span = self._solve(task, task.demand()).makespan
        except ValueError:
            pre_span = old_span
        return weight * task.rounds * pre_span

    def _reclaim_deficit(self) -> None:
        """Mark shrinks that pay down a ``scale(reclaim=True)`` pool deficit
        (negative free).  Victims in ascending priority order — capacity
        loss is not a priority contest, but the cheapest tasks shed first."""
        free = self.resources.free()
        pending = self._pending_totals()
        deficit: dict[str, list[int]] = {}
        for pool, i in ((free.logical_bundles, 0), (free.physical_devices, 1)):
            for g, v in pool.items():
                short = -v - pending.get(g, [0, 0])[i]
                if short > 0:
                    deficit.setdefault(g, [0, 0])[i] = short
        if not deficit:
            return
        victims = sorted(
            (ex for ex in self.executions.values()
             if ex.state is TaskState.RUNNING),
            key=lambda ex: (ex.task.priority, -ex.started_t, -ex.task.task_id))
        self._mark_shrinks(deficit, victims)

    def _rebalance(self) -> None:
        """Top running tasks' grants back up toward their full demand and
        re-solve their allocations (elastic re-allocation).  The in-flight
        round keeps its already-scheduled completion time; the new split
        applies from the next round."""
        if not self.elastic:
            return
        queued_prio = max((t.priority for t in self.queue.pending()),
                          default=None)
        running = sorted(
            (ex for ex in self.executions.values()
             if ex.state is TaskState.RUNNING and not ex.full_grant),
            key=lambda ex: (-ex.task.priority, ex.task.task_id))
        for ex in running:
            if ex.pending_shrink:
                continue  # marked for reclamation; don't fight the preemption
            if (self.preemptive and queued_prio is not None
                    and queued_prio > ex.task.priority):
                # A higher-priority task is waiting: freed resources belong
                # to it, not to lower-priority top-ups (priority inversion).
                continue
            free = self.resources.free()
            demand = ex.task.demand()
            upgraded = {
                # max(): with a reclaim deficit the free pool can be
                # negative — top-ups never shrink a grant (that only happens
                # at round boundaries via pending_shrink).
                g: (max(ex.grant[g][0],
                        min(rb, ex.grant[g][0]
                            + free.logical_bundles.get(g, 0))),
                    max(ex.grant[g][1],
                        min(rp, ex.grant[g][1]
                            + free.physical_devices.get(g, 0))))
                for g, (rb, rp) in demand.items()
            }
            if upgraded == ex.grant:
                continue
            try:
                allocation = self._solve(ex.task, upgraded)
            except ValueError:
                continue
            self.resources.refreeze(ex.task.task_id, upgraded)
            self._accrue(ex)
            ex.grant = upgraded
            ex.allocation = allocation
            ex.reallocations += 1

    def _on_pool_change(self) -> None:
        # Deferred to an event so mid-round scale() calls take effect at the
        # next event boundary, like every other engine state change.
        self.clock.schedule(self.clock.now, self._pool_change_event)

    def _pool_change_event(self) -> None:
        self._reclaim_deficit()
        self._rebalance()
        self._admit()

    def _schedule(self, ex: TaskExecution, t: float, handler) -> None:
        ex.generation += 1
        gen = ex.generation
        ex.next_event_t = t
        tid = ex.task.task_id
        self.clock.schedule(t, lambda: handler(tid, gen))

    def _apply_shrink(self, ex: TaskExecution) -> None:
        """Refreeze a victim's grant *down* at its round-event boundary.

        The grant loses the marked reclamation; the remaining rounds are
        re-solved (and re-timed, when sampling) on the shrunken
        ``effective_grades``.  A grant clamped to zero — or one the
        allocator can't solve (a grade lost both tiers while still owing
        devices) — pauses the task back to the queue instead, progress kept.
        """
        shrink = ex.pending_shrink or {}
        ex.pending_shrink = None
        new_grant = {
            g: (max(0, b - shrink.get(g, (0, 0))[0]),
                max(0, p - shrink.get(g, (0, 0))[1]))
            for g, (b, p) in ex.grant.items()
        }
        self._accrue(ex)
        if not any(b or p for b, p in new_grant.values()):
            self._pause(ex)
            return
        try:
            allocation = self._solve(ex.task, new_grant)
            self.resources.refreeze(ex.task.task_id, new_grant)
        except ValueError:
            # Infeasible shrink (or a pool deficit deeper than the marked
            # reclamation): shed the whole grant instead of wedging.
            self._pause(ex)
            return
        ex.grant = new_grant
        ex.allocation = allocation
        ex.reallocations += 1
        ex.preemptions += 1

    def _pause(self, ex: TaskExecution) -> None:
        """Preempt ``ex`` entirely: release its resources and send the task
        back to the queue.  ``rounds_done`` is kept — a resumed task picks
        up where it was paused, it does not restart."""
        self._accrue(ex)
        self.resources.release(ex.task.task_id)
        ex.state = TaskState.PAUSED
        ex.paused_t = self.clock.now
        ex.next_event_t = None
        ex.generation += 1  # invalidate any scheduled round event
        ex.preemptions += 1
        self.queue.submit(ex.task)

    def _round_event(self, tid: int, gen: int) -> None:
        ex = self.executions.get(tid)
        if ex is None or ex.generation != gen or ex.state is not TaskState.RUNNING:
            return  # stale event (task rescheduled/failed meanwhile)
        if ex.pending_shrink:
            # Round-event boundary: apply the marked reclamation before the
            # next round runs, then let the freed capacity admit/top-up the
            # preemptor at this same timestamp.  Any deficit the shrink
            # could not fully cover is re-marked on the remaining victims.
            self._apply_shrink(ex)
            self._reclaim_deficit()
            self._rebalance()
            self._admit()
            if ex.state is not TaskState.RUNNING:
                return  # paused to the queue; no round to run
        round_idx = ex.rounds_done
        t = self.clock.now
        duration = None
        try:
            if self.round_runner is not None:
                duration = self.round_runner(ex.task, round_idx, ex.allocation, t)
            elif self.tier_runners is not None:
                _run_tiers(self.tier_runners, ex.task, ex.allocation, round_idx)
        except Exception:
            self._accrue(ex)
            ex.state = TaskState.FAILED
            ex.next_event_t = None
            self.resources.release(tid)
            raise
        if duration is None:
            duration = ex.allocation.makespan
        ex.rounds_done += 1
        if self.on_round_complete is not None:
            self.on_round_complete(ex.task, round_idx)
        if ex.rounds_done >= ex.task.rounds:
            # The task occupies its resources until the last round's slowest
            # device reports — release at t + duration, not at dispatch.
            self._schedule(ex, t + duration, self._completion_event)
        else:
            self._schedule(ex, t + duration, self._round_event)

    def _completion_event(self, tid: int, gen: int) -> None:
        ex = self.executions.get(tid)
        if ex is None or ex.generation != gen or ex.state is not TaskState.RUNNING:
            return
        self._accrue(ex)
        ex.state = TaskState.COMPLETED
        ex.finished_t = self.clock.now
        ex.next_event_t = None
        self.resources.release(tid)
        self.completed.append(ex)
        if self.on_task_complete is not None:
            self.on_task_complete(ex)
        # Event boundary: freed resources may fit queued tasks or top up
        # running elastic grants (or settle a leftover reclaim deficit).
        self._reclaim_deficit()
        self._rebalance()
        self._admit()

    # -- driving -------------------------------------------------------------
    def run_until(self, t_end: float = float("inf")) -> list[TaskExecution]:
        """Drive the clock; returns tasks completed so far."""
        self.clock.run_until(t_end)
        return self.completed

    def drain(self) -> DrainResult:
        """Run until the event heap empties; reports stranded tasks."""
        self.run_until()
        stranded = list(self.queue.pending())
        return DrainResult(self.completed, stranded,
                           "nothing-fits" if stranded else None)

    @property
    def makespan(self) -> float:
        """Virtual time of the latest task completion so far."""
        return max((ex.finished_t for ex in self.completed
                    if ex.finished_t is not None), default=0.0)

    # -- checkpointing --------------------------------------------------------
    def state_dict(self, deviceflow=None, *, fleets=None,
                   services=None) -> dict:
        """Resume-safe engine state (JSON-friendly; no Task objects).

        Captures the queue order, every live execution's grant/progress and
        its next scheduled event time, and the clock.  Tasks themselves are
        *not* serialized — like ``DeviceFlow.load_state_dict`` after
        ``register_task``, the caller re-supplies the ``Task`` objects on
        restore.

        ``deviceflow`` (optional) embeds the message plane's shelves and
        dispatcher state in the same snapshot — one unified engine state
        covering scheduled round events AND in-flight arrivals (including
        columnar ``ArrivalBatch`` segments, whose update buffers are
        materialized to host arrays by ``Shelf.state_dict``).

        ``fleets`` (optional, ``{name: DeviceFleet}`` — e.g.
        ``HybridSimulation.fleets``) folds every fleet's per-device RNG
        counters into the same snapshot, and ``services`` (optional,
        ``{task_id: AggregationService}``) folds in aggregation state
        including streaming partial sums.  Together this makes ONE manifest
        the atomic unit of a running simulation — engine events, message
        plane, fleet randomness, and half-reduced rounds snapshot/restore
        as a unit instead of as separate ``extra`` entries (the
        coordinator/worker contract of ``runtime.workers``: workers hold
        no authoritative state, so this manifest IS the simulation).
        """
        def enc(ex: TaskExecution) -> dict:
            return {
                "task_id": ex.task.task_id,
                "grant": {g: list(bp) for g, bp in ex.grant.items()},
                "state": ex.state.value,
                "rounds_done": ex.rounds_done,
                "started_t": ex.started_t,
                "submitted_t": ex.submitted_t,
                "next_event_t": ex.next_event_t,
                "finished_t": ex.finished_t,
                "reallocations": ex.reallocations,
                "preemptions": ex.preemptions,
                "pending_shrink": (
                    None if ex.pending_shrink is None
                    else {g: list(bp) for g, bp in ex.pending_shrink.items()}),
                "preemption_decisions": [dict(d)
                                         for d in ex.preemption_decisions],
                "paused_t": ex.paused_t,
                "queued_s": ex.queued_s,
                "running_s": ex.running_s,
                "grant_seconds": ex.grant_seconds,
                "accrued_t": ex.accrued_t,
                # The solved allocation is saved verbatim: restoring it
                # (instead of re-solving) keeps a sampling engine's
                # duration_rng stream aligned with the uninterrupted run.
                "allocation": _encode_allocation(ex.allocation),
            }

        state = {
            "now": self.clock.now,
            "queue": [t.task_id for t in self.queue.pending()],
            "submitted_t": {int(tid): t
                            for tid, t in self._submitted_t.items()},
            "arrivals": {int(tid): t
                         for tid, (_, t) in self._pending_arrivals.items()},
            "executions": [enc(ex) for ex in self.executions.values()],
        }
        if self.duration_rng is not None:
            # PCG64-style state dicts are plain ints/strings — JSON-safe —
            # so a restored engine draws the exact same sampled runtimes.
            state["duration_rng"] = self.duration_rng.bit_generator.state
        if deviceflow is not None:
            state["deviceflow"] = deviceflow.state_dict()
        if fleets is not None:
            state["fleets"] = {str(name): fleet.state_dict()
                               for name, fleet in dict(fleets).items()}
        if services is not None:
            state["aggregation"] = {int(tid): svc.state_dict()
                                    for tid, svc in dict(services).items()}
        return state

    def load_state_dict(self, state: Mapping,
                        tasks: Iterable[Task],
                        deviceflow=None, *, fleets=None,
                        services=None) -> None:
        """Rebuild engine state from ``state_dict`` output.

        ``tasks`` supplies the Task objects referenced by the saved state
        (any iterable; matched by ``task_id``).  Requires a fresh engine on
        a fresh ``ResourceManager`` (grants are re-frozen here).  Pending
        round events are rescheduled at their saved timestamps and each
        execution's solved allocation is restored *verbatim* (legacy states
        without one are re-solved), so a restored run continues on the
        exact same virtual timeline — a ``RuntimeCalibrator`` runtimes
        provider must still have its observations reloaded first
        (``RuntimeCalibrator.load_state_dict``), and a ``duration_rng``
        engine additionally restores the saved generator state so resumed
        sampled event times match the uninterrupted run draw for draw.
        PAUSED (preempted) executions restore un-frozen and un-scheduled;
        they sit in the restored queue and resume at the next event
        boundary that fits them, exactly like the live engine.

        ``deviceflow`` (optional) receives the embedded message-plane state
        when the snapshot carries one (``state_dict(deviceflow=...)``) —
        call ``register_task`` for every task first so dispatchers rebind.
        ``fleets`` / ``services`` likewise receive the fleet RNG counters
        and aggregation partials the one-manifest snapshot carries (matched
        by name / task id; missing sections are ignored for legacy states).
        """
        by_id = {t.task_id: t for t in tasks}
        if deviceflow is not None and "deviceflow" in state:
            deviceflow.load_state_dict(state["deviceflow"])
        if fleets is not None:
            for name, fstate in state.get("fleets", {}).items():
                fleet = dict(fleets).get(name)
                if fleet is not None:
                    fleet.load_state_dict(fstate)
        if services is not None:
            for tid, sstate in state.get("aggregation", {}).items():
                svc = dict(services).get(int(tid))
                if svc is not None:
                    svc.load_state_dict(sstate)
        self.clock.now = float(state["now"])
        if self.duration_rng is not None and "duration_rng" in state:
            self.duration_rng.bit_generator.state = state["duration_rng"]
        for tid in state["queue"]:
            self.queue.submit(by_id[int(tid)])
        for tid, t in state.get("submitted_t", {}).items():
            self._submitted_t[int(tid)] = float(t)
        for tid, t in state.get("arrivals", {}).items():
            # Re-schedule deferred arrivals saved before they fired.
            self.submit(by_id[int(tid)], at=float(t))
        for enc in state["executions"]:
            tid = int(enc["task_id"])
            task = by_id[tid]
            grant = {g: (int(bp[0]), int(bp[1]))
                     for g, bp in enc["grant"].items()}
            pending = enc.get("pending_shrink")
            ex = TaskExecution(
                task=task, grant=grant,
                allocation=(_decode_allocation(enc["allocation"])
                            if enc.get("allocation") is not None
                            else self._solve(task, grant)),
                state=TaskState(enc["state"]),
                rounds_done=int(enc["rounds_done"]),
                started_t=float(enc["started_t"]),
                submitted_t=float(enc.get("submitted_t", enc["started_t"])),
                finished_t=(None if enc["finished_t"] is None
                            else float(enc["finished_t"])),
                reallocations=int(enc["reallocations"]),
                preemptions=int(enc.get("preemptions", 0)),
                pending_shrink=(
                    None if pending is None
                    else {g: (int(bp[0]), int(bp[1]))
                          for g, bp in pending.items()}),
                preemption_decisions=[
                    dict(d) for d in enc.get("preemption_decisions", [])],
                paused_t=(None if enc.get("paused_t") is None
                          else float(enc["paused_t"])),
                queued_s=float(enc.get("queued_s", 0.0)),
                running_s=float(enc.get("running_s", 0.0)),
                grant_seconds=float(enc.get("grant_seconds", 0.0)),
                accrued_t=float(enc.get("accrued_t", self.clock.now)),
            )
            self.executions[tid] = ex
            if ex.state is TaskState.RUNNING:
                self.resources.freeze(tid, grant)
                if enc["next_event_t"] is not None:
                    t = float(enc["next_event_t"])
                    handler = (self._completion_event
                               if ex.rounds_done >= task.rounds
                               else self._round_event)
                    self._schedule(ex, t, handler)
            elif ex.state is TaskState.COMPLETED:
                self.completed.append(ex)
        self.clock.schedule(self.clock.now, self._admit)


class TaskManager:
    """Facade: queue + scheduler + runner (paper's *Task Manager* service).

    ``drain`` is the serial run-to-completion path — kept as the measured
    baseline; use a ``TaskEngine`` on a shared clock for event-driven
    multi-task rounds.
    """

    def __init__(self, resources: ResourceManager, runner: TaskRunner):
        self.queue = TaskQueue()
        self.scheduler = TaskScheduler(resources)
        self.runner = runner

    def submit(self, task: Task) -> int:
        return self.queue.submit(task)

    def step(self) -> list[ScheduledTask]:
        """One scheduling cycle: admit what fits, run to completion."""
        done = []
        for task in self.scheduler.select(self.queue):
            done.append(self.runner.run(task))
        return done

    def drain(self, max_cycles: int = 1000, *, strict: bool = False
              ) -> DrainResult:
        """Run scheduling cycles until the queue empties.

        Previously a non-empty queue at exit (nothing fits, or
        ``max_cycles`` exhausted) looked identical to success; the result
        now reports ``stranded`` tasks and ``stranded_reason`` explicitly,
        and ``strict=True`` raises ``StrandedTasksError`` instead.
        """
        done: list[ScheduledTask] = []
        reason = None
        for _ in range(max_cycles):
            if not len(self.queue):
                break
            got = self.step()
            if not got:  # nothing fits — resources exhausted for now
                reason = "nothing-fits"
                break
            done.extend(got)
        else:
            if len(self.queue):
                reason = "max-cycles-exhausted"
        out = DrainResult(done, self.queue.pending(), reason)
        if strict and out.stranded:
            raise StrandedTasksError(out.stranded, reason or "unknown")
        return out
