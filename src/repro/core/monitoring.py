"""Platform monitoring (paper §III.C: "users can monitor various
computational metrics, edge device performance, and updates to cloud
services throughout the task execution process via the GUI").

Headless equivalent: a structured metrics bus.  Every platform component
emits ``MetricEvent``s; sinks subscribe (the tests use an in-memory sink; a
deployment would attach a TSDB writer).  ``TaskMonitor`` aggregates the
per-task view the paper's GUI shows: round progress, tier split, device
telemetry, shelf depth, aggregation history.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class MetricEvent:
    t: float  # virtual time (wall time only outside the simulation domain)
    source: str  # "logical" | "device" | "deviceflow" | "cloud" | "runner"
    task_id: int
    kind: str  # e.g. "round_start", "telemetry", "dispatch", "aggregation"
    values: dict[str, Any]


class MetricsBus:
    """Metrics fan-out with an *injected* clock.

    Simulation components must stamp events on the simulated timeline, so
    the bus never reads wall time itself (simcheck R002): pass a zero-arg
    ``clock`` callable, or build one from a ``VirtualClock`` with
    :meth:`on_virtual_clock` (``MetricsBus.on_virtual_clock(engine.clock)``
    when driven from ``TaskEngine``/``DeviceFlow``).  Explicitly wall-clock
    producers (checkpoint manifests, dryrun timing) stamp their own ``t``
    and go through :meth:`emit` directly.
    """

    def __init__(self, clock: Callable[[], float] | None = None) -> None:
        self._sinks: list[Callable[[MetricEvent], None]] = []
        self.clock = clock

    @classmethod
    def on_virtual_clock(cls, clock) -> "MetricsBus":
        """A bus stamping events from a ``VirtualClock`` (``clock.now``)."""
        return cls(clock=lambda: clock.now)

    def subscribe(self, sink: Callable[[MetricEvent], None]) -> None:
        self._sinks.append(sink)

    def emit(self, event: MetricEvent) -> None:
        for s in self._sinks:
            s(event)

    def emit_now(self, source: str, task_id: int, kind: str, **values) -> None:
        if self.clock is None:
            raise RuntimeError(
                "MetricsBus.emit_now needs an injected clock — construct "
                "with MetricsBus(clock=...) or "
                "MetricsBus.on_virtual_clock(engine.clock); simulation "
                "metrics must not read wall time (simcheck R002)")
        self.emit(MetricEvent(self.clock(), source, task_id, kind, values))


class InMemorySink:
    """Test/GUI sink: per-(task, kind) ring buffers + latest snapshot."""

    def __init__(self, maxlen: int = 10000):
        self.events: dict[tuple[int, str], collections.deque] = (
            collections.defaultdict(lambda: collections.deque(maxlen=maxlen)))

    def __call__(self, e: MetricEvent) -> None:
        self.events[(e.task_id, e.kind)].append(e)

    def latest(self, task_id: int, kind: str) -> MetricEvent | None:
        buf = self.events.get((task_id, kind))
        return buf[-1] if buf else None

    def series(self, task_id: int, kind: str, key: str) -> list:
        return [e.values.get(key) for e in self.events.get((task_id, kind), ())]


class TaskMonitor:
    """The per-task dashboard state the paper's GUI renders."""

    def __init__(self, bus: MetricsBus, task_id: int):
        self.task_id = task_id
        self.sink = InMemorySink()
        bus.subscribe(lambda e: self.sink(e) if e.task_id == task_id else None)

    def summary(self) -> dict:
        rounds = self.sink.series(self.task_id, "round_complete", "round_idx")
        aggs = self.sink.series(self.task_id, "aggregation", "num_clients")
        power = self.sink.series(self.task_id, "telemetry", "power_mah")
        shelf = self.sink.latest(self.task_id, "dispatch")
        return {
            "rounds_completed": len(rounds),
            "aggregations": len(aggs),
            "clients_aggregated": int(sum(a or 0 for a in aggs)),
            "mean_device_power_mah": (
                sum(power) / len(power) if power else None),
            "shelf_pending": (shelf.values.get("pending") if shelf else None),
        }

    def to_json(self) -> str:
        return json.dumps(self.summary())


def wire_aggregation_service(bus: MetricsBus, svc, task_id: int) -> None:
    """Attach a cloud-service aggregation feed to the bus."""
    prev = svc.on_aggregate

    def hook(ev):
        bus.emit(MetricEvent(ev.t, "cloud", task_id, "aggregation", {
            "round_idx": ev.round_idx,
            "num_clients": ev.num_clients,
            "num_samples": ev.num_samples,
        }))
        if prev is not None:
            prev(ev)

    svc.on_aggregate = hook
