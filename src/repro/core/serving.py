"""Slot-based continuous-batching decode engine over a KV-cache arena.

The fixed-batch server (``launch.serve.BatchedServer``) couples every
request's latency to its batch-mates: a request that lands just after a
batch fires waits a full batch-fill interval, and off-peak traffic strands
sub-batch residuals.  Continuous batching decouples them (ROADMAP item 2):

* ``init_arena`` allocates a fixed-capacity KV-cache *arena* — per layer
  ``(slots, max_len, kv, head_dim)`` — plus one per-slot ``lengths`` counter.
  A slot IS a request's cache residency for its whole lifetime.
* ``arena_prefill`` runs the full-sequence forward for newly admitted
  prompts and scatters their K/V rows into freed slots.  The call is padded
  to a single static shape; out-of-bounds slot ids mark padding rows whose
  writes drop (``kernels.decode_attention.ops`` slot paths).
* ``arena_decode`` advances every active slot one token in ONE fused jitted
  dispatch: per-slot RoPE positions, per-slot ragged cache writes, and
  ragged-``lengths`` attention via ``kernels.decode_attention``.  Slots at
  different sequence positions decode together — that is the whole trick.
* ``ContinuousBatchingEngine`` is the host-side slot manager: finished
  requests retire their slot at the iteration end, queued requests prefill
  into freed slots at the next iteration boundary.  Scheduling never needs
  token *values* (greedy decode to a fixed budget), so the decode loop runs
  sync-free: token arrays are stacked and fetched once, at report time.
* ``ContinuousServer`` adapts the engine to DeviceFlow's delivery callback
  on the shared ``VirtualClock``.  Service time comes from a deterministic
  ``ServeCostModel`` charged identically to both serving modes, so latency
  comparisons measure *scheduling*, not host wall-clock noise.

Stale-KV safety: a reused slot's rows beyond the new prompt keep the retired
request's K/V, but the slot's length counter is reset at prefill and only
ever covers rows the current occupant wrote — attention masks the rest
(tested against a zero-filled cache in ``tests/test_kernels.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizers import hot_path
from repro.configs.base import ModelConfig
from repro.distribution import ctx as shard_ctx
from repro.kernels.decode_attention.ops import (
    decode_attention,
    scatter_decode_token,
    scatter_prefill_rows,
    tuned_block_k,
)
from repro.models import moe as moe_lib
from repro.models.layers import (
    _attend,
    _project_qkv,
    embed_apply,
    mlp_apply,
    rmsnorm,
    rope,
    unembed_apply,
)
from repro.models.registry import get_model

__all__ = [
    "ServeCostModel",
    "RequestRecord",
    "IterationStats",
    "ServingReport",
    "ContinuousBatchingEngine",
    "ContinuousServer",
    "init_arena",
    "arena_prefill",
    "arena_decode",
]


# --------------------------------------------------------------------------- #
# Virtual-time cost model + request accounting
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ServeCostModel:
    """Deterministic virtual-time cost of one serving dispatch.

    A prefill over ``m`` prompts costs ``prefill_base_s + m *
    prefill_per_req_s``; one decode iteration over ``n`` active sequences
    costs ``decode_base_s + n * decode_per_slot_s``.  Charged from the same
    model to the fixed-batch and continuous servers, so their virtual-time
    latency difference is purely the batching policy.
    """

    prefill_base_s: float = 4e-3
    prefill_per_req_s: float = 1e-3
    decode_base_s: float = 1.5e-3
    decode_per_slot_s: float = 2.5e-4

    def prefill_s(self, n_requests: int) -> float:
        if n_requests <= 0:
            return 0.0
        return self.prefill_base_s + n_requests * self.prefill_per_req_s

    def decode_s(self, n_active: int) -> float:
        if n_active <= 0:
            return 0.0
        return self.decode_base_s + n_active * self.decode_per_slot_s


@dataclasses.dataclass
class RequestRecord:
    """One request's serving timeline + greedy-decoded tokens."""

    request_id: int
    arrival_t: float
    prompt: np.ndarray | None = None
    start_t: float | None = None  # admission (prefill begins)
    first_token_t: float | None = None  # prefill completes → first token
    finish_t: float | None = None
    slot: int | None = None
    decoded: int = 0  # decode-step tokens produced (excludes prefill token)
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def latency_s(self) -> float | None:
        return None if self.finish_t is None else self.finish_t - self.arrival_t

    @property
    def ttft_s(self) -> float | None:
        return (None if self.first_token_t is None
                else self.first_token_t - self.arrival_t)


@dataclasses.dataclass(frozen=True)
class IterationStats:
    """One engine iteration: when it ran, what it admitted/decoded."""

    t: float
    duration_s: float
    admitted: int
    n_active: int  # slots decoding this iteration (occupancy)
    queue_depth: int  # requests still waiting after admission


@dataclasses.dataclass
class ServingReport:
    """Latency/goodput rollup over a set of ``RequestRecord``s."""

    records: list[RequestRecord]
    horizon_s: float  # virtual span the run covered (goodput denominator)

    def finished(self) -> list[RequestRecord]:
        return [r for r in self.records if r.finish_t is not None]

    def _pct(self, values: list[float], q: float) -> float:
        return float(np.percentile(np.asarray(values), q)) if values else 0.0

    @property
    def p50_latency_s(self) -> float:
        return self._pct([r.latency_s for r in self.finished()], 50.0)

    @property
    def p99_latency_s(self) -> float:
        return self._pct([r.latency_s for r in self.finished()], 99.0)

    @property
    def p50_ttft_s(self) -> float:
        return self._pct([r.ttft_s for r in self.records
                          if r.first_token_t is not None], 50.0)

    @property
    def p99_ttft_s(self) -> float:
        return self._pct([r.ttft_s for r in self.records
                          if r.first_token_t is not None], 99.0)

    def goodput_rps(self, slo_s: float) -> float:
        """Finished requests meeting the latency SLO, per virtual second."""
        ok = sum(1 for r in self.finished() if r.latency_s <= slo_s)
        return ok / self.horizon_s if self.horizon_s > 0 else 0.0

    def summary(self, slo_s: float) -> dict:
        fin = self.finished()
        return {
            "requests": len(self.records),
            "finished": len(fin),
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "p50_ttft_s": self.p50_ttft_s,
            "p99_ttft_s": self.p99_ttft_s,
            "goodput_rps": self.goodput_rps(slo_s),
            "slo_s": slo_s,
            "slo_attainment": (sum(1 for r in fin if r.latency_s <= slo_s)
                               / len(fin)) if fin else 0.0,
            "horizon_s": self.horizon_s,
        }


# --------------------------------------------------------------------------- #
# KV arena + fused jitted arena ops
# --------------------------------------------------------------------------- #
def init_arena(cfg: ModelConfig, slots: int, max_len: int) -> dict:
    """Fixed-capacity KV arena: per-layer ``(slots, max_len, kv, hd)`` caches
    plus one per-slot ``lengths`` counter (0 = empty/retired slot)."""
    dt = jnp.dtype(cfg.dtype)

    def one():
        shape = (slots, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    if cfg.scan_layers:
        kv = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one())
    else:
        kv = [one() for _ in range(cfg.num_layers)]
    return {"kv": kv, "lengths": jnp.zeros((slots,), jnp.int32)}


def _mlp_or_moe(lp, hn, cfg):
    if cfg.num_experts:
        impl = shard_ctx.moe_impl() or moe_lib.moe_apply
        m, _ = impl(lp["moe"], hn, cfg)
        return m
    return mlp_apply(lp["mlp"], hn, cfg)


def _run_layers(params, x, cfg, run_layer, kv):
    """Drive ``run_layer(lp, h, kc, vc) -> (h, kc, vc)`` across the stack in
    the params' layout (``lax.scan`` over stacked layers, or a Python loop),
    threading each layer's arena K/V through and re-stacking the updates."""
    if cfg.scan_layers:
        def body(h, xs):
            lp, layer_kv = xs
            h, kc, vc = run_layer(lp, h, layer_kv["k"], layer_kv["v"])
            return h, {"k": kc, "v": vc}
        x, kv = jax.lax.scan(body, x, (params["layers"], kv))
    else:
        kv = list(kv)
        for i, (lp, layer_kv) in enumerate(zip(params["layers"], kv)):
            x, kc, vc = run_layer(lp, x, layer_kv["k"], layer_kv["v"])
            kv[i] = {"k": kc, "v": vc}
    return x, kv


def arena_prefill(params, tokens: jax.Array, slot_ids: jax.Array,
                  arena: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Prefill admitted prompts into their arena slots.

    ``tokens`` is ``(m, s) int32`` and ``slot_ids`` ``(m,) int32``; rows with
    ``slot_ids[i] >= slots`` are padding (computed then dropped), so the jit
    sees ONE static shape however many requests joined this iteration.
    Returns ``(first greedy token (m,) int32, arena')`` — the prefill's
    last-position logits already yield each request's first token.
    """
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def run_layer(lp, h, kc, vc):
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o = _attend(q, k, v, cfg, causal=True)
        h = h + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        kc = scatter_prefill_rows(kc, k.astype(kc.dtype), slot_ids)
        vc = scatter_prefill_rows(vc, v.astype(vc.dtype), slot_ids)
        return h + _mlp_or_moe(lp, hn, cfg), kc, vc

    x, kv = _run_layers(params, x, cfg, run_layer, arena["kv"])
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x[:, -1])
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    lengths = arena["lengths"].at[slot_ids].set(s, mode="drop")
    return tok, {"kv": kv, "lengths": lengths}


def arena_decode(params, tok: jax.Array, active: jax.Array, arena: dict,
                 cfg: ModelConfig, *, attn_impl: str = "auto",
                 block_k: int | None = None) -> tuple[jax.Array, dict]:
    """One fused decode iteration across every arena slot.

    ``tok`` is ``(slots,) int32`` — each slot's last token; ``active`` is
    ``(slots,) bool``.  Active slots write K/V at their own cache position
    and attend over their own ragged length; inactive slots neither write
    nor advance (their held token is passed through).  Per-row math is
    identical to the fixed-batch ``layers.attention_decode`` path, which is
    what makes continuous batching token-identical to the fixed reference.
    """
    slots = tok.shape[0]
    lengths = arena["lengths"]
    kv = arena["kv"]
    max_len = (kv["k"].shape[2] if cfg.scan_layers else kv[0]["k"].shape[1])
    if block_k is None:
        block_k = tuned_block_k(max_len, head_dim=cfg.head_dim)
    x = embed_apply(params["embed"], tok[:, None])  # (slots, 1, d)
    pos2d = lengths[:, None]  # per-slot RoPE position for the new token
    write_pos = jnp.where(active, lengths, max_len)  # OOB → write drops
    lens_att = lengths + active.astype(jnp.int32)

    def run_layer(lp, h, kc, vc):
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg)  # (slots, 1, heads, hd)
        q = rope(q, pos2d, cfg.rope_theta)
        k = rope(k, pos2d, cfg.rope_theta)
        kc = scatter_decode_token(kc, k[:, 0].astype(kc.dtype), write_pos)
        vc = scatter_decode_token(vc, v[:, 0].astype(vc.dtype), write_pos)
        o = decode_attention(q[:, 0], kc, vc, lens_att,
                             impl=attn_impl, block_k=block_k)
        h = h + o.reshape(slots, 1, -1) @ lp["attn"]["wo"]
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        return h + _mlp_or_moe(lp, hn, cfg), kc, vc

    x, kv = _run_layers(params, x, cfg, run_layer, kv)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x[:, 0])
    nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    nxt = jnp.where(active, nxt, tok)
    return nxt, {"kv": kv, "lengths": lengths + active.astype(jnp.int32)}


# --------------------------------------------------------------------------- #
# Engine: host-side slot manager
# --------------------------------------------------------------------------- #
class ContinuousBatchingEngine:
    """Iteration-at-a-time continuous batching over the KV arena.

    Each ``step(t)``: (1) admit queued requests into free slots and prefill
    them (one padded jitted call), (2) run one fused ``arena_decode`` over
    all active slots, (3) retire slots whose request hit its decode budget.
    The loop never syncs token values — greedy decode to a fixed budget
    makes scheduling token-value-independent, so device token arrays are
    stacked and fetched once at report time (``simulate_only=True`` skips
    model compute entirely for million-request capacity studies).
    """

    def __init__(self, cfg: ModelConfig | None = None, *, slots: int,
                 prompt_len: int, decode_tokens: int, max_len: int | None = None,
                 seed: int = 0, cost_model: ServeCostModel | None = None,
                 attn_impl: str = "auto", block_k: int | None = None,
                 simulate_only: bool = False, params: Any = None):
        if slots < 1:
            raise ValueError("need at least one slot")
        if decode_tokens < 1:
            raise ValueError("decode_tokens must be >= 1")
        self.cfg = cfg
        self.slots = slots
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.max_len = max_len or (prompt_len + decode_tokens + 1)
        self.cost = cost_model or ServeCostModel()
        self.simulate_only = simulate_only
        if not simulate_only:
            if cfg is None:
                raise ValueError("cfg required unless simulate_only=True")
            api = get_model(cfg)
            if api.prefill is None or api.decode_step is None:
                raise ValueError(f"family {cfg.family!r} has no serving path")
            self.params = (params if params is not None
                           else api.init(jax.random.PRNGKey(seed), cfg))
            self.arena = init_arena(cfg, slots, self.max_len)
            self._tok = jnp.zeros((slots,), jnp.int32)
            self._prefill = jax.jit(
                lambda p, t, sids, ar: arena_prefill(p, t, sids, ar, cfg))
            self._decode = jax.jit(
                lambda p, tok, act, ar: arena_decode(
                    p, tok, act, ar, cfg, attn_impl=attn_impl,
                    block_k=block_k))
            # Jitted so the drop-mode sentinel is a traced constant; the
            # eager .at[].set ships it as a runtime scalar, an implicit
            # h2d that would trip the @hot_path transfer guard.
            self._scatter_tok = jax.jit(
                lambda tok, sids, first: tok.at[sids].set(
                    first, mode="drop"))
        self.queue: collections.deque[RequestRecord] = collections.deque()
        self.records: list[RequestRecord] = []
        self.slot_owner: list[RequestRecord | None] = [None] * slots
        self._free = list(range(slots))
        heapq.heapify(self._free)
        self.busy_until = 0.0
        self.iterations: list[IterationStats] = []
        # Deferred token materialization: (kind, owners, device (slots,) i32).
        self._events: list[tuple[str, list, jax.Array]] = []

    # -- request intake ------------------------------------------------------
    def submit(self, request_id: int, prompt: np.ndarray | None,
               t: float) -> RequestRecord:
        if not self.simulate_only:
            prompt = np.asarray(prompt, np.int32)[: self.prompt_len]
        rec = RequestRecord(request_id=request_id, arrival_t=t, prompt=prompt)
        self.queue.append(rec)
        self.records.append(rec)
        return rec

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(o is not None for o in self.slot_owner)

    @property
    def n_active(self) -> int:
        return sum(o is not None for o in self.slot_owner)

    # -- one iteration -------------------------------------------------------
    @hot_path
    def step(self, t: float) -> float:
        """Run one iteration starting at virtual time ``t``; returns its
        duration (cost-model virtual seconds).

        ``@hot_path``: the decode loop must never host-sync per iteration —
        token materialization is deferred to :meth:`_materialize_tokens`
        (one sync for the whole run), and every h2d transfer here is an
        explicit ``jnp.asarray``.
        """
        admitted: list[RequestRecord] = []
        while self.queue and self._free:
            slot = heapq.heappop(self._free)
            rec = self.queue.popleft()
            rec.slot = slot
            rec.start_t = t
            self.slot_owner[slot] = rec
            admitted.append(rec)
        dur = 0.0
        if admitted:
            dur += self.cost.prefill_s(len(admitted))
            for rec in admitted:
                rec.first_token_t = t + dur
            if not self.simulate_only:
                toks = np.zeros((self.slots, self.prompt_len), np.int32)
                sids = np.full((self.slots,), self.slots, np.int32)
                for i, rec in enumerate(admitted):
                    toks[i, : len(rec.prompt)] = rec.prompt
                    sids[i] = rec.slot
                sids_dev = jnp.asarray(sids)
                first, self.arena = self._prefill(
                    self.params, jnp.asarray(toks), sids_dev, self.arena)
                self._tok = self._scatter_tok(self._tok, sids_dev, first)
                self._events.append(("prefill", list(admitted), first))
        active = [o is not None for o in self.slot_owner]
        n_active = sum(active)
        if n_active:
            dur += self.cost.decode_s(n_active)
            if not self.simulate_only:
                # Host-built bool mask, then one explicit dtype-preserving
                # device_put (an eager dtype conversion would count as an
                # implicit transfer under the guard).
                act_host = np.fromiter(active, np.bool_, count=self.slots)
                nxt, self.arena = self._decode(
                    self.params, self._tok,
                    jnp.asarray(act_host), self.arena)
                self._tok = nxt
                self._events.append(("decode", list(self.slot_owner), nxt))
            end = t + dur
            for s, rec in enumerate(self.slot_owner):
                if rec is None:
                    continue
                rec.decoded += 1
                if rec.decoded >= self.decode_tokens:
                    rec.finish_t = end
                    self.slot_owner[s] = None
                    heapq.heappush(self._free, s)
        self.iterations.append(IterationStats(
            t=t, duration_s=dur, admitted=len(admitted),
            n_active=n_active, queue_depth=len(self.queue)))
        return dur

    # -- results -------------------------------------------------------------
    def _materialize_tokens(self) -> None:
        """One host sync for ALL buffered per-iteration token arrays."""
        if not self._events:
            return
        host = np.asarray(jnp.stack([ev[2] for ev in self._events]))
        for (kind, owners, _), row in zip(self._events, host):
            if kind == "prefill":
                for i, rec in enumerate(owners):
                    rec.tokens.append(int(row[i]))
            else:
                for s, rec in enumerate(owners):
                    if rec is not None:
                        rec.tokens.append(int(row[s]))
        self._events.clear()

    def report(self, *, horizon_s: float | None = None) -> ServingReport:
        self._materialize_tokens()
        if horizon_s is None:
            horizon_s = max((r.finish_t for r in self.records
                             if r.finish_t is not None), default=0.0)
        return ServingReport(records=list(self.records), horizon_s=horizon_s)


# --------------------------------------------------------------------------- #
# VirtualClock adapter
# --------------------------------------------------------------------------- #
class ContinuousServer:
    """DeviceFlow delivery callback driving an engine on the shared clock.

    Arrivals enqueue into the engine; a self-rescheduling *tick* event runs
    one engine iteration whenever work is pending, so queued requests join
    at exactly the next iteration boundary and the engine idles only when
    the queue and every slot are empty.  Use as ``DeviceFlow(server)`` with
    ``server = ContinuousServer(engine, flow.clock)``.
    """

    def __init__(self, engine: ContinuousBatchingEngine, clock, *,
                 prompt_of: Callable[[Any], np.ndarray] | None = None):
        self.engine = engine
        self.clock = clock
        self.prompt_of = prompt_of
        self._armed = False

    def _prompt(self, message) -> np.ndarray | None:
        if self.engine.simulate_only:
            return None
        if self.prompt_of is not None:
            return self.prompt_of(message)
        payload = message.payload
        if hasattr(payload, "materialize"):  # UpdateHandle
            payload = payload.materialize()
        return np.asarray(payload["tokens"])

    def __call__(self, d) -> None:
        msgs = (d.batch.messages() if getattr(d, "batch", None) is not None
                else [d.message])
        for m in msgs:
            self.engine.submit(m.device_id, self._prompt(m), d.t)
        self._kick(d.t)

    def _kick(self, t: float) -> None:
        if self._armed:
            return
        self._armed = True
        self.clock.schedule(max(t, self.engine.busy_until), self._tick)

    def _tick(self) -> None:
        t = self.clock.now
        dur = self.engine.step(t)
        self.engine.busy_until = t + dur
        if self.engine.has_work:
            self.clock.schedule(self.engine.busy_until, self._tick)
        else:
            self._armed = False
