"""Cloud-side aggregation service (paper §II.A, §VI.C).

Implements the device-cloud collaborative objective
``min_w F(w) = sum_k p_k F_k(w; D_k)`` with FedAvg/FedProx aggregation, plus
the two aggregation *triggers* the paper evaluates (Fig. 9):

* **sample threshold** — aggregate as soon as the accumulated number of client
  samples reaches a threshold;
* **scheduled** — aggregate at fixed virtual-time intervals with whatever has
  arrived.

Beyond-paper: an **async buffered (FedBuff-style)** mode with staleness
discounting — the natural straggler-mitigation extension once DeviceFlow
exposes arrival times.

**Zero-copy aggregation.**  When every pending payload is an
``updates.UpdateHandle`` (the round engine's device-resident stacked buffers),
``aggregate`` never materializes host pytrees: ``fused_fedavg_delta`` groups
the handles by buffer, scatters the staleness-discounted weights into one
per-row weight vector per buffer, and runs a single fused weighted reduction
over each stacked buffer (the ``kernels/fed_reduce`` Pallas kernel on TPU, a
fused ``tensordot`` elsewhere).  The per-message host path below
(``weighted_average``/``fedavg_delta``) is kept as the correctness reference
and still serves mixed/host payloads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import Delivery, Message
from repro.core.updates import UpdateHandle
from repro.kernels.fed_reduce.ops import fed_reduce

Params = Any  # pytree


def weighted_average(updates: list[Params], weights: list[float]) -> Params:
    """FedAvg: ``sum_k p_k w_k`` with ``p_k`` normalized weights."""
    if not updates:
        raise ValueError("no updates to aggregate")
    tot = float(sum(weights))
    if tot <= 0:
        raise ValueError("weights must sum to a positive value")
    ws = [w / tot for w in weights]

    def avg(*leaves):
        out = leaves[0] * ws[0]
        for leaf, w in zip(leaves[1:], ws[1:]):
            out = out + leaf * w
        return out

    return jax.tree.map(avg, *updates)


def fedavg_delta(global_params: Params, updates: list[Params],
                 weights: list[float], *, server_lr: float = 1.0) -> Params:
    """Server update: ``w <- w + lr * avg_k p_k (w_k - w)`` (equivalent to
    FedAvg at lr=1 but supports server-side learning rates)."""
    avg = weighted_average(updates, weights)
    return jax.tree.map(lambda g, a: g + server_lr * (a - g), global_params, avg)


def _fused_reduce_apply(global_params: Params, buf_leaves: tuple,
                        wvecs: tuple, inv_total: jax.Array, lr: jax.Array,
                        *, impl: str) -> Params:
    # buf_leaves: one tuple of (rows, size) matrices per buffer, leaf order
    # matching global_params.  Keeping operands 2-D end-to-end is what lets
    # every weighted row-reduction lower to a BLAS/MXU matmul.
    weighted_sum = None  # list of (size,) f32 unnormalized weighted sums
    for leaves2d, w in zip(buf_leaves, wvecs):
        parts = [fed_reduce(leaf, w, impl=impl) for leaf in leaves2d]
        weighted_sum = parts if weighted_sum is None else [
            a + b for a, b in zip(weighted_sum, parts)]
    g_leaves, treedef = jax.tree.flatten(global_params)
    out = [(g + lr * (s.reshape(g.shape) * inv_total - g)).astype(g.dtype)
           for g, s in zip(g_leaves, weighted_sum)]
    return jax.tree_util.tree_unflatten(treedef, out)


# One XLA dispatch per aggregation: every buffer's per-leaf weighted
# row-reduction, the cross-buffer sum, and the server update fuse into a
# single jitted call (eager per-leaf dispatch overhead would otherwise
# dominate).  Two jit instances so donation is a call-site choice, not a
# retrace: the donated variant invalidates the *old* global-params buffer,
# reusing it for the new round's parameters (zero allocation churn between
# rounds).
_FUSED_REDUCE_APPLY = jax.jit(_fused_reduce_apply, static_argnames=("impl",))
_FUSED_REDUCE_APPLY_DONATED = jax.jit(
    _fused_reduce_apply, static_argnames=("impl",), donate_argnums=(0,))


def handles_align(global_params: Params, payloads: list) -> bool:
    """True when every payload is an ``UpdateHandle`` whose buffer layout
    matches ``global_params`` (same treedef, same leaf shapes) — the
    precondition for the fused zero-copy aggregation path."""
    if not payloads or not all(isinstance(p, UpdateHandle) for p in payloads):
        return False
    leaves, treedef = jax.tree.flatten(global_params)
    shapes = [tuple(g.shape) for g in leaves]
    seen: set[int] = set()
    for p in payloads:
        if id(p.buffer) in seen:
            continue
        seen.add(id(p.buffer))
        if p.buffer.treedef != treedef or p.buffer.shapes != shapes:
            return False
    return True


def fused_fedavg_delta(
    global_params: Params,
    handles: list[UpdateHandle],
    weights: list[float],
    *,
    server_lr: float = 1.0,
    impl: str = "auto",
    donate: bool = False,
) -> Params:
    """``fedavg_delta`` over device-resident handle payloads, fused.

    Groups ``handles`` by their stacked update buffer, scatters ``weights``
    into one per-row f32 weight vector per buffer (rows not referenced weigh
    zero), reduces each buffer with one ``fed_reduce`` weighted row-sum per
    leaf (the Pallas kernel on TPU), sums the per-buffer partials, and
    applies the server update — without ever materializing a per-device host
    pytree, in one XLA dispatch.  Matches the host ``fedavg_delta``
    reference within accumulation tolerance.

    ``donate=True`` additionally donates the old global-params buffer to the
    server update (the caller's previous reference is invalidated).
    """
    if not handles:
        raise ValueError("no updates to aggregate")
    if not handles_align(global_params, handles):
        raise ValueError(
            "handle buffers do not align with global_params (treedef/shape "
            "mismatch) — materialize and use fedavg_delta instead")
    return _fused_fedavg_delta_validated(
        global_params, handles, weights, server_lr=server_lr, impl=impl,
        donate=donate)


def _fused_fedavg_delta_validated(global_params, handles, weights, *,
                                  server_lr, impl, donate):
    # Core of fused_fedavg_delta, after handles_align: the aggregation
    # service calls this directly so the O(pending) alignment pass runs
    # once per aggregation, not twice.
    if not handles:
        raise ValueError("no updates to aggregate")
    total = float(sum(weights))
    if total <= 0:
        raise ValueError("weights must sum to a positive value")
    groups: dict[int, tuple[Any, np.ndarray]] = {}
    for h, w in zip(handles, weights):
        key = id(h.buffer)
        if key not in groups:
            groups[key] = (h.buffer, np.zeros(h.buffer.num_rows, np.float32))
        groups[key][1][h.row] += w
    buf_leaves = tuple(tuple(buf.leaves2d) for buf, _ in groups.values())
    wvecs = tuple(jnp.asarray(wvec) for _, wvec in groups.values())
    apply = _FUSED_REDUCE_APPLY_DONATED if donate else _FUSED_REDUCE_APPLY
    return apply(global_params, buf_leaves, wvecs,
                 jnp.float32(1.0 / total), jnp.float32(server_lr), impl=impl)


@dataclasses.dataclass
class AggregationEvent:
    t: float
    round_idx: int
    num_clients: int
    num_samples: int
    global_params: Params
    # Mean shelf-queuing delay of the aggregated updates: delivery time minus
    # ``Message.created_t`` (stamped by DeviceFlow at submit from the fleet's
    # sampled round durations).  Zero only when updates arrive instantly.
    mean_latency_s: float = 0.0


class AggregationService:
    """The paper's *Cloud Service*: consumes DeviceFlow deliveries, fires
    aggregation on a trigger, tracks history for the GUI/metrics stream."""

    def __init__(
        self,
        global_params: Params,
        *,
        trigger: "Trigger",
        server_lr: float = 1.0,
        staleness_discount: Callable[[int], float] | None = None,
        on_aggregate: Callable[[AggregationEvent], None] | None = None,
        reduce_impl: str = "auto",
        donate_params: bool = False,
    ):
        self.global_params = global_params
        self.trigger = trigger
        self.server_lr = server_lr
        self.staleness_discount = staleness_discount
        self.on_aggregate = on_aggregate
        # Zero-copy path knobs: ``reduce_impl`` selects the fed_reduce
        # backend for handle payloads; ``donate_params`` recycles the old
        # global-params buffer each aggregation.  Donation invalidates the
        # params stored on the *previous* AggregationEvent — leave it off
        # when history params are read back (e.g. per-round eval curves).
        self.reduce_impl = reduce_impl
        self.donate_params = donate_params
        self._pending: list[Message] = []
        self._pending_samples = 0
        self._pending_latency = 0.0
        self.round_idx = 0
        self.history: list[AggregationEvent] = []

    # DeviceFlow delivery callback -----------------------------------------
    def __call__(self, d: Delivery) -> None:
        self._pending.append(d.message)
        self._pending_samples += d.message.num_samples
        self._pending_latency += max(0.0, d.t - d.message.created_t)
        if self.trigger.should_fire(self, d.t):
            self.aggregate(d.t)

    def tick(self, t: float) -> None:
        """Clock hook for scheduled triggers."""
        if self.trigger.should_fire_on_tick(self, t):
            self.aggregate(t)

    def aggregate(self, t: float) -> AggregationEvent | None:
        if not self._pending:
            return None
        updates, weights = [], []
        for m in self._pending:
            w = float(m.num_samples)
            if self.staleness_discount is not None:
                staleness = max(0, self.round_idx - m.round_idx)
                w *= self.staleness_discount(staleness)
            updates.append(m.payload)
            weights.append(w)
        if sum(weights) <= 0.0:
            # An aggressive staleness_discount can zero every pending weight;
            # fall back to uniform weights instead of crashing the delivery
            # callback mid-flow.
            weights = [1.0] * len(updates)
        if handles_align(self.global_params, updates):
            # Zero-copy path: one fused weighted reduction per stacked
            # buffer, no host materialization.
            self.global_params = _fused_fedavg_delta_validated(
                self.global_params, updates, weights,
                server_lr=self.server_lr, impl=self.reduce_impl,
                donate=self.donate_params)
        else:
            # Host reference path (serves host payloads; stray handles in a
            # mixed batch are materialized rather than crashing mid-flow).
            updates = [u.materialize() if isinstance(u, UpdateHandle) else u
                       for u in updates]
            self.global_params = fedavg_delta(
                self.global_params, updates, weights,
                server_lr=self.server_lr)
        ev = AggregationEvent(
            t=t,
            round_idx=self.round_idx,
            num_clients=len(self._pending),
            num_samples=self._pending_samples,
            global_params=self.global_params,
            mean_latency_s=self._pending_latency / len(self._pending),
        )
        self.history.append(ev)
        self._pending = []
        self._pending_samples = 0
        self._pending_latency = 0.0
        self.round_idx += 1
        if self.on_aggregate is not None:
            self.on_aggregate(ev)
        return ev

    @property
    def pending_samples(self) -> int:
        return self._pending_samples

    @property
    def pending_clients(self) -> int:
        return len(self._pending)


class Trigger:
    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return False

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        return False


@dataclasses.dataclass
class SampleThresholdTrigger(Trigger):
    """Aggregate when accumulated edge training samples reach a threshold."""

    threshold: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_samples >= self.threshold


@dataclasses.dataclass
class ClientCountTrigger(Trigger):
    """Aggregate when K client updates have arrived (FedBuff buffer size)."""

    k: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_clients >= self.k


@dataclasses.dataclass
class ScheduledTrigger(Trigger):
    """Aggregate every ``period`` virtual seconds (paper: scheduled times)."""

    period: float
    _last: float = 0.0

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        if t - self._last >= self.period - 1e-9 and svc.pending_clients > 0:
            # Snap forward on the fixed grid rather than re-anchoring to the
            # tick's arrival time — aggregation stays on the paper's
            # "scheduled times" instead of drifting by the tick jitter.  The
            # max(1, ...) guards the fire-condition tolerance: a tick landing
            # a hair below the grid point must still advance the grid.
            self._last += self.period * max(1, math.floor(
                (t - self._last + 1e-9) / self.period))
            return True
        return False


def polynomial_staleness(alpha: float = 0.5) -> Callable[[int], float]:
    """FedBuff-style ``(1 + s)^-alpha`` staleness discount."""
    return lambda s: (1.0 + s) ** (-alpha)
