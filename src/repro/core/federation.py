"""Cloud-side aggregation service (paper §II.A, §VI.C).

Implements the device-cloud collaborative objective
``min_w F(w) = sum_k p_k F_k(w; D_k)`` with FedAvg/FedProx aggregation, plus
the two aggregation *triggers* the paper evaluates (Fig. 9):

* **sample threshold** — aggregate as soon as the accumulated number of client
  samples reaches a threshold;
* **scheduled** — aggregate at fixed virtual-time intervals with whatever has
  arrived.

Beyond-paper: an **async buffered (FedBuff-style)** mode with staleness
discounting — the natural straggler-mitigation extension once DeviceFlow
exposes arrival times.

**Zero-copy aggregation.**  When every pending payload is an
``updates.UpdateHandle`` (the round engine's device-resident stacked buffers),
``aggregate`` never materializes host pytrees: ``fused_fedavg_delta`` groups
the handles by buffer, scatters the staleness-discounted weights into one
per-row weight vector per buffer, and runs a single fused weighted reduction
over each stacked buffer (the ``kernels/fed_reduce`` Pallas kernel on TPU, a
fused ``tensordot`` elsewhere).  The per-message host path below
(``weighted_average``/``fedavg_delta``) is kept as the correctness reference
and still serves mixed/host payloads.

**Streaming chunk aggregation** (``streaming=True``): instead of holding
every pending message until the trigger fires and reducing in one shot, the
service accumulates per-buffer weight vectors as handle deliveries land and
fires a ``fed_reduce`` *partial* the moment a cohort chunk's ``UpdateBuffer``
is fully referenced — FedBuff-style running weighted partial sums, dispatched
asynchronously so reduction overlaps the remaining chunks' compute instead of
serializing after the round.  At trigger time the partials (plus any
incomplete chunks and host-path stragglers) fold into the same server-delta
update the one-shot fused path applies, matching ``fused_fedavg_delta``
numerics to ~1e-6 across chunk orderings and staleness weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import (
    ArrivalBatch,
    Delivery,
    Message,
    decode_arrival_batches,
    encode_arrival_batches,
)
from repro.core.updates import UpdateHandle, materialize_handles
from repro.kernels.fed_reduce.ops import fed_reduce

Params = Any  # pytree


def _dev_f32(v) -> jax.Array:
    """Explicit device_put of a host f32 scalar.  A bare ``jnp.float32``/
    numpy scalar reaching a jit is an *implicit* h2d transfer and trips the
    hot-path ``transfer_guard("disallow")`` (analysis.sanitizers)."""
    return jax.device_put(np.float32(v))


def weighted_average(updates: list[Params], weights: list[float]) -> Params:
    """FedAvg: ``sum_k p_k w_k`` with ``p_k`` normalized weights."""
    if not updates:
        raise ValueError("no updates to aggregate")
    tot = float(sum(weights))
    if tot <= 0:
        raise ValueError("weights must sum to a positive value")
    ws = [w / tot for w in weights]

    def avg(*leaves):
        out = leaves[0] * ws[0]
        for leaf, w in zip(leaves[1:], ws[1:]):
            out = out + leaf * w
        return out

    return jax.tree.map(avg, *updates)


def fedavg_delta(global_params: Params, updates: list[Params],
                 weights: list[float], *, server_lr: float = 1.0) -> Params:
    """Server update: ``w <- w + lr * avg_k p_k (w_k - w)`` (equivalent to
    FedAvg at lr=1 but supports server-side learning rates)."""
    avg = weighted_average(updates, weights)
    return jax.tree.map(lambda g, a: g + server_lr * (a - g), global_params, avg)


def _fused_reduce_apply(global_params: Params, buf_leaves: tuple,
                        buf_scales: tuple, wvecs: tuple,
                        inv_total: jax.Array, lr: jax.Array,
                        *, impl: str, mesh=None) -> Params:
    # buf_leaves: one tuple of (rows, size) matrices per buffer, leaf order
    # matching global_params.  Keeping operands 2-D end-to-end is what lets
    # every weighted row-reduction lower to a BLAS/MXU matmul.  ``mesh``
    # (static, a jax.sharding.Mesh) shards every row-reduction over its
    # ``dp`` axis — see ``kernels.fed_reduce.ops.fed_reduce``.
    # ``buf_scales``: per buffer, either None (f32 wire) or one (rows,) f32
    # scale column per leaf (int8 wire) — fed_reduce folds the scales into
    # the weight vector, so quantized buffers reduce without ever
    # materializing a dense f32 copy of the stack.
    weighted_sum = None  # list of (size,) f32 unnormalized weighted sums
    for leaves2d, scales, w in zip(buf_leaves, buf_scales, wvecs):
        parts = [fed_reduce(leaf, w,
                            scales=None if scales is None else scales[k],
                            impl=impl, mesh=mesh)
                 for k, leaf in enumerate(leaves2d)]
        weighted_sum = parts if weighted_sum is None else [
            a + b for a, b in zip(weighted_sum, parts)]
    g_leaves, treedef = jax.tree.flatten(global_params)
    out = [(g + lr * (s.reshape(g.shape) * inv_total - g)).astype(g.dtype)
           for g, s in zip(g_leaves, weighted_sum)]
    return jax.tree_util.tree_unflatten(treedef, out)


# One XLA dispatch per aggregation: every buffer's per-leaf weighted
# row-reduction, the cross-buffer sum, and the server update fuse into a
# single jitted call (eager per-leaf dispatch overhead would otherwise
# dominate).  Two jit instances so donation is a call-site choice, not a
# retrace: the donated variant invalidates the *old* global-params buffer,
# reusing it for the new round's parameters (zero allocation churn between
# rounds).
_FUSED_REDUCE_APPLY = jax.jit(
    _fused_reduce_apply, static_argnames=("impl", "mesh"))
_FUSED_REDUCE_APPLY_DONATED = jax.jit(
    _fused_reduce_apply, static_argnames=("impl", "mesh"),
    donate_argnums=(0,), keep_unused=True)


def _partial_reduce(buf_leaves: tuple, buf_scales, wvec: jax.Array,
                    *, impl: str, mesh=None) -> tuple:
    # One chunk's streaming partial: the weighted row-sum of every leaf of
    # one UpdateBuffer (``buf_scales`` carries the int8 wire's per-leaf
    # scale columns, or None).  Dispatched the moment the chunk fully
    # lands, so the reduction runs (async) while later chunks are still
    # computing.
    return tuple(
        fed_reduce(leaf, wvec,
                   scales=None if buf_scales is None else buf_scales[k],
                   impl=impl, mesh=mesh)
        for k, leaf in enumerate(buf_leaves))


_PARTIAL_REDUCE = jax.jit(_partial_reduce, static_argnames=("impl", "mesh"))


def _apply_weighted_sum(global_params: Params, sum_leaves: tuple,
                        inv_total: jax.Array, lr: jax.Array) -> Params:
    # Trigger-time fold of the streaming partials: same server update the
    # one-shot fused path applies, over pre-reduced weighted sums.
    g_leaves, treedef = jax.tree.flatten(global_params)
    out = [(g + lr * (s.reshape(g.shape) * inv_total - g)).astype(g.dtype)
           for g, s in zip(g_leaves, sum_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


_APPLY_WEIGHTED_SUM = jax.jit(_apply_weighted_sum)
_APPLY_WEIGHTED_SUM_DONATED = jax.jit(
    _apply_weighted_sum, donate_argnums=(0,), keep_unused=True)


@dataclasses.dataclass
class _StreamChunk:
    """Accumulation state for one in-flight cohort chunk (one UpdateBuffer)."""

    buffer: Any  # updates.UpdateBuffer
    weights: np.ndarray  # per-row staleness-discounted weights (f32)
    hits: np.ndarray  # per-row delivery counts (uniform-weight fallback)
    clients: int = 0
    filled: int = 0  # distinct rows seen — O(1) completion test

    def alive(self) -> bool:
        """False once the buffer's arrays were invalidated (e.g. donated by
        ``HybridSimulation(recycle_buffers=True)`` into a later round)."""
        if getattr(type(self.buffer), "__simdc_donated__", False):
            # Sanitizer-poisoned buffer (analysis.sanitizers.poison_donated):
            # leaf access would raise UseAfterDonateError, and by definition
            # a donated buffer is dead.  Probe the class marker instead.
            return False
        return not any(
            getattr(leaf, "is_deleted", lambda: False)()
            for leaf in self.buffer.leaves2d)


def _scales_of(buf) -> "tuple | None":
    """A buffer's per-leaf scale columns as a hashable-by-structure tuple
    (None for the f32 wire) — the ``buf_scales`` pytree fed to the fused
    reduce jits.  Quantized and f32 buffers may mix freely in one
    aggregation; each reduces with its own wire format."""
    scales = getattr(buf, "scales", None)
    return None if scales is None else tuple(scales)


def handles_align(global_params: Params, payloads: list) -> bool:
    """True when every payload is an ``UpdateHandle`` whose buffer layout
    matches ``global_params`` (same treedef, same leaf shapes) — the
    precondition for the fused zero-copy aggregation path.  Quantized
    (``wire="int8"``) buffers align exactly like f32 ones: ``shapes`` always
    describes what rows *materialize* to, and the fused path dequantizes
    in-reduction via the buffer's scale columns."""
    if not payloads or not all(isinstance(p, UpdateHandle) for p in payloads):
        return False
    leaves, treedef = jax.tree.flatten(global_params)
    shapes = [tuple(g.shape) for g in leaves]
    seen: set[int] = set()
    for p in payloads:
        if id(p.buffer) in seen:
            continue
        seen.add(id(p.buffer))
        if p.buffer.treedef != treedef or p.buffer.shapes != shapes:
            return False
    return True


def fused_fedavg_delta(
    global_params: Params,
    handles: list[UpdateHandle],
    weights: list[float],
    *,
    server_lr: float = 1.0,
    impl: str = "auto",
    donate: bool = False,
    mesh=None,
) -> Params:
    """``fedavg_delta`` over device-resident handle payloads, fused.

    Groups ``handles`` by their stacked update buffer, scatters ``weights``
    into one per-row f32 weight vector per buffer (rows not referenced weigh
    zero), reduces each buffer with one ``fed_reduce`` weighted row-sum per
    leaf (the Pallas kernel on TPU), sums the per-buffer partials, and
    applies the server update — without ever materializing a per-device host
    pytree, in one XLA dispatch.  Matches the host ``fedavg_delta``
    reference within accumulation tolerance.

    ``donate=True`` additionally donates the old global-params buffer to the
    server update (the caller's previous reference is invalidated).
    """
    if not handles:
        raise ValueError("no updates to aggregate")
    if not handles_align(global_params, handles):
        raise ValueError(
            "handle buffers do not align with global_params (treedef/shape "
            "mismatch) — materialize and use fedavg_delta instead")
    return _fused_fedavg_delta_validated(
        global_params, handles, weights, server_lr=server_lr, impl=impl,
        donate=donate, mesh=mesh)


def _fused_fedavg_delta_validated(global_params, handles, weights, *,
                                  server_lr, impl, donate, mesh=None):
    # Core of fused_fedavg_delta, after handles_align: the aggregation
    # service calls this directly so the O(pending) alignment pass runs
    # once per aggregation, not twice.
    if not handles:
        raise ValueError("no updates to aggregate")
    total = float(sum(weights))
    if total <= 0:
        raise ValueError("weights must sum to a positive value")
    groups: dict[int, tuple[Any, np.ndarray]] = {}
    for h, w in zip(handles, weights):
        key = id(h.buffer)
        if key not in groups:
            groups[key] = (h.buffer, np.zeros(h.buffer.num_rows, np.float32))
        groups[key][1][h.row] += w
    buf_leaves = tuple(tuple(buf.leaves2d) for buf, _ in groups.values())
    buf_scales = tuple(_scales_of(buf) for buf, _ in groups.values())
    wvecs = tuple(jnp.asarray(wvec) for _, wvec in groups.values())
    apply = _FUSED_REDUCE_APPLY_DONATED if donate else _FUSED_REDUCE_APPLY
    return apply(global_params, buf_leaves, buf_scales, wvecs,
                 _dev_f32(1.0 / total), _dev_f32(server_lr), impl=impl,
                 mesh=mesh)


@dataclasses.dataclass
class AggregationEvent:
    t: float
    round_idx: int
    num_clients: int
    num_samples: int
    global_params: Params
    # Mean shelf-queuing delay of the aggregated updates: delivery time minus
    # ``Message.created_t`` (stamped by DeviceFlow at submit from the fleet's
    # sampled round durations).  Zero only when updates arrive instantly.
    mean_latency_s: float = 0.0


class AggregationService:
    """The paper's *Cloud Service*: consumes DeviceFlow deliveries, fires
    aggregation on a trigger, tracks history for the GUI/metrics stream."""

    def __init__(
        self,
        global_params: Params,
        *,
        trigger: "Trigger",
        server_lr: float = 1.0,
        staleness_discount: Callable[[int], float] | None = None,
        on_aggregate: Callable[[AggregationEvent], None] | None = None,
        reduce_impl: str = "auto",
        donate_params: bool = False,
        streaming: bool = False,
        mesh=None,
    ):
        self.global_params = global_params
        self.trigger = trigger
        self.server_lr = server_lr
        self.staleness_discount = staleness_discount
        self.on_aggregate = on_aggregate
        # Zero-copy path knobs: ``reduce_impl`` selects the fed_reduce
        # backend for handle payloads; ``donate_params`` recycles the old
        # global-params buffer each aggregation.  Donation invalidates the
        # params stored on the *previous* AggregationEvent — leave it off
        # when history params are read back (e.g. per-round eval curves).
        self.reduce_impl = reduce_impl
        self.donate_params = donate_params
        # Streaming chunk aggregation (module docstring): aligned handle
        # payloads accumulate per-buffer weight vectors; each chunk's
        # fed_reduce partial fires as soon as its buffer is fully referenced.
        # Non-handle payloads still take the pending-message path and are
        # folded in at trigger time.
        self.streaming = streaming
        # ``mesh`` (jax.sharding.Mesh with a ``dp`` axis, or None) shards the
        # fused weighted row-reductions across fleet shards — one round's
        # aggregation spans multiple devices/hosts.
        self.mesh = mesh
        self._pending: list[Message] = []
        # Columnar plane: pending ArrivalBatches ride whole (struct-of-array
        # columns, shared buffer) until the trigger fires — no per-row
        # objects.  ``_pending_batch_rows`` keeps client counts O(1).
        self._pending_batches: list[ArrivalBatch] = []
        self._pending_batch_rows = 0
        self._pending_samples = 0
        self._pending_latency = 0.0
        self._chunks: dict[int, _StreamChunk] = {}  # open, by id(buffer)
        self._fired: list[_StreamChunk] = []  # kept for uniform fallback
        self._partials: list[tuple[tuple, float]] = []  # (leaves, weight sum)
        self._stream_clients = 0
        self._g_sig = None  # cached (treedef, shapes) of global_params
        self.round_idx = 0
        self.history: list[AggregationEvent] = []

    # DeviceFlow delivery callback -----------------------------------------
    def __call__(self, d: Delivery) -> None:
        if d.batch is not None:
            self._on_batch(d.t, d.batch)
        else:
            m = d.message
            self._pending_samples += m.num_samples
            # created_t is None for messages delivered without passing
            # through a DeviceFlow Sorter (direct service calls): no
            # queuing, zero latency.
            if m.created_t is not None:
                self._pending_latency += max(0.0, d.t - m.created_t)
            if (self.streaming and isinstance(m.payload, UpdateHandle)
                    and self._stream_aligned(m.payload.buffer)):
                self._stream_add(m)
            else:
                self._pending.append(m)
        if self.trigger.should_fire(self, d.t):
            self.aggregate(d.t)

    def _on_batch(self, t: float, b: ArrivalBatch) -> None:
        """Columnar intake: one ArrivalBatch slice, all accounting
        vectorized — the 10^6-messages/s path never touches per-row
        objects."""
        if b.buffer is None:
            raise ValueError(
                "AggregationService needs buffer-backed ArrivalBatches "
                "(metadata-only batches carry no model update)")
        self._pending_samples += b.total_samples
        stamped = ~np.isnan(b.created_t)
        if stamped.any():
            self._pending_latency += float(
                np.clip(t - b.created_t[stamped], 0.0, None).sum())
        if self.streaming and self._stream_aligned(b.buffer):
            self._stream_add_batch(b)
        else:
            self._pending_batches.append(b)
            self._pending_batch_rows += b.n

    # -- streaming accumulation --------------------------------------------
    def _weight(self, m: Message) -> float:
        w = float(m.num_samples)
        if self.staleness_discount is not None:
            w *= self.staleness_discount(max(0, self.round_idx - m.round_idx))
        return w

    def _weights_of(self, b: ArrivalBatch) -> np.ndarray:
        """Per-row aggregation weights of a batch (vectorized ``_weight``)."""
        w = b.num_samples.astype(np.float32)
        if self.staleness_discount is not None:
            w = w * np.float32(self.staleness_discount(
                max(0, self.round_idx - b.round_idx)))
        return w

    def _stream_aligned(self, buffer) -> bool:
        sig = self._g_sig
        if sig is None:
            leaves, treedef = jax.tree.flatten(self.global_params)
            sig = self._g_sig = (treedef, [tuple(g.shape) for g in leaves])
        return buffer.treedef == sig[0] and buffer.shapes == sig[1]

    def _stream_add(self, m: Message) -> None:
        h = m.payload
        key = id(h.buffer)
        ch = self._chunks.get(key)
        if ch is None:
            ch = self._chunks[key] = _StreamChunk(
                h.buffer,
                np.zeros(h.buffer.num_rows, np.float32),
                np.zeros(h.buffer.num_rows, np.float32))
        ch.weights[h.row] += self._weight(m)
        if ch.hits[h.row] == 0.0:
            ch.filled += 1
        ch.hits[h.row] += 1.0
        ch.clients += 1
        self._stream_clients += 1
        if ch.filled == ch.buffer.num_rows:
            # The chunk has fully landed: fire its fed_reduce partial now —
            # the (async) reduction overlaps the remaining chunks' compute.
            self._fire_chunk(key)

    def _stream_add_batch(self, b: ArrivalBatch) -> None:
        """Vectorized ``_stream_add``: one scatter per batch slice."""
        key = id(b.buffer)
        ch = self._chunks.get(key)
        if ch is None:
            ch = self._chunks[key] = _StreamChunk(
                b.buffer,
                np.zeros(b.buffer.num_rows, np.float32),
                np.zeros(b.buffer.num_rows, np.float32))
        np.add.at(ch.weights, b.rows, self._weights_of(b))
        np.add.at(ch.hits, b.rows, np.float32(1.0))
        ch.filled = int(np.count_nonzero(ch.hits))
        ch.clients += b.n
        self._stream_clients += b.n
        if ch.filled == ch.buffer.num_rows:
            self._fire_chunk(key)

    def _fire_chunk(self, key: int) -> None:
        ch = self._chunks.pop(key)
        leaves = _PARTIAL_REDUCE(tuple(ch.buffer.leaves2d),
                                 _scales_of(ch.buffer),
                                 jnp.asarray(ch.weights),
                                 impl=self.reduce_impl, mesh=self.mesh)
        self._partials.append((leaves, float(ch.weights.sum())))
        self._fired.append(ch)

    def tick(self, t: float) -> None:
        """Clock hook for scheduled triggers."""
        if self.trigger.should_fire_on_tick(self, t):
            self.aggregate(t)

    def aggregate(self, t: float) -> AggregationEvent | None:
        n_stream = self._stream_clients
        n_batch = self._pending_batch_rows
        if not self._pending and not n_stream and not n_batch:
            return None
        num_clients = len(self._pending) + n_stream + n_batch
        updates = [m.payload for m in self._pending]
        weights = [self._weight(m) for m in self._pending]
        if n_stream:
            # Streaming mode: pending batches here have foreign buffer
            # layouts (aligned ones streamed into chunks on arrival) —
            # fold them in through the scalar adapter.
            for b in self._pending_batches:
                for m in b.messages():
                    updates.append(m.payload)
                    weights.append(self._weight(m))
            self.global_params = self._aggregate_streaming(updates, weights)
        else:
            # Partition the columnar batches: buffer layouts matching the
            # global params ride the fused path whole; foreign layouts
            # spill through the scalar adapter.
            aligned: list[ArrivalBatch] = []
            for b in self._pending_batches:
                if self._stream_aligned(b.buffer):
                    aligned.append(b)
                else:
                    for m in b.messages():
                        updates.append(m.payload)
                        weights.append(self._weight(m))
            if aligned and updates and not handles_align(
                    self.global_params, updates):
                # Host payloads in the mix demote the whole aggregation to
                # the host reference path (scalar-plane contract): batches
                # join row-by-row via the adapter.
                for b in aligned:
                    for m in b.messages():
                        updates.append(m.payload)
                        weights.append(self._weight(m))
                aligned = []
            if aligned:
                bvecs = [self._weights_of(b) for b in aligned]
                total = (float(sum(weights))
                         + float(sum(v.sum() for v in bvecs)))
                if total <= 0.0:
                    # Uniform fallback, spanning both planes.
                    weights = [1.0] * len(updates)
                    bvecs = [np.ones(b.n, np.float32) for b in aligned]
                    total = float(len(updates)
                                  + sum(b.n for b in aligned))
                self.global_params = self._fused_mixed(
                    aligned, bvecs, updates, weights, total)
            else:
                if sum(weights) <= 0.0:
                    # An aggressive staleness_discount can zero every pending
                    # weight; fall back to uniform weights instead of
                    # crashing the delivery callback mid-flow.
                    weights = [1.0] * len(updates)
                if handles_align(self.global_params, updates):
                    # Zero-copy path: one fused weighted reduction per
                    # stacked buffer, no host materialization.
                    self.global_params = _fused_fedavg_delta_validated(
                        self.global_params, updates, weights,
                        server_lr=self.server_lr, impl=self.reduce_impl,
                        donate=self.donate_params, mesh=self.mesh)
                else:
                    # Host reference path (serves host payloads; stray
                    # handles in a mixed batch are materialized rather than
                    # crashing).
                    updates = [u.materialize() if isinstance(u, UpdateHandle)
                               else u for u in updates]
                    self.global_params = fedavg_delta(
                        self.global_params, updates, weights,
                        server_lr=self.server_lr)
        ev = AggregationEvent(
            t=t,
            round_idx=self.round_idx,
            num_clients=num_clients,
            num_samples=self._pending_samples,
            global_params=self.global_params,
            mean_latency_s=self._pending_latency / num_clients,
        )
        self.history.append(ev)
        self._pending = []
        self._pending_batches = []
        self._pending_batch_rows = 0
        self._pending_samples = 0
        self._pending_latency = 0.0
        self._chunks = {}
        self._fired = []
        self._partials = []
        self._stream_clients = 0
        self.round_idx += 1
        if self.on_aggregate is not None:
            self.on_aggregate(ev)
        return ev

    def _fused_mixed(self, batches: list[ArrivalBatch],
                     bvecs: list[np.ndarray], handles: list[UpdateHandle],
                     weights: list[float], total: float) -> Params:
        """One fused reduction over columnar batches *and* scalar handles:
        both scatter into the same per-buffer weight vectors (a batch is
        just the vectorized form of its rows' handles), then one jitted
        reduce-and-apply dispatch."""
        groups: dict[int, tuple[Any, np.ndarray]] = {}

        def wvec(buf) -> np.ndarray:
            key = id(buf)
            if key not in groups:
                groups[key] = (buf, np.zeros(buf.num_rows, np.float32))
            return groups[key][1]

        for b, v in zip(batches, bvecs):
            np.add.at(wvec(b.buffer), b.rows, v)
        for h, w in zip(handles, weights):
            wvec(h.buffer)[h.row] += w
        buf_leaves = tuple(tuple(buf.leaves2d) for buf, _ in groups.values())
        buf_scales = tuple(_scales_of(buf) for buf, _ in groups.values())
        wvecs = tuple(jnp.asarray(v) for _, v in groups.values())
        apply = (_FUSED_REDUCE_APPLY_DONATED if self.donate_params
                 else _FUSED_REDUCE_APPLY)
        return apply(self.global_params, buf_leaves, buf_scales, wvecs,
                     _dev_f32(1.0 / total), _dev_f32(self.server_lr),
                     impl=self.reduce_impl, mesh=self.mesh)

    def _aggregate_streaming(self, host_updates: list,
                             host_weights: list[float]) -> Params:
        """Fold fired partials + leftover chunks + host stragglers into the
        server update (same math as ``fused_fedavg_delta``)."""
        for key in list(self._chunks):  # chunks the dispatcher cut short
            self._fire_chunk(key)
        total = (sum(w for _, w in self._partials) + sum(host_weights))
        if total <= 0.0:
            # Uniform fallback: re-reduce every chunk with its delivery
            # counts.  Needs the chunk buffers, which are retained until
            # aggregation exactly for this case — but a retained buffer may
            # have been invalidated meanwhile (``recycle_buffers`` donation)
            # and a restored service has none at all (see ``state_dict``);
            # the fallback covers whatever is still alive and keeps the
            # params unchanged when nothing is, instead of crashing the
            # delivery callback on dead device memory.
            alive = [ch for ch in self._fired if ch.alive()]
            if not alive and not host_updates:
                return self.global_params
            self._partials = [
                (_PARTIAL_REDUCE(tuple(ch.buffer.leaves2d),
                                 _scales_of(ch.buffer),
                                 jnp.asarray(ch.hits), impl=self.reduce_impl,
                                 mesh=self.mesh),
                 float(ch.hits.sum()))
                for ch in alive]
            host_weights = [1.0] * len(host_updates)
            total = (sum(w for _, w in self._partials) + sum(host_weights))
        summed = None
        for leaves, _ in self._partials:
            summed = (list(leaves) if summed is None
                      else [a + b for a, b in zip(summed, leaves)])
        if host_updates:
            # Host-path stragglers (non-handle payloads): their f32 weighted
            # sum joins the partials as one extra term.
            host_updates = [u.materialize() if isinstance(u, UpdateHandle)
                            else u for u in host_updates]
            hs = None
            for u, w in zip(host_updates, host_weights):
                leaves = [np.asarray(l, np.float32).reshape(-1)
                          * np.float32(w) for l in jax.tree.leaves(u)]
                hs = (leaves if hs is None
                      else [a + b for a, b in zip(hs, leaves)])
            summed = (list(map(jnp.asarray, hs)) if summed is None
                      else [a + jnp.asarray(b) for a, b in zip(summed, hs)])
        apply = (_APPLY_WEIGHTED_SUM_DONATED if self.donate_params
                 else _APPLY_WEIGHTED_SUM)
        return apply(self.global_params, tuple(summed),
                     _dev_f32(1.0 / total), _dev_f32(self.server_lr))

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Resume-safe aggregation state.

        Open streaming chunks are flushed to partials first, and partial
        sums are materialized to host arrays, so the result holds no live
        device references.  (A restored service cannot apply the
        uniform-weight fallback for pre-checkpoint partials — the chunk
        buffers are gone; it keeps the params unchanged in that edge case.)
        """
        for key in list(self._chunks):
            self._fire_chunk(key)

        def enc_msg(m: Message) -> dict:
            return {"task_id": m.task_id, "device_id": m.device_id,
                    "round_idx": m.round_idx, "num_samples": m.num_samples,
                    "created_t": m.created_t, "size_bytes": m.size_bytes,
                    "payload": materialize_handles(m.payload)}

        return {
            "round_idx": self.round_idx,
            "pending": [enc_msg(m) for m in self._pending],
            # Columnar plane: pending batches round-trip as struct-of-array
            # state (host columns + deduplicated buffer snapshots), so a
            # mid-round snapshot with in-flight batches restores to the
            # identical aggregation timeline.
            "pending_batches": encode_arrival_batches(self._pending_batches),
            "pending_samples": self._pending_samples,
            "pending_latency": self._pending_latency,
            "stream_clients": self._stream_clients,
            "partials": [
                {"leaves": [np.asarray(l) for l in leaves], "weight": w}
                for leaves, w in self._partials],
        }

    def load_state_dict(self, d: dict) -> None:
        self.round_idx = int(d["round_idx"])
        self._pending = [Message(**m) for m in d["pending"]]
        self._pending_batches = decode_arrival_batches(
            d.get("pending_batches", {}))
        self._pending_batch_rows = sum(b.n for b in self._pending_batches)
        self._pending_samples = int(d["pending_samples"])
        self._pending_latency = float(d["pending_latency"])
        self._stream_clients = int(d.get("stream_clients", 0))
        self._partials = [
            (tuple(jnp.asarray(l) for l in p["leaves"]), float(p["weight"]))
            for p in d.get("partials", ())]
        self._chunks = {}
        self._fired = []
        self._g_sig = None

    @property
    def pending_samples(self) -> int:
        return self._pending_samples

    @property
    def pending_clients(self) -> int:
        return (len(self._pending) + self._stream_clients
                + self._pending_batch_rows)


class Trigger:
    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return False

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        return False


@dataclasses.dataclass
class SampleThresholdTrigger(Trigger):
    """Aggregate when accumulated edge training samples reach a threshold."""

    threshold: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_samples >= self.threshold


@dataclasses.dataclass
class ClientCountTrigger(Trigger):
    """Aggregate when K client updates have arrived (FedBuff buffer size)."""

    k: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_clients >= self.k


@dataclasses.dataclass
class ScheduledTrigger(Trigger):
    """Aggregate every ``period`` virtual seconds (paper: scheduled times)."""

    period: float
    _last: float = 0.0

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        if t - self._last >= self.period - 1e-9 and svc.pending_clients > 0:
            # Snap forward on the fixed grid rather than re-anchoring to the
            # tick's arrival time — aggregation stays on the paper's
            # "scheduled times" instead of drifting by the tick jitter.  The
            # max(1, ...) guards the fire-condition tolerance: a tick landing
            # a hair below the grid point must still advance the grid.
            self._last += self.period * max(1, math.floor(
                (t - self._last + 1e-9) / self.period))
            return True
        return False


def polynomial_staleness(alpha: float = 0.5) -> Callable[[int], float]:
    """FedBuff-style ``(1 + s)^-alpha`` staleness discount."""
    return lambda s: (1.0 + s) ** (-alpha)
