"""Cloud-side aggregation service (paper §II.A, §VI.C).

Implements the device-cloud collaborative objective
``min_w F(w) = sum_k p_k F_k(w; D_k)`` with FedAvg/FedProx aggregation, plus
the two aggregation *triggers* the paper evaluates (Fig. 9):

* **sample threshold** — aggregate as soon as the accumulated number of client
  samples reaches a threshold;
* **scheduled** — aggregate at fixed virtual-time intervals with whatever has
  arrived.

Beyond-paper: an **async buffered (FedBuff-style)** mode with staleness
discounting — the natural straggler-mitigation extension once DeviceFlow
exposes arrival times.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.deviceflow import Delivery, Message

Params = Any  # pytree


def weighted_average(updates: list[Params], weights: list[float]) -> Params:
    """FedAvg: ``sum_k p_k w_k`` with ``p_k`` normalized weights."""
    if not updates:
        raise ValueError("no updates to aggregate")
    tot = float(sum(weights))
    if tot <= 0:
        raise ValueError("weights must sum to a positive value")
    ws = [w / tot for w in weights]

    def avg(*leaves):
        out = leaves[0] * ws[0]
        for leaf, w in zip(leaves[1:], ws[1:]):
            out = out + leaf * w
        return out

    return jax.tree.map(avg, *updates)


def fedavg_delta(global_params: Params, updates: list[Params],
                 weights: list[float], *, server_lr: float = 1.0) -> Params:
    """Server update: ``w <- w + lr * avg_k p_k (w_k - w)`` (equivalent to
    FedAvg at lr=1 but supports server-side learning rates)."""
    avg = weighted_average(updates, weights)
    return jax.tree.map(lambda g, a: g + server_lr * (a - g), global_params, avg)


@dataclasses.dataclass
class AggregationEvent:
    t: float
    round_idx: int
    num_clients: int
    num_samples: int
    global_params: Params
    # Mean shelf-queuing delay of the aggregated updates: delivery time minus
    # ``Message.created_t`` (stamped by DeviceFlow at submit from the fleet's
    # sampled round durations).  Zero only when updates arrive instantly.
    mean_latency_s: float = 0.0


class AggregationService:
    """The paper's *Cloud Service*: consumes DeviceFlow deliveries, fires
    aggregation on a trigger, tracks history for the GUI/metrics stream."""

    def __init__(
        self,
        global_params: Params,
        *,
        trigger: "Trigger",
        server_lr: float = 1.0,
        staleness_discount: Callable[[int], float] | None = None,
        on_aggregate: Callable[[AggregationEvent], None] | None = None,
    ):
        self.global_params = global_params
        self.trigger = trigger
        self.server_lr = server_lr
        self.staleness_discount = staleness_discount
        self.on_aggregate = on_aggregate
        self._pending: list[Message] = []
        self._pending_samples = 0
        self._pending_latency = 0.0
        self.round_idx = 0
        self.history: list[AggregationEvent] = []

    # DeviceFlow delivery callback -----------------------------------------
    def __call__(self, d: Delivery) -> None:
        self._pending.append(d.message)
        self._pending_samples += d.message.num_samples
        self._pending_latency += max(0.0, d.t - d.message.created_t)
        if self.trigger.should_fire(self, d.t):
            self.aggregate(d.t)

    def tick(self, t: float) -> None:
        """Clock hook for scheduled triggers."""
        if self.trigger.should_fire_on_tick(self, t):
            self.aggregate(t)

    def aggregate(self, t: float) -> AggregationEvent | None:
        if not self._pending:
            return None
        updates, weights = [], []
        for m in self._pending:
            w = float(m.num_samples)
            if self.staleness_discount is not None:
                staleness = max(0, self.round_idx - m.round_idx)
                w *= self.staleness_discount(staleness)
            updates.append(m.payload)
            weights.append(w)
        if sum(weights) <= 0.0:
            # An aggressive staleness_discount can zero every pending weight;
            # fall back to uniform weights instead of crashing the delivery
            # callback mid-flow.
            weights = [1.0] * len(updates)
        self.global_params = fedavg_delta(
            self.global_params, updates, weights, server_lr=self.server_lr
        )
        ev = AggregationEvent(
            t=t,
            round_idx=self.round_idx,
            num_clients=len(self._pending),
            num_samples=self._pending_samples,
            global_params=self.global_params,
            mean_latency_s=self._pending_latency / len(self._pending),
        )
        self.history.append(ev)
        self._pending = []
        self._pending_samples = 0
        self._pending_latency = 0.0
        self.round_idx += 1
        if self.on_aggregate is not None:
            self.on_aggregate(ev)
        return ev

    @property
    def pending_samples(self) -> int:
        return self._pending_samples

    @property
    def pending_clients(self) -> int:
        return len(self._pending)


class Trigger:
    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return False

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        return False


@dataclasses.dataclass
class SampleThresholdTrigger(Trigger):
    """Aggregate when accumulated edge training samples reach a threshold."""

    threshold: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_samples >= self.threshold


@dataclasses.dataclass
class ClientCountTrigger(Trigger):
    """Aggregate when K client updates have arrived (FedBuff buffer size)."""

    k: int

    def should_fire(self, svc: AggregationService, t: float) -> bool:
        return svc.pending_clients >= self.k


@dataclasses.dataclass
class ScheduledTrigger(Trigger):
    """Aggregate every ``period`` virtual seconds (paper: scheduled times)."""

    period: float
    _last: float = 0.0

    def should_fire_on_tick(self, svc: AggregationService, t: float) -> bool:
        if t - self._last >= self.period - 1e-9 and svc.pending_clients > 0:
            # Snap forward on the fixed grid rather than re-anchoring to the
            # tick's arrival time — aggregation stays on the paper's
            # "scheduled times" instead of drifting by the tick jitter.  The
            # max(1, ...) guards the fire-condition tolerance: a tick landing
            # a hair below the grid point must still advance the grid.
            self._last += self.period * max(1, math.floor(
                (t - self._last + 1e-9) / self.period))
            return True
        return False


def polynomial_staleness(alpha: float = 0.5) -> Callable[[int], float]:
    """FedBuff-style ``(1 + s)^-alpha`` staleness discount."""
    return lambda s: (1.0 + s) ** (-alpha)
