"""Traffic-curve library for DeviceFlow time-interval dispatching (paper §V.B).

A traffic curve is a single-valued, bounded, non-negative continuous (or
piecewise-continuous) function ``y = f(t)`` over a closed domain ``[a, b]``.
The curves below are the ones evaluated in the paper (Table II) plus the
right-tailed normal used for the federated-learning traffic experiments
(Fig. 9: N(0, sigma) restricted to t >= 0).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable


@dataclasses.dataclass(frozen=True)
class TrafficCurve:
    """A named rate curve ``f`` on closed domain ``[lo, hi]``."""

    name: str
    fn: Callable[[float], float]
    lo: float
    hi: float

    def __post_init__(self):
        if not self.hi > self.lo:
            raise ValueError("domain must be a nonempty closed interval")

    def __call__(self, t: float) -> float:
        v = self.fn(t)
        if v < -1e-12:
            raise ValueError(f"curve {self.name} negative at t={t}: {v}")
        return max(0.0, v)


def normal_pdf(sigma: float, mu: float = 0.0) -> Callable[[float], float]:
    c = 1.0 / (sigma * math.sqrt(2.0 * math.pi))
    return lambda t: c * math.exp(-0.5 * ((t - mu) / sigma) ** 2)


def right_tailed_normal(sigma: float, hi: float | None = None) -> TrafficCurve:
    """N(0, sigma) restricted to t >= 0 (paper Fig. 9 response curves)."""
    return TrafficCurve(
        name=f"right_normal(sigma={sigma})",
        fn=normal_pdf(sigma),
        lo=0.0,
        hi=4.0 * sigma if hi is None else hi,
    )


# The Table II evaluation set.
def table2_curves() -> tuple[TrafficCurve, ...]:
    return (
        TrafficCurve("N(0,1)", normal_pdf(1.0), -4.0, 4.0),
        TrafficCurve("N(0,2)", normal_pdf(2.0), -4.0, 4.0),
        TrafficCurve("sin(t)+1", lambda t: math.sin(t) + 1.0, 0.0, 6.0 * math.pi),
        TrafficCurve("cos(t)+1", lambda t: math.cos(t) + 1.0, 0.0, 6.0 * math.pi),
        TrafficCurve("2^t", lambda t: 2.0**t, 0.0, 3.0),
        TrafficCurve("10^t", lambda t: 10.0**t, 0.0, 3.0),
    )


def diurnal(day_s: float = 86400.0, *, trough: float = 0.12,
            peaks: tuple[tuple[float, float, float], ...] = (
                (0.36, 0.055, 0.75), (0.82, 0.075, 1.0)),
            days: float = 1.0, name: str = "diurnal") -> TrafficCurve:
    """Double-peaked diurnal access-load curve (million-user serving shape).

    ``peaks`` are ``(center, width, amplitude)`` Gaussian bumps in
    day-fraction units (defaults: a morning shoulder and a taller evening
    peak) on a ``trough`` base rate — the "fluctuating access load" profile
    SimDC's traffic controller replays against the cloud (§I challenge 2).
    The curve is periodic, so ``days > 1`` spans multiple days.
    """
    if not 0.0 <= trough:
        raise ValueError("trough must be non-negative")

    def fn(t: float) -> float:
        x = (t / day_s) % 1.0
        v = trough
        for c, w, a in peaks:
            # Wrap-around distance so a peak near midnight stays smooth.
            dx = min(abs(x - c), 1.0 - abs(x - c))
            v += a * math.exp(-0.5 * (dx / w) ** 2)
        return v

    return TrafficCurve(name, fn, 0.0, day_s * days)


def arrival_quantiles(curve: TrafficCurve, n: int,
                      duration_s: float | None = None,
                      *, samples: int = 4096) -> "list[float]":
    """Deterministic request arrival times shaped by ``curve``.

    Places ``n`` arrivals at the equal-AUC quantiles of the curve (inverse
    CDF at ``(i + 0.5) / n``), scaled onto ``[0, duration_s]`` (defaults to
    the curve's own domain span).  Deterministic by construction — the same
    trace drives every serving mode in a comparison.
    """
    import numpy as np
    if n < 0:
        raise ValueError("n must be non-negative")
    if n == 0:
        return []
    ts = np.linspace(curve.lo, curve.hi, samples + 1)
    ys = np.array([curve(float(t)) for t in ts])
    seg = 0.5 * (ys[1:] + ys[:-1]) * np.diff(ts)
    cdf = np.concatenate([[0.0], np.cumsum(seg)])
    if cdf[-1] <= 0.0:
        raise ValueError("curve has zero area — cannot place arrivals")
    cdf /= cdf[-1]
    q = (np.arange(n) + 0.5) / n
    t_curve = np.interp(q, cdf, ts)
    span = curve.hi - curve.lo
    scale = (span if duration_s is None else duration_s) / span
    return [float((t - curve.lo) * scale) for t in t_curve]


def piecewise(segments: list[tuple[float, float, Callable[[float], float]]],
              name: str = "piecewise") -> TrafficCurve:
    """Piecewise-continuous curve from ``(lo, hi, fn)`` segments (paper allows
    piecewise continuity)."""
    if not segments:
        raise ValueError("need at least one segment")
    segs = sorted(segments, key=lambda s: s[0])
    for (l0, h0, _), (l1, _, _) in zip(segs, segs[1:]):
        if h0 > l1 + 1e-12:
            raise ValueError("overlapping segments")

    def fn(t: float) -> float:
        for lo, hi, f in segs:
            if lo - 1e-12 <= t <= hi + 1e-12:
                return f(t)
        return 0.0

    return TrafficCurve(name, fn, segs[0][0], segs[-1][1])
