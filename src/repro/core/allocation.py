"""Hybrid allocation optimization (paper §IV.B, Eq. 1).

Given ``c`` device grades, choose how many devices ``x_i`` of each grade run on
the Logical Simulation tier (the rest run on the Device Simulation tier) to
minimize the task makespan::

    T_l  = max_i ceil(k_i * x_i / f_i) * alpha_i                (logical tier)
    T_p  = max_i ceil((N_i - q_i - x_i) / m_i) * beta_i + lambda_i   (device tier)
    T    = max(T_l, T_p)

subject to ``0 <= x_i <= N_i - q_i``.  The paper formulates this as an ILP; the
objective is *separable* — ``x_i`` only influences grade ``i``'s two terms — so
the exact optimum is ``T* = max_i min_{x_i} g_i(x_i)`` with
``g_i(x) = max(logical_i(x), physical_i(x))``.  ``logical_i`` is nondecreasing
and ``physical_i`` nonincreasing in ``x``, so each inner minimum is found at
the crossing of two staircase functions by binary search (O(log N) per grade).

A secondary objective (paper: "prioritizing the use of Logical Simulation
resources") maximizes ``sum_i x_i`` over all makespan-optimal solutions; by the
same monotonicity each grade independently takes the largest feasible ``x_i``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.task import GradeSpec


@dataclasses.dataclass(frozen=True)
class GradeRuntime:
    """Pre-measured runtime parameters for one grade (paper symbols)."""

    alpha: float  # avg round duration of a logical-simulation bundle-group
    beta: float  # avg round duration on a physical phone
    lam: float  # startup time of the on-phone compute framework (lambda_i)

    def __post_init__(self):
        if self.alpha <= 0 or self.beta <= 0 or self.lam < 0:
            raise ValueError("alpha, beta must be > 0 and lambda >= 0")


@dataclasses.dataclass(frozen=True)
class GradeAllocation:
    grade: str
    logical_devices: int  # x_i
    physical_devices: int  # N_i - q_i - x_i
    logical_time: float
    physical_time: float


@dataclasses.dataclass(frozen=True)
class AllocationResult:
    makespan: float  # T*
    per_grade: tuple[GradeAllocation, ...]

    @property
    def total_logical(self) -> int:
        return sum(g.logical_devices for g in self.per_grade)


_INF = float("inf")


def _logical_time(x: int, spec: GradeSpec, rt: GradeRuntime) -> float:
    """ceil(k*x/f) * alpha; +inf when x devices are requested but f == 0."""
    if x == 0:
        return 0.0
    if spec.logical_bundles <= 0:
        return _INF
    return math.ceil(spec.bundles_per_device * x / spec.logical_bundles) * rt.alpha


def _physical_time(y: int, spec: GradeSpec, rt: GradeRuntime) -> float:
    """ceil(y/m) * beta + lambda; +inf when y devices requested but m == 0."""
    if y == 0:
        return 0.0
    if spec.physical_devices <= 0:
        return _INF
    return math.ceil(y / spec.physical_devices) * rt.beta + rt.lam


def _grade_makespan(x: int, spec: GradeSpec, rt: GradeRuntime) -> float:
    n = spec.allocatable_devices
    return max(_logical_time(x, spec, rt), _physical_time(n - x, spec, rt))


def _min_single_grade(spec: GradeSpec, rt: GradeRuntime) -> tuple[float, int]:
    """Exact ``min_x max(logical(x), physical(n-x))`` via crossing search.

    Returns ``(T_i, x_i)``.  ``logical`` is nondecreasing in x, ``physical``
    nonincreasing, so binary-search the largest x where physical >= logical and
    inspect the boundary pair.
    """
    n = spec.allocatable_devices
    if n == 0:
        return 0.0, 0
    lo, hi = 0, n
    # Invariant target: find largest x with physical(n-x) >= logical(x).
    if _physical_time(n - lo, spec, rt) < _logical_time(lo, spec, rt):
        # physical already below logical at x=0 -> optimum at x=0.
        candidates = [0]
    else:
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if _physical_time(n - mid, spec, rt) >= _logical_time(mid, spec, rt):
                lo = mid
            else:
                hi = mid - 1
        candidates = [lo] + ([lo + 1] if lo + 1 <= n else [])
    best_x = min(candidates, key=lambda x: (_grade_makespan(x, spec, rt), -x))
    return _grade_makespan(best_x, spec, rt), best_x


def _max_x_within(spec: GradeSpec, rt: GradeRuntime, budget: float) -> int:
    """Largest feasible x_i with both tier times <= budget (secondary obj)."""
    n = spec.allocatable_devices
    lo, hi = -1, n
    # logical(x) nondecreasing: binary search largest x with logical <= budget.
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if _logical_time(mid, spec, rt) <= budget + 1e-12:
            lo = mid
        else:
            hi = mid - 1
    x_hi = lo
    # physical(n-x) <= budget gives a LOWER bound on x.
    x_lo = 0
    while _physical_time(n - x_lo, spec, rt) > budget + 1e-12:
        # physical is nonincreasing in x -> binary search the smallest ok x.
        a, b = x_lo + 1, n
        while a < b:
            mid = (a + b) // 2
            if _physical_time(n - mid, spec, rt) <= budget + 1e-12:
                b = mid
            else:
                a = mid + 1
        x_lo = a
        break
    if x_lo > x_hi:
        raise ValueError("budget infeasible for grade (internal inconsistency)")
    return x_hi


def solve_allocation(
    specs: Sequence[GradeSpec],
    runtimes: Sequence[GradeRuntime],
    *,
    prefer_logical: bool = True,
) -> AllocationResult:
    """Exact solution of the paper's hybrid-allocation ILP (Eq. 1).

    When ``prefer_logical`` is set, among all makespan-optimal solutions the
    one maximizing ``sum_i x_i`` is returned (paper's stated tie-break).
    """
    if len(specs) != len(runtimes):
        raise ValueError("specs and runtimes must align")
    mins = [_min_single_grade(s, r) for s, r in zip(specs, runtimes)]
    makespan = max((t for t, _ in mins), default=0.0)
    if math.isinf(makespan):
        raise ValueError(
            "infeasible: some grade has devices but no resources on either tier"
        )
    out = []
    for (t_i, x_i), spec, rt in zip(mins, specs, runtimes):
        n = spec.allocatable_devices
        x = _max_x_within(spec, rt, makespan) if prefer_logical else x_i
        out.append(
            GradeAllocation(
                grade=spec.grade,
                logical_devices=x,
                physical_devices=n - x,
                logical_time=_logical_time(x, spec, rt),
                physical_time=_physical_time(n - x, spec, rt),
            )
        )
    return AllocationResult(makespan=makespan, per_grade=tuple(out))


def solve_allocation_bruteforce(
    specs: Sequence[GradeSpec],
    runtimes: Sequence[GradeRuntime],
    *,
    prefer_logical: bool = True,
) -> AllocationResult:
    """O(sum N_i) oracle used by property tests (exhaustive per grade)."""
    out = []
    makespan = 0.0
    per_grade_best: list[tuple[float, int]] = []
    for spec, rt in zip(specs, runtimes):
        n = spec.allocatable_devices
        best = min(
            ((_grade_makespan(x, spec, rt), x) for x in range(n + 1)),
            key=lambda p: (p[0], -p[1] if prefer_logical else p[1]),
        )
        per_grade_best.append(best)
        makespan = max(makespan, best[0])
    if math.isinf(makespan):
        raise ValueError("infeasible")
    for (t_i, _), spec, rt in zip(per_grade_best, specs, runtimes):
        n = spec.allocatable_devices
        feas = [
            x for x in range(n + 1) if _grade_makespan(x, spec, rt) <= makespan + 1e-12
        ]
        x = max(feas) if prefer_logical else min(feas, key=lambda x: _grade_makespan(x, spec, rt))
        out.append(
            GradeAllocation(
                grade=spec.grade,
                logical_devices=x,
                physical_devices=n - x,
                logical_time=_logical_time(x, spec, rt),
                physical_time=_physical_time(n - x, spec, rt),
            )
        )
    return AllocationResult(makespan=makespan, per_grade=tuple(out))


def fixed_ratio_allocation(
    specs: Sequence[GradeSpec],
    runtimes: Sequence[GradeRuntime],
    logical_fraction: float,
) -> AllocationResult:
    """Paper Fig. 7 baselines: fixed (logical, device) split ratios."""
    if not 0.0 <= logical_fraction <= 1.0:
        raise ValueError("logical_fraction in [0, 1]")
    out = []
    for spec, rt in zip(specs, runtimes):
        n = spec.allocatable_devices
        x = round(n * logical_fraction)
        out.append(
            GradeAllocation(
                grade=spec.grade,
                logical_devices=x,
                physical_devices=n - x,
                logical_time=_logical_time(x, spec, rt),
                physical_time=_physical_time(n - x, spec, rt),
            )
        )
    makespan = max(
        (max(g.logical_time, g.physical_time) for g in out), default=0.0
    )
    return AllocationResult(makespan=makespan, per_grade=tuple(out))
