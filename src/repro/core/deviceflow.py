"""DeviceFlow — the device-behavior traffic controller (paper §V).

DeviceFlow sits between the simulated edge tiers and the cloud service.  From
the edge's viewpoint it is a cloud proxy; from the cloud's viewpoint it *is*
the device population.  Four modules (paper Fig. 4):

* **Sorter** — receives messages from the compute clusters and routes them to
  the correct **Shelf** by ``task_id``.
* **Shelf** — per-task FIFO buffer of pending messages.
* **Strategy** — stores the user-defined dispatch strategy per task.
* **Dispatcher** — per-shelf, independent; parses the strategy and emits
  messages to the downstream cloud service.  Dispatchers of different tasks
  never interfere.

Everything runs against a *virtual clock* (deterministic event-driven
simulation), which is the TPU-container adaptation of the paper's wall-clock
network component: identical ordering semantics, fully reproducible.

Arrival-time contract (batched round engine): the simulation tiers sample
per-device round durations from ``DeviceFleet`` and hand them to the Sorter as
arrival times — ``submit(msg, t)`` stamps ``Message.created_t`` at submit time
so downstream latency/staleness accounting sees real queuing delay, and
``submit_many(msgs, ts)`` is the bulk fast path: messages are routed, sorted
by arrival time, shelved in one append, and the accumulated dispatcher drains
per threshold *crossing* (timestamped at the message that crossed it) instead
of via one Python call per message.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchStrategy,
    TimeIntervalStrategy,
    TimePointStrategy,
)


def payload_nbytes(payload: Any) -> int:
    """Best-effort wire size of a message payload.

    Anything exposing ``nbytes`` (ndarray / jax.Array leaves, and
    ``updates.UpdateHandle`` — which reports its stacked-buffer *row* size,
    the bytes a physical device would actually upload) counts directly;
    containers sum their children; opaque objects count 0.
    """
    nb = getattr(payload, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(payload, dict):
        return sum(payload_nbytes(v) for v in payload.values())
    if isinstance(payload, (list, tuple)):
        return sum(payload_nbytes(v) for v in payload)
    return 0


class _Weakrefable:
    # Base slot so the slotted Message below still supports weak references
    # (``weakref_slot=True`` needs 3.11; the base-class form works on 3.10).
    __slots__ = ("__weakref__",)


@dataclasses.dataclass(frozen=True, slots=True)
class Message(_Weakrefable):
    """One edge→cloud message (model update, metric packet, ...).

    Slotted: rounds emit one instance per simulated device, so per-instance
    ``__dict__``s are real memory at fleet scale.  ``size_bytes`` is
    auto-computed from the payload when not given, so DeviceFlow traffic
    accounting reflects real model-update sizes instead of defaulting to 0.

    ``created_t=None`` means *unstamped* — the Sorter stamps it at submit
    time.  (``0.0`` used to double as the sentinel, which silently
    re-stamped producer-stamped t=0 messages submitted later and corrupted
    latency accounting; a producer-stamped ``0.0`` is now preserved.)
    """

    task_id: int
    device_id: int
    round_idx: int
    payload: Any
    created_t: float | None = None
    num_samples: int = 1
    size_bytes: int = 0

    def __post_init__(self):
        if self.size_bytes == 0:
            object.__setattr__(
                self, "size_bytes", payload_nbytes(self.payload))


@dataclasses.dataclass(frozen=True)
class Delivery:
    """A message delivered to the cloud service at virtual time ``t``."""

    t: float
    message: Message


class Shelf:
    """FIFO buffer of pending messages for one task."""

    def __init__(self, task_id: int):
        self.task_id = task_id
        self._buf: deque[Message] = deque()
        self.total_received = 0
        self.total_dispatched = 0
        self.total_dropped = 0
        # Real traffic accounting (edge->cloud model-update bytes): payloads
        # report their wire size via Message.size_bytes — handle payloads
        # count the stacked-buffer row, not the reference.
        self.total_bytes_received = 0
        self.total_bytes_dispatched = 0

    def put(self, msg: Message) -> None:
        self._buf.append(msg)
        self.total_received += 1
        self.total_bytes_received += msg.size_bytes

    def put_many(self, msgs: Iterable[Message]) -> int:
        msgs = list(msgs)
        self._buf.extend(msgs)
        self.total_received += len(msgs)
        self.total_bytes_received += sum(m.size_bytes for m in msgs)
        return len(msgs)

    def take(self, n: int) -> list[Message]:
        n = min(n, len(self._buf))
        out = [self._buf.popleft() for _ in range(n)]
        return out

    def __len__(self) -> int:
        return len(self._buf)

    # -- checkpointing hooks (runtime/fault tolerance) ---------------------
    def state_dict(self) -> dict:
        return {
            "task_id": self.task_id,
            "buf": list(self._buf),
            "received": self.total_received,
            "dispatched": self.total_dispatched,
            "dropped": self.total_dropped,
            "bytes_received": self.total_bytes_received,
            "bytes_dispatched": self.total_bytes_dispatched,
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "Shelf":
        s = cls(d["task_id"])
        s._buf = deque(d["buf"])
        s.total_received = d["received"]
        s.total_dispatched = d["dispatched"]
        s.total_dropped = d["dropped"]
        s.total_bytes_received = d.get("bytes_received", 0)
        s.total_bytes_dispatched = d.get("bytes_dispatched", 0)
        return s


class Dispatcher:
    """Per-shelf dispatcher executing one strategy.  Independent per task."""

    def __init__(
        self,
        shelf: Shelf,
        strategy: DispatchStrategy,
        deliver: Callable[[Delivery], None],
        *,
        seed: int = 0,
    ):
        self.shelf = shelf
        self.strategy = strategy
        self.deliver = deliver
        self.rng = np.random.default_rng(seed ^ (shelf.task_id * 0x9E3779B9))
        self._cycle = 0  # accumulated-strategy threshold cursor

    # -- real-time accumulated path ----------------------------------------
    def on_message(self, t: float) -> None:
        """Called by the Sorter after every shelf insertion.

        Drains in a loop: with bulk restores or a shrinking ``threshold_at``
        schedule the shelf can sit multiple thresholds above the waterline —
        a single-batch dispatch would strand that backlog forever.
        """
        if not isinstance(self.strategy, AccumulatedStrategy):
            return
        while len(self.shelf) >= (thr := self.strategy.threshold_at(self._cycle)):
            batch = self.shelf.take(thr)
            self._cycle += 1
            self._send(t, batch, self.strategy.failure_prob, 0)

    def on_messages(self, ts: np.ndarray, t_base: float) -> None:
        """Bulk-insert hook: ``len(ts)`` messages (already shelved, arrival
        order) landed at times ``ts``; dispatch once per threshold crossing.

        Equivalent to calling ``on_message(ts[j])`` after each insertion, but
        O(dispatch events) instead of O(messages) Python work.  Pre-existing
        backlog above the threshold drains at ``t_base``.
        """
        if not isinstance(self.strategy, AccumulatedStrategy):
            return
        k = len(ts)
        pre = len(self.shelf) - k  # messages buffered before this bulk insert
        arrived = consumed = 0
        while True:
            thr = self.strategy.threshold_at(self._cycle)
            avail = pre + arrived - consumed
            if avail < thr:
                need = thr - avail
                if arrived + need > k:
                    break  # not enough arrivals left to cross the threshold
                arrived += need
                t_evt = float(ts[arrived - 1])
            else:
                t_evt = float(ts[arrived - 1]) if arrived > 0 else t_base
            batch = self.shelf.take(thr)
            self._cycle += 1
            consumed += thr
            self._send(t_evt, batch, self.strategy.failure_prob, 0)

    # -- rule-based path -----------------------------------------------------
    def on_round_complete(self, t: float, clock: "VirtualClock") -> None:
        """Called when a task round completes; schedules rule-based dispatch."""
        strat = self.strategy
        if isinstance(strat, TimeIntervalStrategy):
            strat = strat.discretize(len(self.shelf))
        if not isinstance(strat, TimePointStrategy):
            return
        base = t if strat.relative else 0.0
        for p in strat.points:
            clock.schedule(
                base + p.t,
                lambda pt=p, bt=base: self._dispatch_point(bt + pt.t, pt),
            )

    def _dispatch_point(self, t: float, p) -> None:
        batch = self.shelf.take(p.count)
        self._send(t, batch, p.failure_prob, p.random_discard)

    def _send(
        self, t: float, batch: list[Message], failure_prob: float, random_discard: int
    ) -> None:
        if random_discard > 0 and batch:
            k = min(random_discard, len(batch))
            drop_idx = set(
                self.rng.choice(len(batch), size=k, replace=False).tolist()
            )
            kept = [m for i, m in enumerate(batch) if i not in drop_idx]
            self.shelf.total_dropped += len(batch) - len(kept)
            batch = kept
        for m in batch:
            if failure_prob > 0.0 and self.rng.random() < failure_prob:
                self.shelf.total_dropped += 1
                continue
            self.shelf.total_dispatched += 1
            self.shelf.total_bytes_dispatched += m.size_bytes
            self.deliver(Delivery(t=t, message=m))

    # -- checkpointing hooks -----------------------------------------------
    def state_dict(self) -> dict:
        """Dispatch-progress state: the accumulated-strategy threshold cursor
        and the failure/discard RNG stream (so restores don't replay it)."""
        return {"cycle": self._cycle, "rng": self.rng.bit_generator.state}

    def load_state_dict(self, d: dict) -> None:
        self._cycle = int(d["cycle"])
        self.rng.bit_generator.state = d["rng"]


class VirtualClock:
    """Deterministic event loop over virtual seconds."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._tie = itertools.count()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now - 1e-12:
            t = self.now
        heapq.heappush(self._heap, (t, next(self._tie), fn))

    def run_until(self, t_end: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            fn()
        self.now = max(self.now, min(t_end, self.now) if t_end == float("inf") else t_end)

    def next_time(self) -> float | None:
        """Timestamp of the earliest pending event (None when idle)."""
        return self._heap[0][0] if self._heap else None

    def run_one(self) -> bool:
        """Execute only the earliest pending event; False when idle.

        Single-stepping hook for event-boundary logic (the task engine's
        admission checks run between events, not between rounds).
        """
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        fn()
        return True

    def advance(self, dt: float) -> None:
        """Run every event inside the next ``dt`` virtual seconds and leave
        ``now`` at the end of the window (serial round accounting)."""
        if dt < 0:
            raise ValueError("cannot advance the clock backwards")
        self.run_until(self.now + dt)

    def pending(self) -> int:
        return len(self._heap)


class DeviceFlow:
    """Facade wiring Sorter → Shelf → Dispatcher → cloud service."""

    def __init__(
        self,
        deliver: Callable[[Delivery], None],
        *,
        clock: VirtualClock | None = None,
        seed: int = 0,
    ):
        self.clock = clock or VirtualClock()
        self._deliver = deliver
        self._shelves: dict[int, Shelf] = {}
        self._dispatchers: dict[int, Dispatcher] = {}
        self._strategies: dict[int, DispatchStrategy] = {}
        self._seed = seed

    # -- Strategy module ------------------------------------------------------
    def register_task(self, task_id: int, strategy: DispatchStrategy) -> None:
        if task_id in self._shelves:
            raise ValueError(f"task {task_id} already registered with DeviceFlow")
        shelf = Shelf(task_id)
        self._shelves[task_id] = shelf
        self._strategies[task_id] = strategy
        self._dispatchers[task_id] = Dispatcher(
            shelf, strategy, self._deliver, seed=self._seed
        )

    # -- Sorter ----------------------------------------------------------------
    def submit(self, msg: Message, t: float | None = None) -> None:
        """Sorter entry point: route by task_id, trigger accumulated dispatch.

        Stamps ``Message.created_t`` at submit time (when not pre-stamped by
        the producer) so delivery latency ``Delivery.t - created_t`` reflects
        real shelf queuing delay.
        """
        t = self.clock.now if t is None else t
        try:
            shelf = self._shelves[msg.task_id]
        except KeyError:
            raise KeyError(
                f"message for unregistered task {msg.task_id}"
            ) from None
        if msg.created_t is None:
            msg = dataclasses.replace(msg, created_t=t)
        shelf.put(msg)
        self._dispatchers[msg.task_id].on_message(t)

    def submit_many(self, msgs: Iterable[Message],
                    ts: "np.ndarray | Sequence[float] | None" = None) -> None:
        """Bulk Sorter fast path: route once per task, not once per message.

        ``ts`` (optional) gives per-message arrival times — e.g. the fleet-
        sampled round durations from the simulation tiers.  Within each task
        messages are shelved in arrival-time order and the accumulated
        dispatcher fires once per threshold crossing, timestamped at the
        message that crossed it — identical semantics to per-message
        ``submit`` in time order, minus the per-message Python overhead.
        """
        msgs = list(msgs)
        if not msgs:
            return
        now = self.clock.now
        if ts is None:
            ts_arr = np.full(len(msgs), now, dtype=float)
        else:
            ts_arr = np.asarray(ts, dtype=float)
            if ts_arr.shape != (len(msgs),):
                raise ValueError("ts must align 1:1 with msgs")
        by_task: dict[int, list[int]] = {}
        for i, m in enumerate(msgs):
            by_task.setdefault(m.task_id, []).append(i)
        for tid, idxs in by_task.items():
            try:
                shelf = self._shelves[tid]
            except KeyError:
                raise KeyError(f"message for unregistered task {tid}") from None
            order = sorted(idxs, key=lambda i: ts_arr[i])
            stamped = []
            for i in order:
                m, t = msgs[i], float(ts_arr[i])
                if m.created_t is None:
                    m = dataclasses.replace(m, created_t=t)
                stamped.append(m)
            shelf.put_many(stamped)
            self._dispatchers[tid].on_messages(ts_arr[order], t_base=now)

    # -- round boundaries --------------------------------------------------------
    def round_complete(self, task_id: int, t: float | None = None) -> None:
        t = self.clock.now if t is None else t
        self._dispatchers[task_id].on_round_complete(t, self.clock)

    # -- introspection -------------------------------------------------------------
    def shelf(self, task_id: int) -> Shelf:
        return self._shelves[task_id]

    def run(self, t_end: float = float("inf")) -> None:
        self.clock.run_until(t_end)

    def conservation_ok(self, task_id: int) -> bool:
        """Invariant: received == dispatched + dropped + still-pending."""
        s = self._shelves[task_id]
        return s.total_received == s.total_dispatched + s.total_dropped + len(s)

    # -- checkpointing ----------------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            tid: {"shelf": s.state_dict(),
                  "dispatcher": self._dispatchers[tid].state_dict()}
            for tid, s in self._shelves.items()
        }

    def load_state_dict(self, d: dict) -> None:
        for tid, sd in d.items():
            # Accept both the nested format and legacy shelf-only dicts.
            shelf_sd = sd["shelf"] if "shelf" in sd else sd
            shelf = Shelf.from_state_dict(shelf_sd)
            self._shelves[tid] = shelf
            if tid in self._strategies:
                disp = Dispatcher(
                    shelf, self._strategies[tid], self._deliver, seed=self._seed
                )
                if "dispatcher" in sd:
                    disp.load_state_dict(sd["dispatcher"])
                self._dispatchers[tid] = disp
