"""DeviceFlow — the device-behavior traffic controller (paper §V).

DeviceFlow sits between the simulated edge tiers and the cloud service.  From
the edge's viewpoint it is a cloud proxy; from the cloud's viewpoint it *is*
the device population.  Four modules (paper Fig. 4):

* **Sorter** — receives messages from the compute clusters and routes them to
  the correct **Shelf** by ``task_id``.
* **Shelf** — per-task FIFO buffer of pending messages.
* **Strategy** — stores the user-defined dispatch strategy per task.
* **Dispatcher** — per-shelf, independent; parses the strategy and emits
  messages to the downstream cloud service.  Dispatchers of different tasks
  never interfere.

Everything runs against a *virtual clock* (deterministic event-driven
simulation), which is the TPU-container adaptation of the paper's wall-clock
network component: identical ordering semantics, fully reproducible.

**Columnar message plane.**  At the fleet scales the roadmap targets (10^6
devices per round) one Python ``Message`` per device is the whole round
budget, so the hot path is struct-of-arrays: an ``ArrivalBatch`` carries one
cohort chunk's worth of arrivals as parallel numpy columns (``rows``,
``created_t``, ``nbytes``, ``num_samples``, ``device_ids``) plus ONE shared
``updates.UpdateBuffer`` reference — the ``UpdateHandle`` row index is
already the columnar key; a batch is its vectorization.  ``submit_batch`` /
``submit_arrivals`` merge batches (and scalar stragglers) into global
arrival order, the Shelf stores them as time-interleaved segments without
materializing per-row objects, and the Dispatcher threshold-triggers on row
counts and byte totals, delivering contiguous batch *slices* downstream.
The scalar ``Message`` API is kept as a thin adapter — ``submit`` /
``submit_many`` behave exactly as before, a 1-row batch delivery exposes
``Delivery.message``, and ``ArrivalBatch.messages()`` materializes per-row
views for compat consumers (fault injection, serve.py, tests).

Arrival-time contract (batched round engine): the simulation tiers sample
per-device round durations from ``DeviceFleet`` and hand them to the Sorter
as arrival times — ``submit(msg, t)`` stamps ``Message.created_t`` at submit
time so downstream latency/staleness accounting sees real queuing delay, and
the bulk paths (``submit_many``, ``submit_batch``) stamp only *unstamped*
rows (``created_t=None`` scalar / NaN column) with their own arrival time; a
producer stamp — including ``0.0`` — is always preserved.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from repro.analysis import sanitizers
from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchStrategy,
    TimeIntervalStrategy,
    TimePointStrategy,
)


def payload_nbytes(payload: Any) -> int:
    """Best-effort wire size of a message payload.

    Anything exposing ``nbytes`` (ndarray / jax.Array leaves, and
    ``updates.UpdateHandle`` — which reports its stacked-buffer *row* size,
    the bytes a physical device would actually upload) counts directly;
    containers sum their children; opaque objects count 0.
    """
    nb = getattr(payload, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(payload, dict):
        return sum(payload_nbytes(v) for v in payload.values())
    if isinstance(payload, (list, tuple)):
        return sum(payload_nbytes(v) for v in payload)
    return 0


class _Weakrefable:
    # Base slot so the slotted Message below still supports weak references
    # (``weakref_slot=True`` needs 3.11; the base-class form works on 3.10).
    __slots__ = ("__weakref__",)


@dataclasses.dataclass(frozen=True, slots=True)
class Message(_Weakrefable):
    """One edge→cloud message (model update, metric packet, ...).

    Slotted: rounds emit one instance per simulated device, so per-instance
    ``__dict__``s are real memory at fleet scale.  ``size_bytes`` is
    auto-computed from the payload when not given, so DeviceFlow traffic
    accounting reflects real model-update sizes instead of defaulting to 0.

    ``created_t=None`` means *unstamped* — the Sorter stamps it at submit
    time.  (``0.0`` used to double as the sentinel, which silently
    re-stamped producer-stamped t=0 messages submitted later and corrupted
    latency accounting; a producer-stamped ``0.0`` is now preserved.)
    """

    task_id: int
    device_id: int
    round_idx: int
    payload: Any
    created_t: float | None = None
    num_samples: int = 1
    size_bytes: int = 0

    def __post_init__(self):
        if self.size_bytes == 0:
            object.__setattr__(
                self, "size_bytes", payload_nbytes(self.payload))


class ArrivalBatch(_Weakrefable):
    """Struct-of-arrays record of one cohort chunk's edge→cloud arrivals.

    Parallel numpy columns over ``n`` rows plus ONE shared ``buffer``
    reference (``updates.UpdateBuffer`` — or ``None`` for metadata-only
    traffic):

    * ``rows: int32[n]`` — row index of each arrival inside ``buffer``;
    * ``created_t: float64[n]`` — producer stamp; **NaN means unstamped**
      (the columnar equivalent of the scalar ``created_t=None`` sentinel)
      and is filled with the arrival time at submit;
    * ``nbytes: int64[n]`` — wire size per row (defaults to the buffer's
      ``row_nbytes``, so a quantized buffer — ``UpdateBuffer(wire="int8")``
      with its int8 leaves + per-leaf scale columns — reports its real
      ~4x-smaller wire footprint through ``Shelf.total_bytes_*`` without any
      caller involvement);
    * ``num_samples: int64[n]`` and ``device_ids: int64[n]`` — aggregation
      weight and global identity per row.

    Slicing (``islice`` / ``select``) returns cheap column views sharing the
    same buffer, so threshold dispatch never copies update payloads.
    ``message(i)`` / ``messages()`` are the scalar-``Message`` compat
    adapter: each row materializes as a ``Message`` whose payload is
    ``buffer.handle(rows[i])``.
    """

    __slots__ = ("task_id", "round_idx", "rows", "created_t", "nbytes",
                 "num_samples", "device_ids", "buffer")

    def __init__(self, task_id: int, round_idx: int, rows,
                 created_t=None, nbytes=None, num_samples=None,
                 device_ids=None, buffer: Any = None):
        self.task_id = int(task_id)
        self.round_idx = int(round_idx)
        self.rows = np.asarray(rows, np.int32)
        if self.rows.ndim != 1:
            raise ValueError("ArrivalBatch.rows must be 1-D")
        n = self.rows.shape[0]
        self.created_t = (np.full(n, np.nan) if created_t is None
                          else np.asarray(created_t, np.float64))
        if nbytes is None:
            per_row = int(getattr(buffer, "row_nbytes", 0) or 0)
            self.nbytes = np.full(n, per_row, np.int64)
        else:
            self.nbytes = np.asarray(nbytes, np.int64)
        self.num_samples = (np.ones(n, np.int64) if num_samples is None
                            else np.asarray(num_samples, np.int64))
        self.device_ids = (self.rows.astype(np.int64) if device_ids is None
                           else np.asarray(device_ids, np.int64))
        self.buffer = buffer
        for name in ("created_t", "nbytes", "num_samples", "device_ids"):
            if getattr(self, name).shape != (n,):
                raise ValueError(f"ArrivalBatch.{name} must have shape ({n},)")

    @classmethod
    def from_buffer(cls, task_id: int, round_idx: int, buffer, *,
                    rows=None, device_ids=None, num_samples=None,
                    created_t=None) -> "ArrivalBatch":
        """One arrival per buffer row (the cohort-chunk emission shape)."""
        if rows is None:
            rows = np.arange(buffer.num_rows, dtype=np.int32)
        return cls(task_id, round_idx, rows, created_t=created_t,
                   num_samples=num_samples, device_ids=device_ids,
                   buffer=buffer)

    # -- columnar views ----------------------------------------------------
    @property
    def n(self) -> int:
        return self.rows.shape[0]

    def __len__(self) -> int:
        return self.rows.shape[0]

    @property
    def total_bytes(self) -> int:
        return int(self.nbytes.sum())

    @property
    def total_samples(self) -> int:
        return int(self.num_samples.sum())

    def select(self, idx) -> "ArrivalBatch":
        """Row subset (new column arrays, same shared buffer)."""
        return ArrivalBatch(
            self.task_id, self.round_idx, self.rows[idx],
            created_t=self.created_t[idx], nbytes=self.nbytes[idx],
            num_samples=self.num_samples[idx],
            device_ids=self.device_ids[idx], buffer=self.buffer)

    def islice(self, lo: int, hi: int) -> "ArrivalBatch":
        """Contiguous row slice (column *views* — zero copies)."""
        return ArrivalBatch(
            self.task_id, self.round_idx, self.rows[lo:hi],
            created_t=self.created_t[lo:hi], nbytes=self.nbytes[lo:hi],
            num_samples=self.num_samples[lo:hi],
            device_ids=self.device_ids[lo:hi], buffer=self.buffer)

    def stamp(self, ts: np.ndarray) -> "ArrivalBatch":
        """Fill *unstamped* rows (NaN) with their arrival times; rows the
        producer stamped — including 0.0 — are preserved verbatim."""
        nan = np.isnan(self.created_t)
        if not nan.any():
            return self
        created = self.created_t.copy()
        created[nan] = np.asarray(ts, np.float64)[nan]
        return ArrivalBatch(
            self.task_id, self.round_idx, self.rows, created_t=created,
            nbytes=self.nbytes, num_samples=self.num_samples,
            device_ids=self.device_ids, buffer=self.buffer)

    # -- scalar compat adapter ---------------------------------------------
    def message(self, i: int) -> Message:
        """Row ``i`` as a scalar ``Message`` (payload = buffer row handle)."""
        ct = float(self.created_t[i])
        payload = (self.buffer.handle(int(self.rows[i]))
                   if self.buffer is not None else None)
        return Message(
            self.task_id, int(self.device_ids[i]), self.round_idx, payload,
            created_t=None if np.isnan(ct) else ct,
            num_samples=int(self.num_samples[i]),
            size_bytes=int(self.nbytes[i]))

    def messages(self) -> list[Message]:
        return [self.message(i) for i in range(self.n)]

    def __repr__(self) -> str:
        return (f"ArrivalBatch(task_id={self.task_id}, "
                f"round_idx={self.round_idx}, n={self.n}, "
                f"bytes={self.total_bytes})")

    # -- checkpointing -----------------------------------------------------
    def state_dict(self, buffer_table: "_BufferTable | None" = None) -> dict:
        buf = (None if self.buffer is None else
               buffer_table.add(self.buffer) if buffer_table is not None
               else self.buffer.state_dict())
        return {"task_id": self.task_id, "round_idx": self.round_idx,
                "rows": np.array(self.rows),
                "created_t": np.array(self.created_t),
                "nbytes": np.array(self.nbytes),
                "num_samples": np.array(self.num_samples),
                "device_ids": np.array(self.device_ids),
                "buffer": buf}

    @classmethod
    def from_state_dict(cls, d: dict,
                        buffers: "list | None" = None) -> "ArrivalBatch":
        buf = d["buffer"]
        if isinstance(buf, int):
            buf = buffers[buf]
        elif isinstance(buf, dict):
            from repro.core.updates import UpdateBuffer
            buf = UpdateBuffer.from_state_dict(buf)
        return cls(d["task_id"], d["round_idx"], d["rows"],
                   created_t=d["created_t"], nbytes=d["nbytes"],
                   num_samples=d["num_samples"], device_ids=d["device_ids"],
                   buffer=buf)


class _BufferTable:
    """Deduplicating UpdateBuffer encoder: batches sharing one buffer keep
    sharing it across a state_dict round-trip (one stored copy, restored to
    one live object — aggregation re-groups them correctly)."""

    def __init__(self):
        self._idx: dict[int, int] = {}
        self.encoded: list = []

    def add(self, buffer) -> int:
        key = id(buffer)
        if key not in self._idx:
            self._idx[key] = len(self.encoded)
            self.encoded.append(buffer.state_dict())
        return self._idx[key]

    @staticmethod
    def decode(encoded: list) -> list:
        from repro.core.updates import UpdateBuffer
        return [UpdateBuffer.from_state_dict(d) for d in encoded]


def encode_arrival_batches(batches: "Sequence[ArrivalBatch]") -> dict:
    """Columnar-state helper: encode batches with shared-buffer dedup."""
    table = _BufferTable()
    return {"batches": [b.state_dict(table) for b in batches],
            "buffers": table.encoded}


def decode_arrival_batches(d: dict) -> "list[ArrivalBatch]":
    buffers = _BufferTable.decode(d.get("buffers", []))
    return [ArrivalBatch.from_state_dict(b, buffers)
            for b in d.get("batches", [])]


class _BatchGroup:
    """Time-interleaved shelf segment over columnar batches (plus any scalar
    stragglers submitted in the same call).

    ``src[j]`` is the source index of the j-th pending row in global arrival
    order; ``take`` pops rows in that order and returns at most one
    contiguous ``islice`` per batch source — dispatch-group membership is
    exactly what per-message submits in time order would produce, at
    O(sources) per dispatch instead of O(rows).
    """

    __slots__ = ("sources", "src", "cursors", "pos")

    def __init__(self, sources: list, src):
        self.sources = list(sources)  # ArrivalBatch | list[Message], sorted
        self.src = np.asarray(src, np.int32)
        self.cursors = [0] * len(self.sources)
        self.pos = 0

    def remaining(self) -> int:
        return len(self.src) - self.pos

    def take(self, k: int) -> list:
        seg = self.src[self.pos:self.pos + int(k)]
        self.pos += len(seg)
        out: list = []
        counts = np.bincount(seg, minlength=len(self.sources))
        for s_idx in np.flatnonzero(counts):
            source = self.sources[s_idx]
            lo = self.cursors[s_idx]
            hi = lo + int(counts[s_idx])
            self.cursors[s_idx] = hi
            if isinstance(source, ArrivalBatch):
                out.append(source.islice(lo, hi))
            else:
                out.extend(source[lo:hi])
        return out

    def state_dict(self, buffer_table: _BufferTable) -> dict:
        sources = [
            {"__batch__": s.state_dict(buffer_table)}
            if isinstance(s, ArrivalBatch) else {"__msgs__": list(s)}
            for s in self.sources]
        return {"sources": sources, "src": np.array(self.src),
                "cursors": list(self.cursors), "pos": self.pos}

    @classmethod
    def from_state_dict(cls, d: dict, buffers: list) -> "_BatchGroup":
        sources = [
            ArrivalBatch.from_state_dict(s["__batch__"], buffers)
            if "__batch__" in s else list(s["__msgs__"])
            for s in d["sources"]]
        g = cls(sources, d["src"])
        g.cursors = list(d["cursors"])
        g.pos = int(d["pos"])
        return g


def _item_rows(item) -> int:
    """Pending-row count of one shelf/dispatch item."""
    if isinstance(item, ArrivalBatch):
        return item.n
    if isinstance(item, _BatchGroup):
        return item.remaining()
    return 1


class Delivery:
    """A message — or a columnar batch slice — delivered to the cloud
    service at virtual time ``t``.

    Exactly one of ``message`` / ``batch`` is set at construction.  As the
    scalar compat adapter, a single-row batch delivery also answers
    ``.message`` (materialized lazily), so per-message consumers written
    against realtime strategies (threshold 1 ⇒ every delivery is one row)
    keep working unchanged.
    """

    __slots__ = ("t", "batch", "_message")

    def __init__(self, t: float, message: Message | None = None,
                 batch: ArrivalBatch | None = None):
        if (message is None) == (batch is None):
            raise ValueError("Delivery takes exactly one of message/batch")
        self.t = float(t)
        self.batch = batch
        self._message = message

    @property
    def message(self) -> Message | None:
        if self._message is None and self.batch is not None and self.batch.n == 1:
            self._message = self.batch.message(0)
        return self._message

    @property
    def task_id(self) -> int:
        return (self.batch.task_id if self.batch is not None
                else self._message.task_id)

    @property
    def num_messages(self) -> int:
        return self.batch.n if self.batch is not None else 1

    def __repr__(self) -> str:
        what = self.batch if self._message is None else self._message
        return f"Delivery(t={self.t}, {what!r})"


class Shelf:
    """FIFO buffer of pending messages for one task.

    Holds scalar ``Message`` items and ``_BatchGroup`` columnar segments in
    one arrival-ordered deque; ``len()`` and every counter are in *rows*
    (message-equivalents), so threshold strategies and conservation checks
    see identical semantics on both planes.
    """

    def __init__(self, task_id: int):
        self.task_id = task_id
        self._buf: deque = deque()  # Message | _BatchGroup
        self._rows = 0  # pending rows, O(1) (groups make len(_buf) wrong)
        self.total_received = 0
        self.total_dispatched = 0
        self.total_dropped = 0
        # Real traffic accounting (edge->cloud model-update bytes): payloads
        # report their wire size via Message.size_bytes — handle payloads
        # count the stacked-buffer row, not the reference; batches sum their
        # ``nbytes`` column.
        self.total_bytes_received = 0
        self.total_bytes_dispatched = 0

    def put(self, msg: Message) -> None:
        self._buf.append(msg)
        self._rows += 1
        self.total_received += 1
        self.total_bytes_received += msg.size_bytes

    def put_many(self, msgs: Iterable[Message]) -> int:
        msgs = list(msgs)
        self._buf.extend(msgs)
        self._rows += len(msgs)
        self.total_received += len(msgs)
        self.total_bytes_received += sum(m.size_bytes for m in msgs)
        return len(msgs)

    def put_group(self, group: _BatchGroup) -> int:
        n = group.remaining()
        nbytes = sum(
            s.total_bytes if isinstance(s, ArrivalBatch)
            else sum(m.size_bytes for m in s)
            for s in group.sources)
        self._buf.append(group)
        self._rows += n
        self.total_received += n
        self.total_bytes_received += nbytes
        return n

    def take(self, n: int) -> list:
        """Pop up to ``n`` rows in arrival order.  Returns a mixed list of
        ``Message`` items and contiguous ``ArrivalBatch`` slices."""
        out: list = []
        need = int(n)
        while need > 0 and self._buf:
            head = self._buf[0]
            if isinstance(head, _BatchGroup):
                before = head.remaining()
                out.extend(head.take(need))
                took = before - head.remaining()
                need -= took
                self._rows -= took
                if head.remaining() == 0:
                    self._buf.popleft()
            else:
                out.append(self._buf.popleft())
                need -= 1
                self._rows -= 1
        return out

    def __len__(self) -> int:
        return self._rows

    # -- checkpointing hooks (runtime/fault tolerance) ---------------------
    def state_dict(self) -> dict:
        table = _BufferTable()
        buf = [{"__group__": e.state_dict(table)}
               if isinstance(e, _BatchGroup) else e
               for e in self._buf]
        return {
            "task_id": self.task_id,
            "buf": buf,
            "buffers": table.encoded,
            "received": self.total_received,
            "dispatched": self.total_dispatched,
            "dropped": self.total_dropped,
            "bytes_received": self.total_bytes_received,
            "bytes_dispatched": self.total_bytes_dispatched,
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "Shelf":
        s = cls(d["task_id"])
        buffers = _BufferTable.decode(d.get("buffers", []))
        s._buf = deque(
            _BatchGroup.from_state_dict(e["__group__"], buffers)
            if isinstance(e, dict) and "__group__" in e else e
            for e in d["buf"])
        s._rows = sum(_item_rows(e) for e in s._buf)
        s.total_received = d["received"]
        s.total_dispatched = d["dispatched"]
        s.total_dropped = d["dropped"]
        s.total_bytes_received = d.get("bytes_received", 0)
        s.total_bytes_dispatched = d.get("bytes_dispatched", 0)
        return s


class Dispatcher:
    """Per-shelf dispatcher executing one strategy.  Independent per task."""

    def __init__(
        self,
        shelf: Shelf,
        strategy: DispatchStrategy,
        deliver: Callable[[Delivery], None],
        *,
        seed: int = 0,
    ):
        self.shelf = shelf
        self.strategy = strategy
        self.deliver = deliver
        self.rng = np.random.default_rng(seed ^ (shelf.task_id * 0x9E3779B9))
        self._cycle = 0  # accumulated-strategy threshold cursor

    # -- real-time accumulated path ----------------------------------------
    def on_message(self, t: float) -> None:
        """Called by the Sorter after every shelf insertion.

        Drains in a loop: with bulk restores or a shrinking ``threshold_at``
        schedule the shelf can sit multiple thresholds above the waterline —
        a single-batch dispatch would strand that backlog forever.
        """
        if not isinstance(self.strategy, AccumulatedStrategy):
            return
        while len(self.shelf) >= (thr := self.strategy.threshold_at(self._cycle)):
            batch = self.shelf.take(thr)
            self._cycle += 1
            self._send(t, batch, self.strategy.failure_prob, 0)

    def on_messages(self, ts: np.ndarray, t_base: float) -> None:
        """Bulk-insert hook: ``len(ts)`` rows (already shelved, arrival
        order) landed at times ``ts``; dispatch once per threshold crossing.

        Equivalent to calling ``on_message(ts[j])`` after each insertion, but
        O(dispatch events) instead of O(rows) Python work — the batch plane
        rides this unchanged because it only reasons about *counts*.
        Pre-existing backlog above the threshold drains at ``t_base``.
        """
        if not isinstance(self.strategy, AccumulatedStrategy):
            return
        k = len(ts)
        pre = len(self.shelf) - k  # rows buffered before this bulk insert
        arrived = consumed = 0
        while True:
            thr = self.strategy.threshold_at(self._cycle)
            avail = pre + arrived - consumed
            if avail < thr:
                need = thr - avail
                if arrived + need > k:
                    break  # not enough arrivals left to cross the threshold
                arrived += need
                t_evt = float(ts[arrived - 1])
            else:
                t_evt = float(ts[arrived - 1]) if arrived > 0 else t_base
            batch = self.shelf.take(thr)
            self._cycle += 1
            consumed += thr
            self._send(t_evt, batch, self.strategy.failure_prob, 0)

    # -- rule-based path -----------------------------------------------------
    def on_round_complete(self, t: float, clock: "VirtualClock") -> None:
        """Called when a task round completes; schedules rule-based dispatch."""
        strat = self.strategy
        if isinstance(strat, TimeIntervalStrategy):
            strat = strat.discretize(len(self.shelf))
        if not isinstance(strat, TimePointStrategy):
            return
        base = t if strat.relative else 0.0
        for p in strat.points:
            clock.schedule(
                base + p.t,
                lambda pt=p, bt=base: self._dispatch_point(bt + pt.t, pt),
            )

    def _dispatch_point(self, t: float, p) -> None:
        batch = self.shelf.take(p.count)
        self._send(t, batch, p.failure_prob, p.random_discard)

    def _send(
        self, t: float, batch: list, failure_prob: float, random_discard: int
    ) -> None:
        # ``batch`` is a mixed list of Message items and ArrivalBatch slices.
        # Scalar items keep the historical draw-for-draw RNG consumption
        # (restored dispatchers replay identical timelines); batch items
        # draw vectorized masks — one ``random(n)`` per slice.
        if random_discard > 0 and batch:
            n_rows = sum(_item_rows(it) for it in batch)
            k = min(random_discard, n_rows)
            drop = np.zeros(n_rows, bool)
            drop[self.rng.choice(n_rows, size=k, replace=False)] = True
            kept: list = []
            base = 0
            dropped = 0
            for it in batch:
                if isinstance(it, ArrivalBatch):
                    keep = ~drop[base:base + it.n]
                    base += it.n
                    dropped += int(it.n - keep.sum())
                    if keep.all():
                        kept.append(it)
                    elif keep.any():
                        kept.append(it.select(np.flatnonzero(keep)))
                else:
                    if drop[base]:
                        dropped += 1
                    else:
                        kept.append(it)
                    base += 1
            self.shelf.total_dropped += dropped
            batch = kept
        for it in batch:
            if isinstance(it, ArrivalBatch):
                if failure_prob > 0.0 and it.n:
                    keep = self.rng.random(it.n) >= failure_prob
                    self.shelf.total_dropped += int(it.n - keep.sum())
                    if not keep.any():
                        continue
                    if not keep.all():
                        it = it.select(np.flatnonzero(keep))
                self.shelf.total_dispatched += it.n
                self.shelf.total_bytes_dispatched += it.total_bytes
                self.deliver(Delivery(t=t, batch=it))
                continue
            if failure_prob > 0.0 and self.rng.random() < failure_prob:
                self.shelf.total_dropped += 1
                continue
            self.shelf.total_dispatched += 1
            self.shelf.total_bytes_dispatched += it.size_bytes
            self.deliver(Delivery(t=t, message=it))

    # -- checkpointing hooks -----------------------------------------------
    def state_dict(self) -> dict:
        """Dispatch-progress state: the accumulated-strategy threshold cursor
        and the failure/discard RNG stream (so restores don't replay it)."""
        return {"cycle": self._cycle, "rng": self.rng.bit_generator.state}

    def load_state_dict(self, d: dict) -> None:
        self._cycle = int(d["cycle"])
        self.rng.bit_generator.state = d["rng"]


class VirtualClock:
    """Deterministic event loop over virtual seconds."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._tie = itertools.count()
        self.now = 0.0

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now - 1e-12:
            # A past timestamp means some component computed an event time
            # from stale state; clamping keeps production runs monotone,
            # the sanitizer makes the stale computation fail loudly.
            if sanitizers.enabled():
                raise sanitizers.ClockMonotonicityError(
                    f"schedule at t={t!r} is in the virtual past "
                    f"(now={self.now!r})")
            t = self.now
        heapq.heappush(self._heap, (t, next(self._tie), fn))

    def run_until(self, t_end: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            fn()
        self.now = max(self.now, min(t_end, self.now) if t_end == float("inf") else t_end)

    def next_time(self) -> float | None:
        """Timestamp of the earliest pending event (None when idle)."""
        return self._heap[0][0] if self._heap else None

    def run_one(self) -> bool:
        """Execute only the earliest pending event; False when idle.

        Single-stepping hook for event-boundary logic (the task engine's
        admission checks run between events, not between rounds).
        """
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        fn()
        return True

    def advance(self, dt: float) -> None:
        """Run every event inside the next ``dt`` virtual seconds and leave
        ``now`` at the end of the window (serial round accounting)."""
        if dt < 0:
            raise ValueError("cannot advance the clock backwards")
        self.run_until(self.now + dt)

    def pending(self) -> int:
        return len(self._heap)


class DeviceFlow:
    """Facade wiring Sorter → Shelf → Dispatcher → cloud service."""

    def __init__(
        self,
        deliver: Callable[[Delivery], None],
        *,
        clock: VirtualClock | None = None,
        seed: int = 0,
    ):
        self.clock = clock or VirtualClock()
        self._deliver = deliver
        self._shelves: dict[int, Shelf] = {}
        self._dispatchers: dict[int, Dispatcher] = {}
        self._strategies: dict[int, DispatchStrategy] = {}
        self._seed = seed

    # -- Strategy module ------------------------------------------------------
    def register_task(self, task_id: int, strategy: DispatchStrategy) -> None:
        if task_id in self._shelves:
            raise ValueError(f"task {task_id} already registered with DeviceFlow")
        shelf = Shelf(task_id)
        self._shelves[task_id] = shelf
        self._strategies[task_id] = strategy
        self._dispatchers[task_id] = Dispatcher(
            shelf, strategy, self._deliver, seed=self._seed
        )

    # -- Sorter ----------------------------------------------------------------
    def submit(self, msg: Message, t: float | None = None) -> None:
        """Sorter entry point: route by task_id, trigger accumulated dispatch.

        Stamps ``Message.created_t`` at submit time (when not pre-stamped by
        the producer) so delivery latency ``Delivery.t - created_t`` reflects
        real shelf queuing delay.
        """
        t = self.clock.now if t is None else t
        try:
            shelf = self._shelves[msg.task_id]
        except KeyError:
            raise KeyError(
                f"message for unregistered task {msg.task_id}"
            ) from None
        if msg.created_t is None:
            msg = dataclasses.replace(msg, created_t=t)
        shelf.put(msg)
        self._dispatchers[msg.task_id].on_message(t)

    def submit_many(self, msgs: Iterable[Message],
                    ts: "np.ndarray | Sequence[float] | None" = None) -> None:
        """Bulk Sorter fast path: route once per task, not once per message.

        ``ts`` (optional) gives per-message arrival times — e.g. the fleet-
        sampled round durations from the simulation tiers.  Within each task
        messages are shelved in arrival-time order and the accumulated
        dispatcher fires once per threshold crossing, timestamped at the
        message that crossed it — identical semantics to per-message
        ``submit`` in time order, minus the per-message Python overhead.
        """
        msgs = list(msgs)
        if not msgs:
            return
        now = self.clock.now
        if ts is None:
            ts_arr = np.full(len(msgs), now, dtype=float)
        else:
            ts_arr = np.asarray(ts, dtype=float)
            if ts_arr.shape != (len(msgs),):
                raise ValueError("ts must align 1:1 with msgs")
        by_task: dict[int, list[int]] = {}
        for i, m in enumerate(msgs):
            by_task.setdefault(m.task_id, []).append(i)
        for tid, idxs in by_task.items():
            try:
                shelf = self._shelves[tid]
            except KeyError:
                raise KeyError(f"message for unregistered task {tid}") from None
            order = sorted(idxs, key=lambda i: ts_arr[i])
            stamped = []
            for i in order:
                m, t = msgs[i], float(ts_arr[i])
                if m.created_t is None:
                    m = dataclasses.replace(m, created_t=t)
                stamped.append(m)
            shelf.put_many(stamped)
            self._dispatchers[tid].on_messages(ts_arr[order], t_base=now)

    # -- columnar Sorter fast path -------------------------------------------
    def submit_batch(self, batch: ArrivalBatch,
                     ts: "np.ndarray | Sequence[float] | None" = None) -> None:
        """Submit one columnar ``ArrivalBatch`` (one cohort chunk).

        ``ts`` gives per-row arrival times (defaults to ``clock.now`` for
        every row).  Rows are shelved in arrival order without materializing
        per-row objects; unstamped rows (``created_t`` NaN) are stamped with
        their own arrival time — producer stamps, including 0.0, survive.
        """
        self.submit_arrivals([batch], ts=ts)

    def submit_batches(self, batches: "Iterable[ArrivalBatch]",
                       ts: "np.ndarray | Sequence[float] | None" = None
                       ) -> None:
        """Bulk columnar submit: all batches merge into one globally
        arrival-ordered shelf segment per task (``ts`` concatenates the
        per-batch row times, in batch order)."""
        self.submit_arrivals(list(batches), ts=ts)

    def submit_arrivals(self, items: "Sequence[ArrivalBatch | Message]",
                        ts: "np.ndarray | Sequence[float] | None" = None
                        ) -> None:
        """Mixed-plane Sorter entry: columnar batches and scalar messages in
        one call, globally merged by arrival time per task.

        Dispatch-group membership and threshold-crossing timestamps match
        per-message submits in time order exactly; only O(items + dispatch
        events) Python work is done, never O(rows).
        """
        items = [it for it in items if _item_rows(it)]
        if not items:
            return
        sizes = [_item_rows(it) for it in items]
        n_total = sum(sizes)
        now = self.clock.now
        if ts is None:
            ts_arr = np.full(n_total, now, dtype=float)
        else:
            ts_arr = np.asarray(ts, dtype=float)
            if ts_arr.shape != (n_total,):
                raise ValueError(
                    f"ts must align 1:1 with the {n_total} submitted rows")
        offsets = np.concatenate(([0], np.cumsum(sizes)))
        by_task: dict[int, list[int]] = {}
        for i, it in enumerate(items):
            by_task.setdefault(it.task_id, []).append(i)
        for tid, idxs in by_task.items():
            try:
                shelf = self._shelves[tid]
            except KeyError:
                raise KeyError(f"message for unregistered task {tid}") from None
            sources: list = []
            parts_ts: list[np.ndarray] = []
            for i in idxs:
                it = items[i]
                tpart = ts_arr[offsets[i]:offsets[i + 1]]
                if isinstance(it, ArrivalBatch):
                    order = np.argsort(tpart, kind="stable")
                    tpart = tpart[order]
                    sources.append(it.select(order).stamp(tpart))
                else:
                    if it.created_t is None:
                        it = dataclasses.replace(it, created_t=float(tpart[0]))
                    sources.append([it])
                parts_ts.append(tpart)
            cat_ts = np.concatenate(parts_ts)
            src_of = np.concatenate(
                [np.full(len(tp), j, np.int32)
                 for j, tp in enumerate(parts_ts)])
            order = np.argsort(cat_ts, kind="stable")
            shelf.put_group(_BatchGroup(sources, src_of[order]))
            self._dispatchers[tid].on_messages(cat_ts[order], t_base=now)

    # -- round boundaries --------------------------------------------------------
    def round_complete(self, task_id: int, t: float | None = None) -> None:
        t = self.clock.now if t is None else t
        self._dispatchers[task_id].on_round_complete(t, self.clock)

    # -- introspection -------------------------------------------------------------
    def shelf(self, task_id: int) -> Shelf:
        return self._shelves[task_id]

    def run(self, t_end: float = float("inf")) -> None:
        self.clock.run_until(t_end)

    def conservation_ok(self, task_id: int) -> bool:
        """Invariant: received == dispatched + dropped + still-pending.
        All four terms count *rows*, so the invariant spans both planes
        (scalar messages and columnar batch rows) uniformly."""
        s = self._shelves[task_id]
        return s.total_received == s.total_dispatched + s.total_dropped + len(s)

    # -- checkpointing ----------------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            tid: {"shelf": s.state_dict(),
                  "dispatcher": self._dispatchers[tid].state_dict()}
            for tid, s in self._shelves.items()
        }

    def load_state_dict(self, d: dict) -> None:
        for tid, sd in d.items():
            # Accept both the nested format and legacy shelf-only dicts.
            shelf_sd = sd["shelf"] if "shelf" in sd else sd
            shelf = Shelf.from_state_dict(shelf_sd)
            self._shelves[tid] = shelf
            if tid in self._strategies:
                disp = Dispatcher(
                    shelf, self._strategies[tid], self._deliver, seed=self._seed
                )
                if "dispatcher" in sd:
                    disp.load_state_dict(sd["dispatcher"])
                self._dispatchers[tid] = disp
