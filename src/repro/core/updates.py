"""Device-resident update buffers and handle payloads (zero-copy round path).

The batched round engine produces one *stacked* model update per cohort chunk
(pytree leaves shaped ``(rows, ...)``).  The PR 2 engine blocked on
``jax.device_get`` of that stack after every chunk and built one host pytree
per device as the ``Message.payload`` — O(devices x leaves) host transfer and
Python tree traffic per round.  The zero-copy path instead wraps each chunk's
output in an :class:`UpdateBuffer` that *stays on device*, and hands each
message an :class:`UpdateHandle` — a (buffer, row) reference that weighs a
few dozen bytes on the wire between the simulation tiers and the cloud
service.  Aggregation never materializes: ``federation.fused_fedavg_delta``
groups the pending handles by buffer and runs one fused weighted
row-reduction per leaf per buffer (the ``kernels/fed_reduce`` Pallas kernel
on TPU) directly over the device arrays, in a single XLA dispatch.

**Layout.**  Buffer leaves are stored as ``(rows, size)`` 2-D matrices — the
tiers fold the flattening reshape into the cohort jit itself, where XLA
fuses it into the producers (a bitcast, not a copy).  This is deliberate:
the weighted row-reduction on a 2-D operand lowers to a BLAS/MXU matmul,
while reducing an ``(n, ...)``-shaped operand (or reshaping it in-graph)
falls off that path entirely (~40x slower on CPU XLA).  The pytree view
(``treedef`` + per-leaf trailing shapes/dtypes) rides alongside for
materialization and alignment checks.

Handles materialize to host pytrees only where the platform genuinely needs
host data:

* the q_i benchmarking devices (their updates ride next to the full
  ``RoundReport`` telemetry, paper §IV.C);
* checkpointing (``Checkpointer`` calls :func:`materialize_handles` so saved
  state never contains live device references);
* payload transforms that are host-side by nature (e.g. top-k compression in
  ``launch/train.py``).

Buffers are freed by ordinary garbage collection: once the aggregation
service consumes the round's messages and drops them, no handle references
the buffer and the device memory is released.

**Quantized wire mode** (``wire="int8"``).  Quantization is a property of
the wire, not a host-side afterthought: a buffer built with ``wire="int8"``
stores each leaf as an int8 ``(rows, size)`` matrix plus one f32 ``(rows,)``
*scale column* (symmetric per-row, per-leaf scaling — ``scale = max|row| /
127``), produced *inside* the cohort jit by :func:`quantize_rows`.
``row_nbytes`` reports the true quantized footprint (1 byte per element + 4
scale bytes per leaf per row), so ``Shelf.total_bytes_*`` and the
``ArrivalBatch`` nbytes columns show a real ~4x wire cut, not a simulated
one.  Aggregation never dequantizes to a dense f32 stack:
``kernels.fed_reduce.fed_reduce(stack, weights, scales=...)`` folds the
per-row scales into the MXU weight vector (``weights[i]*scales[i]``) and
reduces the int8 rows directly.  Materialization (handles, checkpoints)
dequantizes on the way out.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree


def flatten_rows(stacked: Params) -> Params:
    """Per-leaf ``(rows, ...) -> (rows, size)`` reshape (jit-safe).

    Inside a compiled cohort function this is free — XLA writes the output
    directly in the 2-D layout.  Eagerly it dispatches one reshape per leaf.
    """
    return jax.tree.map(lambda leaf: jnp.reshape(leaf, (leaf.shape[0], -1)),
                        stacked)


def quantize_rows(
    leaves2d: Sequence[jax.Array], *, compute_residual: bool = False
) -> tuple[list[jax.Array], list[jax.Array], list[jax.Array] | None]:
    """Symmetric per-row int8 quantization of ``(rows, size)`` leaves
    (jit-safe — the round engine folds this into the cohort jit).

    Returns ``(q_leaves, scale_columns, residuals)``: int8 ``(rows, size)``
    matrices, f32 ``(rows,)`` scale columns (``max|row| / 127``, floored so
    all-zero rows quantize to zeros instead of NaN), and — when
    ``compute_residual`` — the f32 quantization error ``x - q*scale`` per
    leaf, the error-feedback memory carried into the next round's update.
    """
    qs: list[jax.Array] = []
    scales: list[jax.Array] = []
    residuals: list[jax.Array] = [] if compute_residual else None
    for leaf in leaves2d:
        x = leaf.astype(jnp.float32)
        s = jnp.maximum(jnp.abs(x).max(axis=1), 1e-12) / jnp.float32(127.0)
        q = jnp.clip(jnp.round(x / s[:, None]), -127, 127).astype(jnp.int8)
        qs.append(q)
        scales.append(s)
        if compute_residual:
            residuals.append(x - q.astype(jnp.float32) * s[:, None])
    return qs, scales, residuals


def dequantize_rows(q_leaves: Sequence[jax.Array],
                    scales: Sequence[jax.Array]) -> list[jax.Array]:
    """Inverse of :func:`quantize_rows`: f32 ``(rows, size)`` leaves."""
    return [q.astype(jnp.float32) * s[:, None]
            for q, s in zip(q_leaves, scales)]


def stacked_spec(stacked: Params) -> tuple[Any, list[tuple], list[np.dtype]]:
    """(treedef, per-leaf trailing shapes, per-leaf dtypes) of a stacked tree
    (works on concrete arrays and on ``jax.eval_shape`` results alike)."""
    leaves, treedef = jax.tree.flatten(stacked)
    shapes = [tuple(leaf.shape[1:]) for leaf in leaves]
    dtypes = [np.dtype(leaf.dtype) for leaf in leaves]
    return treedef, shapes, dtypes


class UpdateBuffer:
    """One cohort chunk's stacked model update, resident on device.

    ``leaves2d`` are the update's leaves as ``(rows, size)`` device matrices
    (one row per simulated device); ``treedef``/``shapes``/``dtypes``
    describe the pytree each row materializes to.  The buffer never copies
    device data — it just records the layout so handles can report real
    payload sizes, aggregation can check alignment against the global
    params, and single rows can materialize on demand.

    ``wire="int8"`` marks a *quantized* buffer: ``leaves2d`` are int8 and
    ``scales`` carries one f32 ``(rows,)`` scale column per leaf (see the
    module docstring).  ``shapes``/``dtypes`` still describe what rows
    *materialize* to (dequantized), while ``row_nbytes`` reports the true
    quantized wire footprint.
    """

    __slots__ = ("leaves2d", "treedef", "shapes", "dtypes", "num_rows",
                 "row_nbytes", "wire", "scales", "__weakref__")

    def __init__(self, leaves2d: Sequence[jax.Array], treedef,
                 shapes: Sequence[tuple], dtypes: Sequence[Any], *,
                 wire: str = "f32",
                 scales: "Sequence[jax.Array] | None" = None):
        leaves2d = list(leaves2d)
        if not leaves2d:
            raise ValueError("UpdateBuffer needs at least one leaf")
        n = int(leaves2d[0].shape[0])
        if n < 1:
            raise ValueError("UpdateBuffer needs at least one row")
        self.shapes = [tuple(s) for s in shapes]
        self.dtypes = [np.dtype(d) for d in dtypes]
        for leaf, shape in zip(leaves2d, self.shapes):
            if leaf.ndim != 2 or int(leaf.shape[0]) != n:
                raise ValueError(
                    f"buffer leaves must be (rows, size), got {leaf.shape}")
            if int(leaf.shape[1]) != math.prod(shape):
                raise ValueError(
                    f"leaf carries {leaf.shape[1]} elements but the spec "
                    f"shape {shape} needs {math.prod(shape)}")
        if not (len(leaves2d) == len(self.shapes) == len(self.dtypes)):
            raise ValueError("leaves/shapes/dtypes must align")
        if wire == "f32":
            if scales is not None:
                raise ValueError("scales only apply to wire='int8' buffers")
            self.scales = None
            row_nbytes = sum(math.prod(s) * d.itemsize
                             for s, d in zip(self.shapes, self.dtypes))
        elif wire == "int8":
            if scales is None or len(list(scales)) != len(leaves2d):
                raise ValueError(
                    "wire='int8' needs one (rows,) scale column per leaf")
            scales = list(scales)
            for leaf, s in zip(leaves2d, scales):
                if np.dtype(leaf.dtype) != np.int8:
                    raise ValueError(
                        f"wire='int8' leaves must be int8, got {leaf.dtype}")
                if tuple(s.shape) != (n,):
                    raise ValueError(
                        f"scale column must be ({n},), got {s.shape}")
            self.scales = scales
            # True quantized footprint: 1 byte/element + one f32 scale per
            # leaf per row — the bytes this row actually puts on the wire.
            row_nbytes = sum(math.prod(s) * 1 + np.dtype(sc.dtype).itemsize
                             for s, sc in zip(self.shapes, scales))
        else:
            raise ValueError(f"unknown wire format {wire!r}")
        self.wire = wire
        self.leaves2d = leaves2d
        self.treedef = treedef
        self.num_rows = n
        self.row_nbytes = int(row_nbytes)

    @classmethod
    def from_stacked(cls, stacked: Params) -> "UpdateBuffer":
        """Build from a stacked pytree (leaves ``(rows, ...)``).

        Flattens eagerly — one reshape dispatch per leaf.  The round engine
        avoids even that by folding :func:`flatten_rows` into the cohort jit
        (``run_cohort_zero_copy``); this constructor serves tests and ad-hoc
        callers.
        """
        leaves = jax.tree.leaves(stacked)
        if not leaves:
            raise ValueError("UpdateBuffer needs at least one leaf")
        n = int(leaves[0].shape[0]) if leaves[0].ndim else -1
        if any(leaf.ndim < 1 or int(leaf.shape[0]) != n for leaf in leaves):
            raise ValueError(
                "every stacked leaf must share the leading (row) dimension")
        return cls(jax.tree.leaves(flatten_rows(stacked)),
                   *stacked_spec(stacked))

    @classmethod
    def quantized_from_stacked(cls, stacked: Params) -> "UpdateBuffer":
        """Eagerly quantized ``wire="int8"`` buffer from a stacked pytree.

        The round engine instead fuses :func:`quantize_rows` into the cohort
        jit (``run_cohort_quantized``); this constructor serves tests,
        benchmarks and ad-hoc callers.
        """
        ref = cls.from_stacked(stacked)
        q, s, _ = quantize_rows(ref.leaves2d)
        return cls(q, ref.treedef, ref.shapes, ref.dtypes,
                   wire="int8", scales=s)

    def handle(self, row: int) -> "UpdateHandle":
        return UpdateHandle(self, row)

    def handles(self) -> list["UpdateHandle"]:
        return [UpdateHandle(self, r) for r in range(self.num_rows)]

    def materialize_row(self, row: int) -> Params:
        """One device's update as a host pytree (blocks on this buffer).
        Quantized buffers dequantize on the way out.  Always returns OWNED
        arrays: a host-resident buffer (e.g. shared-memory views from a
        multi-process round) must never leak views into storage that is
        recycled when the buffer is dropped."""
        if not 0 <= row < self.num_rows:
            raise IndexError(f"row {row} out of range [0, {self.num_rows})")
        # Static-bound row extraction for device leaves: eager ``leaf[row]``
        # ships the index to device as a runtime scalar — an implicit h2d
        # that trips the hot-path transfer guard.  ``index_in_dim`` bakes
        # the row into the compiled gather; np.asarray is an explicit d2h.
        def _row(arr):
            if isinstance(arr, jax.Array):
                return np.asarray(
                    jax.lax.index_in_dim(arr, row, keepdims=False))
            return arr[row]

        out = []
        for k, (leaf, shape, dt) in enumerate(
                zip(self.leaves2d, self.shapes, self.dtypes)):
            r = np.asarray(_row(leaf))
            if self.wire == "int8":
                r = r.astype(np.float32) * np.float32(
                    np.asarray(_row(self.scales[k])))
            elif isinstance(leaf, np.ndarray):
                r = r.copy()
            out.append(r.reshape(shape).astype(dt, copy=False))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def materialize(self) -> Params:
        """The whole stacked update as a host pytree (dequantized; owned
        arrays — see ``materialize_row``)."""
        out = []
        for k, (leaf, shape, dt) in enumerate(
                zip(self.leaves2d, self.shapes, self.dtypes)):
            a = np.asarray(leaf)
            if self.wire == "int8":
                a = a.astype(np.float32) * np.asarray(self.scales[k])[:, None]
            elif isinstance(leaf, np.ndarray):
                a = a.copy()
            out.append(a.reshape((self.num_rows,) + shape)
                       .astype(dt, copy=False))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def __repr__(self) -> str:
        return (f"UpdateBuffer(rows={self.num_rows}, "
                f"leaves={len(self.shapes)}, wire={self.wire!r}, "
                f"row_nbytes={self.row_nbytes})")

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot with leaves materialized to host arrays and the treedef
        stored as a container *skeleton* (``unflatten(treedef, 0..n)``) —
        plain dicts/lists/ints only, so columnar engine snapshots with
        in-flight ``ArrivalBatch``es hold no live device references and
        survive pickling."""
        skeleton = jax.tree_util.tree_unflatten(
            self.treedef, list(range(len(self.shapes))))
        # Snapshots must own their arrays: np.asarray of a numpy leaf (e.g.
        # a shared-memory view from a multi-process round) is an alias, and
        # the backing segment may be recycled before the snapshot persists.
        own = lambda a: (np.array(a, copy=True) if isinstance(a, np.ndarray)
                         else np.asarray(a))
        out = {
            "leaves2d": [own(leaf) for leaf in self.leaves2d],
            "skeleton": skeleton,
            "shapes": [tuple(s) for s in self.shapes],
            "dtypes": [str(d) for d in self.dtypes],
            "wire": self.wire,
        }
        if self.wire == "int8":
            # Quantized buffers checkpoint in wire form: int8 leaves + scale
            # columns, NOT a dequantized f32 copy.
            out["scales"] = [own(s) for s in self.scales]
        return out

    @classmethod
    def from_state_dict(cls, d: dict) -> "UpdateBuffer":
        treedef = jax.tree.structure(d["skeleton"])
        wire = d.get("wire", "f32")
        scales = ([jnp.asarray(s) for s in d["scales"]]
                  if wire == "int8" else None)
        return cls([jnp.asarray(leaf) for leaf in d["leaves2d"]], treedef,
                   d["shapes"], [np.dtype(s) for s in d["dtypes"]],
                   wire=wire, scales=scales)


class UpdateHandle:
    """Lightweight ``Message.payload``: a (buffer, row) reference.

    ``nbytes`` reports the row's real model-update size, so DeviceFlow
    traffic accounting sees the bytes a physical device would have uploaded —
    not the size of the reference.
    """

    __slots__ = ("buffer", "row", "__weakref__")

    def __init__(self, buffer: UpdateBuffer, row: int):
        if not 0 <= row < buffer.num_rows:
            raise IndexError(
                f"row {row} out of range [0, {buffer.num_rows})")
        self.buffer = buffer
        self.row = row

    @property
    def nbytes(self) -> int:
        return self.buffer.row_nbytes

    def materialize(self) -> Params:
        return self.buffer.materialize_row(self.row)

    def __repr__(self) -> str:
        return f"UpdateHandle(row={self.row}, nbytes={self.nbytes})"


def materialize_handles(tree: Any) -> Any:
    """Replace every ``UpdateHandle``/``UpdateBuffer`` in ``tree`` with its
    materialized host pytree (checkpointing hook — saved state must not
    contain live device references)."""
    is_ref = lambda x: isinstance(x, (UpdateHandle, UpdateBuffer))
    return jax.tree.map(
        lambda x: x.materialize() if is_ref(x) else x, tree, is_leaf=is_ref)
