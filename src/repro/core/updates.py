"""Device-resident update buffers and handle payloads (zero-copy round path).

The batched round engine produces one *stacked* model update per cohort chunk
(pytree leaves shaped ``(rows, ...)``).  The PR 2 engine blocked on
``jax.device_get`` of that stack after every chunk and built one host pytree
per device as the ``Message.payload`` — O(devices x leaves) host transfer and
Python tree traffic per round.  The zero-copy path instead wraps each chunk's
output in an :class:`UpdateBuffer` that *stays on device*, and hands each
message an :class:`UpdateHandle` — a (buffer, row) reference that weighs a
few dozen bytes on the wire between the simulation tiers and the cloud
service.  Aggregation never materializes: ``federation.fused_fedavg_delta``
groups the pending handles by buffer and runs one fused weighted
row-reduction per leaf per buffer (the ``kernels/fed_reduce`` Pallas kernel
on TPU) directly over the device arrays, in a single XLA dispatch.

**Layout.**  Buffer leaves are stored as ``(rows, size)`` 2-D matrices — the
tiers fold the flattening reshape into the cohort jit itself, where XLA
fuses it into the producers (a bitcast, not a copy).  This is deliberate:
the weighted row-reduction on a 2-D operand lowers to a BLAS/MXU matmul,
while reducing an ``(n, ...)``-shaped operand (or reshaping it in-graph)
falls off that path entirely (~40x slower on CPU XLA).  The pytree view
(``treedef`` + per-leaf trailing shapes/dtypes) rides alongside for
materialization and alignment checks.

Handles materialize to host pytrees only where the platform genuinely needs
host data:

* the q_i benchmarking devices (their updates ride next to the full
  ``RoundReport`` telemetry, paper §IV.C);
* checkpointing (``Checkpointer`` calls :func:`materialize_handles` so saved
  state never contains live device references);
* payload transforms that are host-side by nature (e.g. top-k compression in
  ``launch/train.py``).

Buffers are freed by ordinary garbage collection: once the aggregation
service consumes the round's messages and drops them, no handle references
the buffer and the device memory is released.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree


def flatten_rows(stacked: Params) -> Params:
    """Per-leaf ``(rows, ...) -> (rows, size)`` reshape (jit-safe).

    Inside a compiled cohort function this is free — XLA writes the output
    directly in the 2-D layout.  Eagerly it dispatches one reshape per leaf.
    """
    return jax.tree.map(lambda leaf: jnp.reshape(leaf, (leaf.shape[0], -1)),
                        stacked)


def stacked_spec(stacked: Params) -> tuple[Any, list[tuple], list[np.dtype]]:
    """(treedef, per-leaf trailing shapes, per-leaf dtypes) of a stacked tree
    (works on concrete arrays and on ``jax.eval_shape`` results alike)."""
    leaves, treedef = jax.tree.flatten(stacked)
    shapes = [tuple(leaf.shape[1:]) for leaf in leaves]
    dtypes = [np.dtype(leaf.dtype) for leaf in leaves]
    return treedef, shapes, dtypes


class UpdateBuffer:
    """One cohort chunk's stacked model update, resident on device.

    ``leaves2d`` are the update's leaves as ``(rows, size)`` device matrices
    (one row per simulated device); ``treedef``/``shapes``/``dtypes``
    describe the pytree each row materializes to.  The buffer never copies
    device data — it just records the layout so handles can report real
    payload sizes, aggregation can check alignment against the global
    params, and single rows can materialize on demand.
    """

    __slots__ = ("leaves2d", "treedef", "shapes", "dtypes", "num_rows",
                 "row_nbytes", "__weakref__")

    def __init__(self, leaves2d: Sequence[jax.Array], treedef,
                 shapes: Sequence[tuple], dtypes: Sequence[Any]):
        leaves2d = list(leaves2d)
        if not leaves2d:
            raise ValueError("UpdateBuffer needs at least one leaf")
        n = int(leaves2d[0].shape[0])
        if n < 1:
            raise ValueError("UpdateBuffer needs at least one row")
        self.shapes = [tuple(s) for s in shapes]
        self.dtypes = [np.dtype(d) for d in dtypes]
        for leaf, shape in zip(leaves2d, self.shapes):
            if leaf.ndim != 2 or int(leaf.shape[0]) != n:
                raise ValueError(
                    f"buffer leaves must be (rows, size), got {leaf.shape}")
            if int(leaf.shape[1]) != math.prod(shape):
                raise ValueError(
                    f"leaf carries {leaf.shape[1]} elements but the spec "
                    f"shape {shape} needs {math.prod(shape)}")
        if not (len(leaves2d) == len(self.shapes) == len(self.dtypes)):
            raise ValueError("leaves/shapes/dtypes must align")
        self.leaves2d = leaves2d
        self.treedef = treedef
        self.num_rows = n
        self.row_nbytes = int(sum(
            math.prod(s) * d.itemsize
            for s, d in zip(self.shapes, self.dtypes)))

    @classmethod
    def from_stacked(cls, stacked: Params) -> "UpdateBuffer":
        """Build from a stacked pytree (leaves ``(rows, ...)``).

        Flattens eagerly — one reshape dispatch per leaf.  The round engine
        avoids even that by folding :func:`flatten_rows` into the cohort jit
        (``run_cohort_zero_copy``); this constructor serves tests and ad-hoc
        callers.
        """
        leaves = jax.tree.leaves(stacked)
        if not leaves:
            raise ValueError("UpdateBuffer needs at least one leaf")
        n = int(leaves[0].shape[0]) if leaves[0].ndim else -1
        if any(leaf.ndim < 1 or int(leaf.shape[0]) != n for leaf in leaves):
            raise ValueError(
                "every stacked leaf must share the leading (row) dimension")
        return cls(jax.tree.leaves(flatten_rows(stacked)),
                   *stacked_spec(stacked))

    def handle(self, row: int) -> "UpdateHandle":
        return UpdateHandle(self, row)

    def handles(self) -> list["UpdateHandle"]:
        return [UpdateHandle(self, r) for r in range(self.num_rows)]

    def materialize_row(self, row: int) -> Params:
        """One device's update as a host pytree (blocks on this buffer)."""
        if not 0 <= row < self.num_rows:
            raise IndexError(f"row {row} out of range [0, {self.num_rows})")
        out = [np.asarray(leaf[row]).reshape(shape).astype(dt, copy=False)
               for leaf, shape, dt in zip(self.leaves2d, self.shapes,
                                          self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def materialize(self) -> Params:
        """The whole stacked update as a host pytree."""
        out = [np.asarray(leaf).reshape((self.num_rows,) + shape)
               .astype(dt, copy=False)
               for leaf, shape, dt in zip(self.leaves2d, self.shapes,
                                          self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def __repr__(self) -> str:
        return (f"UpdateBuffer(rows={self.num_rows}, "
                f"leaves={len(self.shapes)}, row_nbytes={self.row_nbytes})")

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        """Snapshot with leaves materialized to host arrays and the treedef
        stored as a container *skeleton* (``unflatten(treedef, 0..n)``) —
        plain dicts/lists/ints only, so columnar engine snapshots with
        in-flight ``ArrivalBatch``es hold no live device references and
        survive pickling."""
        skeleton = jax.tree_util.tree_unflatten(
            self.treedef, list(range(len(self.shapes))))
        return {
            "leaves2d": [np.asarray(leaf) for leaf in self.leaves2d],
            "skeleton": skeleton,
            "shapes": [tuple(s) for s in self.shapes],
            "dtypes": [str(d) for d in self.dtypes],
        }

    @classmethod
    def from_state_dict(cls, d: dict) -> "UpdateBuffer":
        treedef = jax.tree.structure(d["skeleton"])
        return cls([jnp.asarray(leaf) for leaf in d["leaves2d"]], treedef,
                   d["shapes"], [np.dtype(s) for s in d["dtypes"]])


class UpdateHandle:
    """Lightweight ``Message.payload``: a (buffer, row) reference.

    ``nbytes`` reports the row's real model-update size, so DeviceFlow
    traffic accounting sees the bytes a physical device would have uploaded —
    not the size of the reference.
    """

    __slots__ = ("buffer", "row", "__weakref__")

    def __init__(self, buffer: UpdateBuffer, row: int):
        if not 0 <= row < buffer.num_rows:
            raise IndexError(
                f"row {row} out of range [0, {buffer.num_rows})")
        self.buffer = buffer
        self.row = row

    @property
    def nbytes(self) -> int:
        return self.buffer.row_nbytes

    def materialize(self) -> Params:
        return self.buffer.materialize_row(self.row)

    def __repr__(self) -> str:
        return f"UpdateHandle(row={self.row}, nbytes={self.nbytes})"


def materialize_handles(tree: Any) -> Any:
    """Replace every ``UpdateHandle``/``UpdateBuffer`` in ``tree`` with its
    materialized host pytree (checkpointing hook — saved state must not
    contain live device references)."""
    is_ref = lambda x: isinstance(x, (UpdateHandle, UpdateBuffer))
    return jax.tree.map(
        lambda x: x.materialize() if is_ref(x) else x, tree, is_leaf=is_ref)
