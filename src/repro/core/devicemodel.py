"""Calibrated physical-device models (paper §IV.C, Table I).

The paper measures real phones over ADB: current, voltage, CPU%, memory, and
bandwidth, across five task stages.  No phones exist in this environment, so
the Device Simulation tier is backed by *calibrated stochastic device models*:
per-grade stage costs seeded from Table I, with log-normal jitter for
device-to-device and round-to-round variation.  The interface mirrors what
PhoneMgr's measurement loop produces, so the rest of the platform (allocation,
benchmarking-device accounting, GUI-style metric streams) is unchanged.

Two granularities:

* ``DeviceModel`` — one device, sequential NumPy ``Generator`` draws.  Used
  for telemetry streams and single-device inspection.
* ``DeviceFleet`` — the batched round engine's model: ONE vectorized NumPy
  call samples *all* devices × 5 Table-I stages per round.  Randomness is a
  counter-based hash of ``(seed, device_id, draw_counter, lane)`` so each
  device's stream is persistent across rounds (the round-to-round variation
  the docstring promises), deterministic, independent of fleet composition,
  and checkpointable by saving the per-device counters alone.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator

import numpy as np


class Stage(enum.IntEnum):
    """Table I stages."""

    NO_APK = 1  # background cleared, APK not running
    APK_LAUNCH = 2  # APK started, training not begun
    TRAINING = 3
    POST_TRAINING = 4  # training done, APK still active
    APK_CLOSED = 5


@dataclasses.dataclass(frozen=True)
class StageCost:
    power_mah: float  # average power consumption over the stage
    duration_min: float  # average stage duration (minutes)
    comm_kb: float = 0.0  # communication volume (training stage only)


# Table I of the paper, verbatim (High / Low grade, five stages).
TABLE1: dict[str, dict[Stage, StageCost]] = {
    "High": {
        Stage.NO_APK: StageCost(0.24, 0.25),
        Stage.APK_LAUNCH: StageCost(0.51, 0.25),
        Stage.TRAINING: StageCost(0.18, 0.27, 33.10),
        Stage.POST_TRAINING: StageCost(0.37, 0.25),
        Stage.APK_CLOSED: StageCost(0.44, 0.25),
    },
    "Low": {
        Stage.NO_APK: StageCost(1.71, 0.25),
        Stage.APK_LAUNCH: StageCost(1.80, 0.25),
        Stage.TRAINING: StageCost(0.66, 0.36, 33.10),
        Stage.POST_TRAINING: StageCost(1.65, 0.25),
        Stage.APK_CLOSED: StageCost(1.82, 0.25),
    },
}


@dataclasses.dataclass(frozen=True)
class DeviceGrade:
    """A device performance class (paper: High/Low; extensible by model,
    CPU frequency, NPU support...)."""

    name: str
    cpu_cores: int
    memory_gb: float
    # Relative compute throughput (FLOP/s) used to scale training duration
    # with model cost; High-grade phones in Table I are ~0.27/0.36 = 0.75x
    # the Low-grade training time.
    rel_flops: float = 1.0
    stage_costs: dict[Stage, StageCost] = dataclasses.field(default_factory=dict)

    def cost(self, stage: Stage) -> StageCost:
        if stage in self.stage_costs:
            return self.stage_costs[stage]
        base = TABLE1["High" if self.rel_flops >= 1.0 else "Low"]
        return base[stage]


HIGH = DeviceGrade("High", cpu_cores=4, memory_gb=12.0, rel_flops=1.0,
                   stage_costs=TABLE1["High"])
LOW = DeviceGrade("Low", cpu_cores=1, memory_gb=6.0, rel_flops=0.75,
                  stage_costs=TABLE1["Low"])
GRADES = {"High": HIGH, "Low": LOW}


@dataclasses.dataclass(frozen=True)
class MetricSample:
    """One PhoneMgr telemetry sample (paper §IV.C retrieval set)."""

    t: float
    stage: Stage
    current_ua: float
    voltage_mv: float
    cpu_pct: float
    mem_kb: float
    bandwidth_b: float


@dataclasses.dataclass(frozen=True)
class RoundReport:
    """Per-round, per-stage outcome for one simulated physical device."""

    device_id: int
    grade: str
    round_idx: int
    stage_power_mah: dict[Stage, float]
    stage_duration_min: dict[Stage, float]
    comm_kb: float

    @property
    def total_duration_min(self) -> float:
        return sum(self.stage_duration_min.values())

    @property
    def total_power_mah(self) -> float:
        return sum(self.stage_power_mah.values())


class DeviceModel:
    """Stochastic emulation of one benchmarking device."""

    def __init__(self, device_id: int, grade: DeviceGrade, *, seed: int = 0,
                 jitter: float = 0.08):
        self.device_id = device_id
        self.grade = grade
        self.jitter = jitter
        self.rng = np.random.default_rng(seed ^ (device_id * 0x51ED2705))

    def _noisy(self, mean: float) -> float:
        if mean == 0.0:
            return 0.0
        sigma = math.sqrt(math.log(1.0 + self.jitter**2))
        return float(mean * self.rng.lognormal(-0.5 * sigma**2, sigma))

    def run_round(self, round_idx: int, *, train_cost_scale: float = 1.0
                  ) -> RoundReport:
        """Simulate the five Table-I stages of one training round.

        ``train_cost_scale`` scales the TRAINING stage with the model's
        computational cost (relative to the paper's LR/Avazu workload).
        """
        powers, durs, comm = {}, {}, 0.0
        for stage in Stage:
            c = self.grade.cost(stage)
            scale = train_cost_scale if stage == Stage.TRAINING else 1.0
            powers[stage] = self._noisy(c.power_mah * scale)
            durs[stage] = self._noisy(c.duration_min * scale)
            if stage == Stage.TRAINING:
                comm = self._noisy(c.comm_kb)
        return RoundReport(
            device_id=self.device_id,
            grade=self.grade.name,
            round_idx=round_idx,
            stage_power_mah=powers,
            stage_duration_min=durs,
            comm_kb=comm,
        )

    def telemetry(self, report: RoundReport, hz: float = 1.0) -> Iterator[MetricSample]:
        """Emit PhoneMgr-style samples over the round (for the metrics DB)."""
        t = 0.0
        voltage_mv = 3950.0
        for stage in Stage:
            dur_s = report.stage_duration_min[stage] * 60.0
            n = max(1, int(dur_s * hz))
            # Convert stage mAh over duration to average current in uA.
            dur_h = max(report.stage_duration_min[stage] / 60.0, 1e-9)
            cur_ua = report.stage_power_mah[stage] / dur_h * 1000.0
            cpu = {Stage.TRAINING: 90.0, Stage.APK_LAUNCH: 35.0}.get(stage, 5.0)
            mem = 2.2e5 if stage in (Stage.APK_LAUNCH, Stage.TRAINING,
                                     Stage.POST_TRAINING) else 4.0e4
            bw = report.comm_kb * 1024.0 / n if stage == Stage.TRAINING else 0.0
            for i in range(n):
                yield MetricSample(
                    t=t + (i + 1) / hz,
                    stage=stage,
                    current_ua=self._noisy(cur_ua),
                    voltage_mv=self._noisy(voltage_mv),
                    cpu_pct=min(100.0, self._noisy(cpu)),
                    mem_kb=self._noisy(mem),
                    bandwidth_b=bw,
                )
            t += dur_s


# --------------------------------------------------------------------------- #
# Vectorized fleet model (batched round engine)
# --------------------------------------------------------------------------- #

_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_M2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 wrap-around is intentional)."""
    with np.errstate(over="ignore"):
        z = x + _SM_GAMMA
        z = (z ^ (z >> np.uint64(30))) * _SM_M1
        z = (z ^ (z >> np.uint64(27))) * _SM_M2
        return z ^ (z >> np.uint64(31))


def _counter_normals(seed: int, device_ids: np.ndarray, counters: np.ndarray,
                     n_lanes: int) -> np.ndarray:
    """Standard normals of shape ``(n_devices, n_lanes)`` from a stateless
    hash of (seed, device_id, per-device counter, lane) via Box–Muller."""
    dev = device_ids.astype(np.uint64)[:, None]
    ctr = counters.astype(np.uint64)[:, None]
    lane = np.arange(2 * n_lanes, dtype=np.uint64)[None, :]
    with np.errstate(over="ignore"):
        base = _splitmix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                           ^ dev * np.uint64(0x51ED2705))
        base = _splitmix64(base ^ ctr * np.uint64(0xD1B54A32D192ED03))
        h = _splitmix64(base ^ lane * np.uint64(0x8CB92BA72F3D8DD7))
    # (0, 1) uniforms from the top 53 bits; +0.5 keeps u strictly positive.
    u = ((h >> np.uint64(11)).astype(np.float64) + 0.5) * 2.0**-53
    u1, u2 = u[:, :n_lanes], u[:, n_lanes:]
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


@dataclasses.dataclass(frozen=True)
class FleetRoundSample:
    """One vectorized round of Table-I samples for a whole device cohort.

    Arrays are indexed ``[device, stage]`` with stages in ``list(Stage)``
    order; ``device_ids[i]`` names the device behind row ``i``.
    """

    device_ids: np.ndarray  # (n,) int
    round_idx: int
    grade: str
    stage_power_mah: np.ndarray  # (n, num_stages)
    stage_duration_min: np.ndarray  # (n, num_stages)
    comm_kb: np.ndarray  # (n,)

    @property
    def total_duration_min(self) -> np.ndarray:
        return self.stage_duration_min.sum(axis=1)

    @property
    def total_power_mah(self) -> np.ndarray:
        return self.stage_power_mah.sum(axis=1)

    def arrival_offsets_s(self) -> np.ndarray:
        """Per-device round completion offsets in seconds — the arrival-time
        contract consumed by DeviceFlow (message ``created_t`` stamps)."""
        return self.total_duration_min * 60.0

    def stage_duration_s(self, stage: Stage) -> np.ndarray:
        """Per-device duration of one Table-I stage in seconds (the
        measurement feed of ``calibration.RuntimeCalibrator``)."""
        return self.stage_duration_min[:, list(Stage).index(stage)] * 60.0

    def report(self, i: int) -> RoundReport:
        """Materialize row ``i`` as a classic per-device ``RoundReport``."""
        stages = list(Stage)
        return RoundReport(
            device_id=int(self.device_ids[i]),
            grade=self.grade,
            round_idx=self.round_idx,
            stage_power_mah={s: float(self.stage_power_mah[i, j])
                             for j, s in enumerate(stages)},
            stage_duration_min={s: float(self.stage_duration_min[i, j])
                                for j, s in enumerate(stages)},
            comm_kb=float(self.comm_kb[i]),
        )


class DeviceFleet:
    """Vectorized stochastic model of a whole device cohort of one grade.

    Owns persistent per-device RNG state (a draw counter per device): calling
    ``run_round`` twice yields *different* jittered samples per device, and a
    checkpointed fleet resumes its streams exactly.
    """

    def __init__(self, grade: DeviceGrade, num_devices: int, *, seed: int = 0,
                 jitter: float = 0.08, first_device_id: int = 0):
        if num_devices < 0:
            raise ValueError("num_devices must be non-negative")
        self.grade = grade
        self.seed = seed
        self.jitter = jitter
        self._first_id = first_device_id
        self.device_ids = np.arange(
            first_device_id, first_device_id + num_devices, dtype=np.int64)
        self._counters = np.zeros(num_devices, dtype=np.int64)
        stages = list(Stage)
        self._mean_power = np.array(
            [grade.cost(s).power_mah for s in stages])
        self._mean_dur = np.array(
            [grade.cost(s).duration_min for s in stages])
        self._mean_comm = float(grade.cost(Stage.TRAINING).comm_kb)
        self._train_col = stages.index(Stage.TRAINING)

    def __len__(self) -> int:
        return len(self.device_ids)

    def grow(self, num_devices: int) -> None:
        """Extend the fleet to ``num_devices`` devices (contiguous ids).

        Existing devices keep their draw counters; new ones start fresh —
        safe because each device's stream depends only on its own id/counter,
        never on fleet composition.
        """
        extra = num_devices - len(self.device_ids)
        if extra <= 0:
            return
        self.device_ids = np.arange(
            self._first_id, self._first_id + num_devices, dtype=np.int64)
        self._counters = np.concatenate(
            [self._counters, np.zeros(extra, dtype=np.int64)])

    def rows_for(self, device_ids: np.ndarray) -> np.ndarray:
        """Map device ids to fleet row indices (grows the fleet if needed)."""
        ids = np.asarray(device_ids, dtype=np.int64)
        if ids.size and int(ids.max()) >= self._first_id + len(self.device_ids):
            self.grow(int(ids.max()) - self._first_id + 1)
        return ids - self._first_id

    def run_round(self, round_idx: int, *, train_cost_scale: float = 1.0,
                  rows: np.ndarray | None = None) -> FleetRoundSample:
        """Sample all devices (or the ``rows`` subset) × 5 stages at once."""
        rows = np.arange(len(self.device_ids)) if rows is None else np.asarray(rows)
        ids = self.device_ids[rows]
        n_stages = len(self._mean_power)
        normals = _counter_normals(
            self.seed, ids, self._counters[rows], 2 * n_stages + 1)
        self._counters[rows] += 1
        sigma = math.sqrt(math.log(1.0 + self.jitter**2))
        jit = np.exp(-0.5 * sigma**2 + sigma * normals)  # mean-preserving
        scale = np.ones(n_stages)
        scale[self._train_col] = train_cost_scale
        power = self._mean_power * scale * jit[:, :n_stages]
        dur = self._mean_dur * scale * jit[:, n_stages:2 * n_stages]
        comm = self._mean_comm * jit[:, 2 * n_stages]
        # _noisy semantics: zero-mean costs stay exactly zero.
        power[:, self._mean_power == 0.0] = 0.0
        dur[:, self._mean_dur == 0.0] = 0.0
        if self._mean_comm == 0.0:
            comm = np.zeros_like(comm)
        return FleetRoundSample(
            device_ids=ids, round_idx=round_idx, grade=self.grade.name,
            stage_power_mah=power, stage_duration_min=dur, comm_kb=comm)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> dict:
        return {"counters": self._counters.copy(), "seed": self.seed,
                "jitter": self.jitter, "device_ids": self.device_ids.copy()}

    def load_state_dict(self, d: dict) -> None:
        """Adopt the saved fleet layout wholesale — restoring into a freshly
        constructed (possibly empty, lazily-grown) fleet must work."""
        counters = np.asarray(d["counters"], dtype=np.int64)
        ids = np.asarray(d["device_ids"], dtype=np.int64)
        if counters.shape != ids.shape:
            raise ValueError("corrupt fleet state_dict: counters/ids mismatch")
        if "seed" in d and d["seed"] != self.seed:
            raise ValueError(
                f"fleet seed mismatch: checkpoint {d['seed']} vs {self.seed} "
                "— restored streams would diverge")
        self.device_ids = ids.copy()
        self._counters = counters.copy()
        self.jitter = float(d.get("jitter", self.jitter))
        if len(ids):
            self._first_id = int(ids[0])


def training_duration_s(grade: DeviceGrade, *, train_cost_scale: float = 1.0) -> float:
    """Deterministic mean round duration (beta_i input to the allocator)."""
    return grade.cost(Stage.TRAINING).duration_min * 60.0 * train_cost_scale


def startup_duration_s(grade: DeviceGrade) -> float:
    """Mean framework startup time (lambda_i input to the allocator)."""
    return grade.cost(Stage.APK_LAUNCH).duration_min * 60.0
