"""Calibrated physical-device models (paper §IV.C, Table I).

The paper measures real phones over ADB: current, voltage, CPU%, memory, and
bandwidth, across five task stages.  No phones exist in this environment, so
the Device Simulation tier is backed by *calibrated stochastic device models*:
per-grade stage costs seeded from Table I, with log-normal jitter for
device-to-device and round-to-round variation.  The interface mirrors what
PhoneMgr's measurement loop produces, so the rest of the platform (allocation,
benchmarking-device accounting, GUI-style metric streams) is unchanged.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterator

import numpy as np


class Stage(enum.IntEnum):
    """Table I stages."""

    NO_APK = 1  # background cleared, APK not running
    APK_LAUNCH = 2  # APK started, training not begun
    TRAINING = 3
    POST_TRAINING = 4  # training done, APK still active
    APK_CLOSED = 5


@dataclasses.dataclass(frozen=True)
class StageCost:
    power_mah: float  # average power consumption over the stage
    duration_min: float  # average stage duration (minutes)
    comm_kb: float = 0.0  # communication volume (training stage only)


# Table I of the paper, verbatim (High / Low grade, five stages).
TABLE1: dict[str, dict[Stage, StageCost]] = {
    "High": {
        Stage.NO_APK: StageCost(0.24, 0.25),
        Stage.APK_LAUNCH: StageCost(0.51, 0.25),
        Stage.TRAINING: StageCost(0.18, 0.27, 33.10),
        Stage.POST_TRAINING: StageCost(0.37, 0.25),
        Stage.APK_CLOSED: StageCost(0.44, 0.25),
    },
    "Low": {
        Stage.NO_APK: StageCost(1.71, 0.25),
        Stage.APK_LAUNCH: StageCost(1.80, 0.25),
        Stage.TRAINING: StageCost(0.66, 0.36, 33.10),
        Stage.POST_TRAINING: StageCost(1.65, 0.25),
        Stage.APK_CLOSED: StageCost(1.82, 0.25),
    },
}


@dataclasses.dataclass(frozen=True)
class DeviceGrade:
    """A device performance class (paper: High/Low; extensible by model,
    CPU frequency, NPU support...)."""

    name: str
    cpu_cores: int
    memory_gb: float
    # Relative compute throughput (FLOP/s) used to scale training duration
    # with model cost; High-grade phones in Table I are ~0.27/0.36 = 0.75x
    # the Low-grade training time.
    rel_flops: float = 1.0
    stage_costs: dict[Stage, StageCost] = dataclasses.field(default_factory=dict)

    def cost(self, stage: Stage) -> StageCost:
        if stage in self.stage_costs:
            return self.stage_costs[stage]
        base = TABLE1["High" if self.rel_flops >= 1.0 else "Low"]
        return base[stage]


HIGH = DeviceGrade("High", cpu_cores=4, memory_gb=12.0, rel_flops=1.0,
                   stage_costs=TABLE1["High"])
LOW = DeviceGrade("Low", cpu_cores=1, memory_gb=6.0, rel_flops=0.75,
                  stage_costs=TABLE1["Low"])
GRADES = {"High": HIGH, "Low": LOW}


@dataclasses.dataclass(frozen=True)
class MetricSample:
    """One PhoneMgr telemetry sample (paper §IV.C retrieval set)."""

    t: float
    stage: Stage
    current_ua: float
    voltage_mv: float
    cpu_pct: float
    mem_kb: float
    bandwidth_b: float


@dataclasses.dataclass(frozen=True)
class RoundReport:
    """Per-round, per-stage outcome for one simulated physical device."""

    device_id: int
    grade: str
    round_idx: int
    stage_power_mah: dict[Stage, float]
    stage_duration_min: dict[Stage, float]
    comm_kb: float

    @property
    def total_duration_min(self) -> float:
        return sum(self.stage_duration_min.values())

    @property
    def total_power_mah(self) -> float:
        return sum(self.stage_power_mah.values())


class DeviceModel:
    """Stochastic emulation of one benchmarking device."""

    def __init__(self, device_id: int, grade: DeviceGrade, *, seed: int = 0,
                 jitter: float = 0.08):
        self.device_id = device_id
        self.grade = grade
        self.jitter = jitter
        self.rng = np.random.default_rng(seed ^ (device_id * 0x51ED2705))

    def _noisy(self, mean: float) -> float:
        if mean == 0.0:
            return 0.0
        sigma = math.sqrt(math.log(1.0 + self.jitter**2))
        return float(mean * self.rng.lognormal(-0.5 * sigma**2, sigma))

    def run_round(self, round_idx: int, *, train_cost_scale: float = 1.0
                  ) -> RoundReport:
        """Simulate the five Table-I stages of one training round.

        ``train_cost_scale`` scales the TRAINING stage with the model's
        computational cost (relative to the paper's LR/Avazu workload).
        """
        powers, durs, comm = {}, {}, 0.0
        for stage in Stage:
            c = self.grade.cost(stage)
            scale = train_cost_scale if stage == Stage.TRAINING else 1.0
            powers[stage] = self._noisy(c.power_mah * scale)
            durs[stage] = self._noisy(c.duration_min * scale)
            if stage == Stage.TRAINING:
                comm = self._noisy(c.comm_kb)
        return RoundReport(
            device_id=self.device_id,
            grade=self.grade.name,
            round_idx=round_idx,
            stage_power_mah=powers,
            stage_duration_min=durs,
            comm_kb=comm,
        )

    def telemetry(self, report: RoundReport, hz: float = 1.0) -> Iterator[MetricSample]:
        """Emit PhoneMgr-style samples over the round (for the metrics DB)."""
        t = 0.0
        voltage_mv = 3950.0
        for stage in Stage:
            dur_s = report.stage_duration_min[stage] * 60.0
            n = max(1, int(dur_s * hz))
            # Convert stage mAh over duration to average current in uA.
            dur_h = max(report.stage_duration_min[stage] / 60.0, 1e-9)
            cur_ua = report.stage_power_mah[stage] / dur_h * 1000.0
            cpu = {Stage.TRAINING: 90.0, Stage.APK_LAUNCH: 35.0}.get(stage, 5.0)
            mem = 2.2e5 if stage in (Stage.APK_LAUNCH, Stage.TRAINING,
                                     Stage.POST_TRAINING) else 4.0e4
            bw = report.comm_kb * 1024.0 / n if stage == Stage.TRAINING else 0.0
            for i in range(n):
                yield MetricSample(
                    t=t + (i + 1) / hz,
                    stage=stage,
                    current_ua=self._noisy(cur_ua),
                    voltage_mv=self._noisy(voltage_mv),
                    cpu_pct=min(100.0, self._noisy(cpu)),
                    mem_kb=self._noisy(mem),
                    bandwidth_b=bw,
                )
            t += dur_s


def training_duration_s(grade: DeviceGrade, *, train_cost_scale: float = 1.0) -> float:
    """Deterministic mean round duration (beta_i input to the allocator)."""
    return grade.cost(Stage.TRAINING).duration_min * 60.0 * train_cost_scale


def startup_duration_s(grade: DeviceGrade) -> float:
    """Mean framework startup time (lambda_i input to the allocator)."""
    return grade.cost(Stage.APK_LAUNCH).duration_min * 60.0
