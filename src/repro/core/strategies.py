"""DeviceFlow message-dispatching strategies (paper §V.B).

Two families:

* **Real-time accumulated dispatching** — fires *during* a round: once the
  shelf has accumulated ``n`` messages they are dispatched immediately.  ``n``
  cycles through a user sequence (paper §VI.C.2 example ``[20, 100, 50]``);
  ``n = 1`` degenerates to real-time transmission.  Each message independently
  fails with probability ``p`` (device-dropout simulation).

* **Rule-based dispatching** — fires *after* a round completes:

  - *specific time-point*: user-defined ``(time, count)`` pairs, relative to
    round end or absolute; per-point failure probability and/or random discard.
  - *specific time-interval*: a user-defined rate curve ``y = f(t)`` is
    discretized by (1) equating total pending messages to the curve's AUC,
    (2) choosing a tick small enough that no single tick exceeds the dispatch
    capacity limit (e.g. 700 msg/s single-threaded), and (3) assigning each
    tick the message count proportional to its AUC share — reducing the
    interval mechanism to the time-point mechanism (paper §V.B).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.traffic_curves import TrafficCurve


@dataclasses.dataclass(frozen=True)
class DispatchPoint:
    """One scheduled dispatch: ``count`` messages at time ``t``."""

    t: float
    count: int
    failure_prob: float = 0.0
    random_discard: int = 0

    def __post_init__(self):
        if self.count < 0 or self.random_discard < 0:
            raise ValueError("count/discard must be non-negative")
        if not 0.0 <= self.failure_prob <= 1.0:
            raise ValueError("failure_prob in [0, 1]")


@dataclasses.dataclass(frozen=True)
class AccumulatedStrategy:
    """Real-time accumulated dispatching with cycling thresholds."""

    thresholds: tuple[int, ...] = (1,)
    failure_prob: float = 0.0

    def __post_init__(self):
        if not self.thresholds or any(n < 1 for n in self.thresholds):
            raise ValueError("thresholds must be positive")
        if not 0.0 <= self.failure_prob <= 1.0:
            raise ValueError("failure_prob in [0, 1]")

    def threshold_at(self, cycle: int) -> int:
        return self.thresholds[cycle % len(self.thresholds)]


@dataclasses.dataclass(frozen=True)
class TimePointStrategy:
    """Rule-based dispatching at user-defined time points."""

    points: tuple[DispatchPoint, ...]
    relative: bool = True  # times measured from round end (else absolute)

    def __post_init__(self):
        if not self.points:
            raise ValueError("need at least one dispatch point")
        ts = [p.t for p in self.points]
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("dispatch points must be time-ordered")


@dataclasses.dataclass(frozen=True)
class TimeIntervalStrategy:
    """Rule-based dispatching along a user-defined rate curve."""

    curve: TrafficCurve
    interval: float  # real-time span the curve domain is scaled onto (seconds)
    relative: bool = True
    capacity_per_second: float = 700.0  # paper: single-thread dispatch limit
    failure_prob: float = 0.0
    random_discard_per_tick: int = 0

    def __post_init__(self):
        if self.interval <= 0:
            raise ValueError("interval must be positive")
        if self.capacity_per_second <= 0:
            raise ValueError("capacity must be positive")
        if not 0.0 <= self.failure_prob <= 1.0:
            raise ValueError("failure_prob in [0, 1]")

    def discretize(self, total_messages: int) -> TimePointStrategy:
        pts = discretize_curve(
            self.curve,
            total_messages,
            self.interval,
            self.capacity_per_second,
        )
        return TimePointStrategy(
            points=tuple(
                DispatchPoint(
                    t=t,
                    count=c,
                    failure_prob=self.failure_prob,
                    random_discard=self.random_discard_per_tick,
                )
                for t, c in pts
            ),
            relative=self.relative,
        )


def discretize_curve(
    curve: TrafficCurve,
    total_messages: int,
    interval: float,
    capacity_per_second: float,
    *,
    min_ticks: int = 64,
    samples_per_tick: int = 16,
) -> list[tuple[float, int]]:
    """Paper §V.B discretization: AUC-proportional per-tick counts.

    1. Scale the curve domain ``[lo, hi]`` onto ``[0, interval]`` seconds.
    2. Pick a tick ``dt`` such that the *peak*-rate tick never exceeds the
       per-tick capacity ``capacity_per_second * dt`` and there are at least
       ``min_ticks`` ticks ("the interval is sufficiently small").
    3. Each tick gets ``round(total * AUC_tick / AUC_total)`` messages
       (largest-remainder rounding so the counts sum exactly to ``total``),
       stamped at the tick's start.
    """
    if total_messages < 0:
        raise ValueError("total_messages must be non-negative")
    if total_messages == 0:
        return []
    span = curve.hi - curve.lo
    # Dense sampling of the curve for integration (trapezoid).
    n_dense = max(min_ticks * samples_per_tick, 4096)
    ts = np.linspace(curve.lo, curve.hi, n_dense + 1)
    ys = np.array([curve(float(t)) for t in ts])
    auc_total = float(np.trapezoid(ys, ts))
    if auc_total <= 0.0:
        raise ValueError("curve has zero area — cannot allocate messages")
    peak = float(ys.max())
    # Peak messages per second after scaling mass to total/interval:
    # rate(t_real) = total * f(t_curve) / (auc_total * interval/span) ... but
    # capacity constrains messages-per-tick: n_tick <= capacity * dt.  With
    # AUC-proportional allocation, max tick mass ~= total * peak * dt_curve /
    # auc_total, and dt_real = dt_curve * interval / span.
    # => need total * peak * dt_curve / auc_total <= capacity * dt_curve * interval/span
    # dt cancels: a *rate* requirement; if violated no dt helps -> densify until
    # per-tick count fits capacity*dt_real >= 1 granularity.
    # Resolution: enough ticks that the curve is well sampled, few enough
    # that per-tick counts stay meaningful.  NOTE densification cannot fix a
    # capacity violation — both the per-tick mass and the per-tick budget
    # scale linearly with dt — so when peak demand exceeds the dispatcher's
    # capacity we clip at capacity and spill forward (paper Fig. 10(b): "the
    # cloud service actually receives the full messages over a period
    # spanning the designated time point and subsequent certain intervals").
    n_ticks = int(min(max(min_ticks, 64), 512))
    edges = np.linspace(curve.lo, curve.hi, n_ticks + 1)
    masses = []
    for a, b in zip(edges[:-1], edges[1:]):
        sel = (ts >= a - 1e-15) & (ts <= b + 1e-15)
        tt, yy = ts[sel], ys[sel]
        if len(tt) < 2:
            tt = np.array([a, b])
            yy = np.array([curve(float(a)), curve(float(b))])
        masses.append(float(np.trapezoid(yy, tt)))
    masses = np.array(masses)
    raw = total_messages * masses / masses.sum()
    dt_real = interval / n_ticks
    # Largest-remainder rounding.
    floors = np.floor(raw).astype(int)
    rem = total_messages - int(floors.sum())
    order = np.argsort(-(raw - floors))
    counts = floors.copy()
    counts[order[:rem]] += 1
    # Clip to capacity; spill overflow forward in time.
    cap = max(1, int(math.floor(capacity_per_second * dt_real)))
    spill = 0
    out: list[tuple[float, int]] = []
    for i, c in enumerate(counts):
        c = int(c) + spill
        send = min(c, cap)
        spill = c - send
        t_real = i * dt_real
        if send > 0:
            out.append((t_real, send))
    extra_i = len(counts)
    while spill > 0:  # tail spill past the nominal interval
        send = min(spill, cap)
        out.append((extra_i * dt_real, send))
        spill -= send
        extra_i += 1
    return out


DispatchStrategy = AccumulatedStrategy | TimePointStrategy | TimeIntervalStrategy
