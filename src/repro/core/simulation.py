"""Logical & Device simulation tiers (paper §III.B, §IV.A).

*Logical Simulation* in the paper launches Ray actors on k8s nodes, each actor
sequentially simulating several devices.  The TPU-native adaptation is a
**vectorized client engine**: client-local training is expressed as a pure
function of (client params, client batch) and executed for a whole *cohort* of
clients at once via ``jax.vmap`` — sharded over the mesh ``data`` axis with
``shard_map`` when a mesh is supplied.  One TPU step simulates hundreds of
devices; cohorts iterate to reach arbitrary population sizes (the paper's
"each actor sequentially simulates multiple devices").

*Device Simulation* is backed by the calibrated device models of
``core.devicemodel`` (see DESIGN.md §2 for why physical phones cannot exist
here) and — crucially for the Fig. 6 reproduction — executes the *same
operator flow through a numerically different backend* (bf16 accumulation vs
f32), mirroring the paper's PyMNN-vs-C++-MNN operator discrepancy.

**Batched round engine.**  Both tiers execute whole cohorts per dispatch:
``DeviceTier.run_cohort`` vmaps the (bf16-backend) local step over a chunk of
devices, so a 1k-device round costs a handful of XLA dispatches instead of 1k
``jax.jit`` calls; the behavioral side is one vectorized ``DeviceFleet``
sample of all devices × 5 Table-I stages.  ``HybridSimulation.run_round``
derives per-device arrival times from those sampled round durations when the
caller doesn't pass ``arrival_times``, stamps them into ``Message.created_t``,
and feeds DeviceFlow through the bulk ``submit_many`` Sorter path — the
arrival-time contract between the tiers and DeviceFlow.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import DeviceFlow, Message
from repro.core.devicemodel import (
    DeviceFleet,
    DeviceGrade,
    FleetRoundSample,
    RoundReport,
)

Params = Any
Batch = Any

# A client-local training function: (params, batch, rng) -> (params, metrics).
LocalTrainFn = Callable[[Params, Batch, jax.Array], tuple[Params, dict]]


@dataclasses.dataclass(frozen=True)
class CohortResult:
    """Results of one cohort of simultaneously simulated clients."""

    params: Params  # stacked: leaf shape (cohort, ...)
    metrics: dict  # stacked metrics, e.g. loss per client
    num_samples: jax.Array  # (cohort,)


def _stack_params(params: Params, n: int) -> Params:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), params)


class LogicalTier:
    """Vectorized logical-simulation tier."""

    def __init__(
        self,
        local_train: LocalTrainFn,
        *,
        mesh: jax.sharding.Mesh | None = None,
        data_axis: str = "data",
        cohort_size: int = 64,
        dtype: Any = jnp.float32,
    ):
        self.local_train = local_train
        self.mesh = mesh
        self.data_axis = data_axis
        self.cohort_size = cohort_size
        self.dtype = dtype
        self._compiled = None

    def _build(self):
        vmapped = jax.vmap(self.local_train, in_axes=(0, 0, 0))
        if self.mesh is not None:
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map

            spec = P(self.data_axis)
            vmapped = shard_map(
                vmapped,
                mesh=self.mesh,
                in_specs=(spec, spec, spec),
                out_specs=(spec, spec),
                check_rep=False,
            )
        return jax.jit(vmapped)

    def run_cohort(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rng: jax.Array,
        num_samples: np.ndarray,
    ) -> CohortResult:
        if self._compiled is None:
            self._compiled = self._build()
        n = int(jax.tree.leaves(batches)[0].shape[0])
        cast = lambda x: x.astype(self.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
        stacked = jax.tree.map(cast, _stack_params(global_params, n))
        rngs = jax.random.split(rng, n)
        params, metrics = self._compiled(stacked, batches, rngs)
        return CohortResult(
            params=params, metrics=metrics, num_samples=jnp.asarray(num_samples)
        )


class DeviceTier:
    """Calibrated device-simulation tier.

    Runs the same local computation through a numerically distinct backend
    dtype (the paper's operator discrepancy) and charges virtual time/energy
    via a persistent ``DeviceFleet`` — one vectorized Table-I sample per
    round, per-device RNG streams that *survive* across rounds (a fresh
    ``DeviceModel`` per call would restart every device's jitter every round).

    ``run_cohort`` is the batched execution path: one vmapped XLA dispatch
    simulates a whole chunk of devices; ``run_device`` remains as the
    single-device view (same numerics, same fleet).
    """

    def __init__(
        self,
        local_train: LocalTrainFn,
        grade: DeviceGrade,
        *,
        dtype: Any = jnp.bfloat16,
        seed: int = 0,
        train_cost_scale: float = 1.0,
        cohort_size: int = 256,
        jitter: float = 0.08,
    ):
        self.grade = grade
        self.dtype = dtype
        self.seed = seed
        self.train_cost_scale = train_cost_scale
        self.cohort_size = cohort_size
        self.local_train = local_train
        self._jit = jax.jit(self._device_step)
        self._vjit = jax.jit(self._cohort_step)
        self.fleet = DeviceFleet(grade, 0, seed=seed, jitter=jitter)
        self.reports: list[RoundReport] = []

    # -- numerically-distinct backend: cast in, compute, cast back ---------
    def _device_step(self, global_params: Params, batch: Batch, rng: jax.Array):
        cast_in = lambda x: (
            x.astype(self.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
        )
        p = jax.tree.map(cast_in, global_params)
        b = jax.tree.map(cast_in, batch)
        new_p, metrics = self.local_train(p, b, rng)
        new_p = jax.tree.map(
            lambda x, ref: x.astype(ref.dtype)
            if jnp.issubdtype(ref.dtype, jnp.floating)
            else x,
            new_p,
            global_params,
        )
        return new_p, metrics

    def _cohort_step(self, global_params: Params, batches: Batch,
                     rngs: jax.Array):
        n = jax.tree.leaves(batches)[0].shape[0]
        stacked = _stack_params(global_params, n)
        return jax.vmap(self._device_step, in_axes=(0, 0, 0))(
            stacked, batches, rngs)

    def run_cohort(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rngs: jax.Array,  # (cohort, key)
    ) -> tuple[Params, dict]:
        """One XLA dispatch simulating a whole device cohort (bf16 backend)."""
        return self._vjit(global_params, batches, rngs)

    def sample_round(self, device_ids: np.ndarray, round_idx: int
                     ) -> "FleetRoundSample":
        """Vectorized Table-I behavior sample for ``device_ids`` this round."""
        rows = self.fleet.rows_for(np.asarray(device_ids))
        return self.fleet.run_round(
            round_idx, train_cost_scale=self.train_cost_scale, rows=rows)

    def run_device(
        self,
        device_id: int,
        global_params: Params,
        batch: Batch,
        rng: jax.Array,
        round_idx: int,
        *,
        benchmark: bool = False,
    ) -> tuple[Params, dict, RoundReport | None]:
        new_p, metrics = self._jit(global_params, batch, rng)
        report = None
        if benchmark:
            sample = self.sample_round(np.array([device_id]), round_idx)
            report = sample.report(0)
            self.reports.append(report)
        return new_p, metrics, report


@dataclasses.dataclass
class FederatedRoundOutcome:
    num_logical: int
    num_physical: int
    messages: list[Message]
    reports: list[RoundReport]
    arrival_times: np.ndarray | None = None  # per-message virtual times


class HybridSimulation:
    """Drives one federated round across both tiers and feeds DeviceFlow.

    This is the composition point of the paper: allocation decides the split,
    both tiers execute the same operator flow, results become DeviceFlow
    messages whose *dispatch* to the cloud follows the task's traffic strategy.
    """

    def __init__(
        self,
        logical: LogicalTier,
        device: DeviceTier,
        deviceflow: DeviceFlow | None = None,
    ):
        self.logical = logical
        self.device = device
        self.deviceflow = deviceflow

    def run_round(
        self,
        task_id: int,
        round_idx: int,
        global_params: Params,
        client_batches: Batch,  # leaves (num_clients, ...)
        num_samples: np.ndarray,  # (num_clients,)
        num_logical: int,
        rng: jax.Array,
        *,
        benchmark_devices: int = 0,
        arrival_times: np.ndarray | None = None,
    ) -> FederatedRoundOutcome:
        n_total = int(jax.tree.leaves(client_batches)[0].shape[0])
        if not 0 <= num_logical <= n_total:
            raise ValueError("num_logical out of range")
        take = lambda tree, sl: jax.tree.map(lambda x: x[sl], tree)
        msgs: list[Message] = []
        reports: list[RoundReport] = []

        def emit(host_params, lo, hi):
            for j in range(hi - lo):
                msgs.append(
                    Message(
                        task_id=task_id,
                        device_id=lo + j,
                        round_idx=round_idx,
                        payload=jax.tree.map(lambda x: x[j], host_params),
                        num_samples=int(num_samples[lo + j]),
                    )
                )

        # Logical tier: vectorized cohorts (chunked by cohort_size).
        idx = 0
        while idx < num_logical:
            hi = min(idx + self.logical.cohort_size, num_logical)
            rng, sub = jax.random.split(rng)
            res = self.logical.run_cohort(
                global_params,
                take(client_batches, slice(idx, hi)),
                sub,
                num_samples[idx:hi],
            )
            emit(jax.device_get(res.params), idx, hi)
            idx = hi

        # Device tier: vectorized cohorts through the bf16 backend — one
        # vmapped dispatch per chunk instead of one jit call per device.
        idx = num_logical
        while idx < n_total:
            hi = min(idx + self.device.cohort_size, n_total)
            rng, sub = jax.random.split(rng)
            new_p, _ = self.device.run_cohort(
                global_params,
                take(client_batches, slice(idx, hi)),
                jax.random.split(sub, hi - idx),
            )
            emit(jax.device_get(new_p), idx, hi)
            idx = hi

        # Behavioral side: one vectorized fleet sample covers every simulated
        # device this round — Table-I durations become arrival times, and the
        # benchmarking subset materializes full RoundReports (paper §IV.C).
        sample: FleetRoundSample | None = None
        if n_total > 0:
            sample = self.device.sample_round(np.arange(n_total), round_idx)
        n_bench = min(benchmark_devices, n_total - num_logical)
        for k in range(n_bench):
            rep = sample.report(num_logical + k)
            reports.append(rep)
            self.device.reports.append(rep)

        if arrival_times is None and sample is not None:
            base = 0.0 if self.deviceflow is None else self.deviceflow.clock.now
            arrival_times = base + sample.arrival_offsets_s()

        if self.deviceflow is not None:
            self.deviceflow.submit_many(msgs, ts=arrival_times)
            # The round ends when the slowest device reports, not at clock.now.
            t_end = (float(np.max(arrival_times))
                     if arrival_times is not None and len(arrival_times)
                     else None)
            self.deviceflow.round_complete(task_id, t=t_end)
        return FederatedRoundOutcome(
            num_logical=num_logical,
            num_physical=n_total - num_logical,
            messages=msgs,
            reports=reports,
            arrival_times=arrival_times,
        )
