"""Logical & Device simulation tiers (paper §III.B, §IV.A).

*Logical Simulation* in the paper launches Ray actors on k8s nodes, each actor
sequentially simulating several devices.  The TPU-native adaptation is a
**vectorized client engine**: client-local training is expressed as a pure
function of (client params, client batch) and executed for a whole *cohort* of
clients at once via ``jax.vmap`` — sharded over the mesh ``data`` axis with
``shard_map`` when a mesh is supplied.  One TPU step simulates hundreds of
devices; cohorts iterate to reach arbitrary population sizes (the paper's
"each actor sequentially simulates multiple devices").

*Device Simulation* is backed by the calibrated device models of
``core.devicemodel`` (see DESIGN.md §2 for why physical phones cannot exist
here) and — crucially for the Fig. 6 reproduction — executes the *same
operator flow through a numerically different backend* (bf16 accumulation vs
f32), mirroring the paper's PyMNN-vs-C++-MNN operator discrepancy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import DeviceFlow, Message
from repro.core.devicemodel import DeviceGrade, DeviceModel, RoundReport

Params = Any
Batch = Any

# A client-local training function: (params, batch, rng) -> (params, metrics).
LocalTrainFn = Callable[[Params, Batch, jax.Array], tuple[Params, dict]]


@dataclasses.dataclass(frozen=True)
class CohortResult:
    """Results of one cohort of simultaneously simulated clients."""

    params: Params  # stacked: leaf shape (cohort, ...)
    metrics: dict  # stacked metrics, e.g. loss per client
    num_samples: jax.Array  # (cohort,)


def _stack_params(params: Params, n: int) -> Params:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), params)


class LogicalTier:
    """Vectorized logical-simulation tier."""

    def __init__(
        self,
        local_train: LocalTrainFn,
        *,
        mesh: jax.sharding.Mesh | None = None,
        data_axis: str = "data",
        cohort_size: int = 64,
        dtype: Any = jnp.float32,
    ):
        self.local_train = local_train
        self.mesh = mesh
        self.data_axis = data_axis
        self.cohort_size = cohort_size
        self.dtype = dtype
        self._compiled = None

    def _build(self):
        vmapped = jax.vmap(self.local_train, in_axes=(0, 0, 0))
        if self.mesh is not None:
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map

            spec = P(self.data_axis)
            vmapped = shard_map(
                vmapped,
                mesh=self.mesh,
                in_specs=(spec, spec, spec),
                out_specs=(spec, spec),
                check_rep=False,
            )
        return jax.jit(vmapped)

    def run_cohort(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rng: jax.Array,
        num_samples: np.ndarray,
    ) -> CohortResult:
        if self._compiled is None:
            self._compiled = self._build()
        n = int(jax.tree.leaves(batches)[0].shape[0])
        cast = lambda x: x.astype(self.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
        stacked = jax.tree.map(cast, _stack_params(global_params, n))
        rngs = jax.random.split(rng, n)
        params, metrics = self._compiled(stacked, batches, rngs)
        return CohortResult(
            params=params, metrics=metrics, num_samples=jnp.asarray(num_samples)
        )


class DeviceTier:
    """Calibrated device-simulation tier.

    Runs the same local computation (optionally through a numerically distinct
    backend dtype to reproduce the paper's operator discrepancy) and charges
    virtual time/energy via ``DeviceModel``.
    """

    def __init__(
        self,
        local_train: LocalTrainFn,
        grade: DeviceGrade,
        *,
        dtype: Any = jnp.bfloat16,
        seed: int = 0,
        train_cost_scale: float = 1.0,
    ):
        self.grade = grade
        self.dtype = dtype
        self.seed = seed
        self.train_cost_scale = train_cost_scale
        self.local_train = local_train
        self._jit = jax.jit(local_train)
        self.reports: list[RoundReport] = []

    def run_device(
        self,
        device_id: int,
        global_params: Params,
        batch: Batch,
        rng: jax.Array,
        round_idx: int,
        *,
        benchmark: bool = False,
    ) -> tuple[Params, dict, RoundReport | None]:
        # Numerically-distinct backend: cast to device dtype, compute, cast back.
        cast_in = lambda x: (
            x.astype(self.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
        )
        p = jax.tree.map(cast_in, global_params)
        b = jax.tree.map(cast_in, batch)
        new_p, metrics = self._jit(p, b, rng)
        new_p = jax.tree.map(
            lambda x, ref: x.astype(ref.dtype)
            if jnp.issubdtype(ref.dtype, jnp.floating)
            else x,
            new_p,
            global_params,
        )
        report = None
        if benchmark:
            model = DeviceModel(device_id, self.grade, seed=self.seed)
            report = model.run_round(round_idx, train_cost_scale=self.train_cost_scale)
            self.reports.append(report)
        return new_p, metrics, report


@dataclasses.dataclass
class FederatedRoundOutcome:
    num_logical: int
    num_physical: int
    messages: list[Message]
    reports: list[RoundReport]


class HybridSimulation:
    """Drives one federated round across both tiers and feeds DeviceFlow.

    This is the composition point of the paper: allocation decides the split,
    both tiers execute the same operator flow, results become DeviceFlow
    messages whose *dispatch* to the cloud follows the task's traffic strategy.
    """

    def __init__(
        self,
        logical: LogicalTier,
        device: DeviceTier,
        deviceflow: DeviceFlow | None = None,
    ):
        self.logical = logical
        self.device = device
        self.deviceflow = deviceflow

    def run_round(
        self,
        task_id: int,
        round_idx: int,
        global_params: Params,
        client_batches: Batch,  # leaves (num_clients, ...)
        num_samples: np.ndarray,  # (num_clients,)
        num_logical: int,
        rng: jax.Array,
        *,
        benchmark_devices: int = 0,
        arrival_times: np.ndarray | None = None,
    ) -> FederatedRoundOutcome:
        n_total = int(jax.tree.leaves(client_batches)[0].shape[0])
        if not 0 <= num_logical <= n_total:
            raise ValueError("num_logical out of range")
        take = lambda tree, sl: jax.tree.map(lambda x: x[sl], tree)
        msgs: list[Message] = []
        reports: list[RoundReport] = []

        # Logical tier: one vectorized cohort (chunked by cohort_size).
        idx = 0
        while idx < num_logical:
            hi = min(idx + self.logical.cohort_size, num_logical)
            rng, sub = jax.random.split(rng)
            res = self.logical.run_cohort(
                global_params,
                take(client_batches, slice(idx, hi)),
                sub,
                num_samples[idx:hi],
            )
            host_params = jax.device_get(res.params)
            for j in range(hi - idx):
                msgs.append(
                    Message(
                        task_id=task_id,
                        device_id=idx + j,
                        round_idx=round_idx,
                        payload=jax.tree.map(lambda x: x[j], host_params),
                        num_samples=int(num_samples[idx + j]),
                    )
                )
            idx = hi

        # Device tier: per-device execution with calibrated models.
        for j in range(num_logical, n_total):
            rng, sub = jax.random.split(rng)
            new_p, _, rep = self.device.run_device(
                j,
                global_params,
                take(client_batches, j),
                sub,
                round_idx,
                benchmark=(j - num_logical) < benchmark_devices,
            )
            if rep is not None:
                reports.append(rep)
            msgs.append(
                Message(
                    task_id=task_id,
                    device_id=j,
                    round_idx=round_idx,
                    payload=jax.device_get(new_p),
                    num_samples=int(num_samples[j]),
                )
            )

        if self.deviceflow is not None:
            for i, m in enumerate(msgs):
                t = None if arrival_times is None else float(arrival_times[i])
                self.deviceflow.submit(m, t=t)
            self.deviceflow.round_complete(task_id)
        return FederatedRoundOutcome(
            num_logical=num_logical,
            num_physical=n_total - num_logical,
            messages=msgs,
            reports=reports,
        )
