"""Grade-partitioned logical & device simulation tiers (paper §III.B, §IV).

*Logical Simulation* in the paper launches Ray actors on k8s nodes, each actor
sequentially simulating several devices.  The TPU-native adaptation is a
**vectorized client engine**: client-local training is expressed as a pure
function of (client params, client batch) and executed for a whole *cohort* of
clients at once via ``jax.vmap`` — sharded over the mesh ``data`` axis with
``shard_map`` when a mesh is supplied (both tiers support the mesh path, so
device cohorts shard across hosts exactly like logical ones).

*Device Simulation* is backed by the calibrated device models of
``core.devicemodel`` (see DESIGN.md §2 for why physical phones cannot exist
here) and — crucially for the Fig. 6 reproduction — executes the *same
operator flow through a numerically different backend* (bf16 accumulation vs
f32), mirroring the paper's PyMNN-vs-C++-MNN operator discrepancy.

**Grade-partitioned round engine.**  The §IV.B allocator splits *each device
grade* between the tiers; the engine mirrors that shape.  A ``RoundPlan``
consumes an ``AllocationResult`` directly — one ``GradePlanEntry`` per grade
carrying the allocator's (x_i logical, y_i physical, q_i benchmarking) split —
and ``HybridSimulation`` holds one ``DeviceTier`` (with its own ``DeviceFleet``)
*per grade*::

    sim = HybridSimulation(logical, tiers={"High": ..., "Low": ...},
                           deviceflow=flow)
    plan = RoundPlan.from_allocation(solve_allocation(specs, runtimes), specs)
    outcome = sim.run_plan_round(task_id, rnd, params, plan,
                                 grade_batches, grade_num_samples, rng)

``run_plan_round`` executes each grade's logical and device cohorts (one
vmapped XLA dispatch per chunk), samples each grade's fleet once (all devices
× 5 Table-I stages), merges the per-grade sampled durations into DeviceFlow
arrival times through the bulk ``submit_many`` Sorter path, materializes
``RoundReport``s for exactly the q_i benchmarking devices the allocator
excluded, and reports a per-grade makespan breakdown in
``FederatedRoundOutcome.per_grade``.  Passing a ``RuntimeCalibrator`` feeds
the sampled durations back into allocation (measured, not hand-coded,
``GradeRuntime``s — the paper's calibration loop).

**Zero-copy round pipeline.**  Model updates are device-resident end-to-end:
cohort outputs stay stacked on device (one ``core.updates.UpdateBuffer`` per
chunk), messages carry ``UpdateHandle`` payloads, and aggregation runs one
fused weighted reduction per buffer (``kernels/fed_reduce``) instead of
walking per-device host pytrees.  Host materialization happens only for the
q_i benchmarking devices and at checkpoint time.  Construct
``HybridSimulation(..., zero_copy=False)`` for the host-materializing
reference path.

The legacy single-grade ``run_round(..., num_logical=...)`` path is kept as a
thin wrapper over the same per-grade execution helper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizers
from repro.analysis.sanitizers import hot_path
from repro.core.allocation import AllocationResult
from repro.core.deviceflow import ArrivalBatch, DeviceFlow, Message
from repro.core.updates import (
    UpdateBuffer,
    UpdateHandle,
    flatten_rows,
    quantize_rows,
    stacked_spec,
)
from repro.core.devicemodel import (
    DeviceFleet,
    DeviceGrade,
    FleetRoundSample,
    RoundReport,
)
from repro.core.task import GradeSpec

Params = Any
Batch = Any

# A client-local training function: (params, batch, rng) -> (params, metrics).
LocalTrainFn = Callable[[Params, Batch, jax.Array], tuple[Params, dict]]


@dataclasses.dataclass(frozen=True)
class CohortResult:
    """Results of one cohort of simultaneously simulated clients."""

    params: Params  # stacked: leaf shape (cohort, ...)
    metrics: dict  # stacked metrics, e.g. loss per client
    num_samples: jax.Array  # (cohort,)


def _stack_params(params: Params, n: int) -> Params:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), params)


def _shard_over_data(fn, mesh, data_axis: str, n_in: int, n_out: int):
    """Wrap a vmapped fn so every arg/output shards over the mesh data axis."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    spec = P(data_axis)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(spec,) * n_in,
        out_specs=(spec,) * n_out if n_out > 1 else spec,
        check_rep=False,
    )


@functools.partial(jax.jit, static_argnums=(0, 1))
def _zeros_f32(n: int, sz: int) -> jax.Array:
    # Jitted so the fill constant is baked into the compiled program: an
    # eager ``jnp.zeros`` broadcasts a host scalar — an implicit h2d that
    # trips the hot-path transfer guard (analysis.sanitizers).
    return jnp.zeros((n, sz), jnp.float32)


class _ZeroCopyCohortMixin:
    """Shared zero-copy machinery for the simulation tiers.

    ``run_cohort_zero_copy`` compiles the tier's cohort function with
    ``updates.flatten_rows`` folded onto the output: each update leaf is
    written ONCE, directly in the ``(rows, size)`` ``UpdateBuffer`` layout
    XLA can reduce at matmul speed (an in-graph reshape at aggregation time
    falls off the BLAS/MXU path).  The pytree spec rows materialize to is
    recovered by ``jax.eval_shape`` (abstract — nothing executes) and cached
    per global-params signature.
    """

    _cohort_fn = None  # set by subclasses: (params, batches, rngs) -> (tree, metrics)

    def _zero_copy_machinery(self):
        if getattr(self, "_compiled_zc", None) is None:
            fn = self._cohort_fn

            def zc_fn(global_params, batches, rngs):
                params, metrics = fn(global_params, batches, rngs)
                return flatten_rows(params), metrics

            def zc_fn_recycle(scratch, global_params, batches, rngs):
                # ``scratch`` (a retired round's buffer leaves) is donated:
                # XLA aliases the new update leaves onto its pages, so
                # steady-state rounds allocate nothing buffer-sized — no
                # fresh-page (mmap+zero) cost per round.  ``keep_unused``
                # is REQUIRED: the default jit prunes arguments the traced
                # function never reads, which would silently drop the
                # donation (no aliasing, no invalidation).
                del scratch
                return zc_fn(global_params, batches, rngs)

            self._compiled_zc = jax.jit(zc_fn)
            self._compiled_zc_recycle = jax.jit(
                zc_fn_recycle, donate_argnums=(0,), keep_unused=True)
            self._spec_cache = {}
        return self._compiled_zc

    def run_cohort_zero_copy(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rngs: jax.Array,  # (cohort, key)
        recycle: UpdateBuffer | None = None,
    ) -> tuple[UpdateBuffer, dict]:
        """One fused dispatch producing the chunk's device-resident
        ``UpdateBuffer`` (rows in device order) and stacked metrics.

        ``recycle`` donates a retired buffer of the same layout so the new
        update is written in place of it (see ``HybridSimulation``
        ``recycle_buffers``); the donated buffer's arrays are invalidated.
        """
        compiled = self._zero_copy_machinery()
        spec = self._update_spec(global_params, batches, rngs)
        treedef, shapes, dtypes = spec
        if recycle is not None and not (
                recycle.num_rows == int(rngs.shape[0])
                and recycle.treedef == treedef
                and recycle.shapes == list(shapes)
                and recycle.dtypes == list(dtypes)):
            recycle = None  # layout changed: fall back to fresh allocation
        if recycle is not None:
            donated_leaves = tuple(recycle.leaves2d)
            if sanitizers.enabled():
                # After this dispatch the retired buffer's leaves are dead
                # XLA buffers; poison the object so any late access raises
                # UseAfterDonateError instead of failing deep in XLA.
                sanitizers.poison_donated(recycle)
            leaves2d, metrics = self._compiled_zc_recycle(
                donated_leaves, global_params, batches, rngs)
        else:
            leaves2d, metrics = compiled(global_params, batches, rngs)
        return UpdateBuffer(jax.tree.leaves(leaves2d), *spec), metrics

    def _quantized_machinery(self):
        if getattr(self, "_compiled_q", None) is None:
            fn = self._cohort_fn

            def q_fn(global_params, batches, rngs, residuals):
                # Quantization is fused into the cohort jit: the update
                # leaves are written ONCE, as int8 (rows, size) matrices +
                # f32 (rows,) scale columns — the quantized wire format —
                # and the dense f32 stack never round-trips through HBM.
                # ``residuals`` (None, or one f32 (rows, size) array per
                # leaf) is the error-feedback memory: the previous round's
                # quantization error joins this round's update before
                # quantizing, and the new error is returned to be carried
                # device-resident into the next round.
                params, metrics = fn(global_params, batches, rngs)
                leaves = jax.tree.leaves(flatten_rows(params))
                if residuals is not None:
                    leaves = [l.astype(jnp.float32) + r
                              for l, r in zip(leaves, residuals)]
                q, s, res = quantize_rows(
                    leaves, compute_residual=residuals is not None)
                return tuple(q), tuple(s), res, metrics

            # One jit covers both EF variants: passing residuals=None (an
            # empty pytree) traces the residual-free graph.
            self._compiled_q = jax.jit(q_fn)
        return self._compiled_q

    def run_cohort_quantized(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rngs: jax.Array,  # (cohort, key)
        *,
        residual: "tuple | None" = None,
        error_feedback: bool = True,
    ) -> "tuple[UpdateBuffer, dict, tuple | None]":
        """One fused dispatch producing the chunk's *quantized*
        ``UpdateBuffer`` (``wire="int8"``: int8 leaves + per-row scale
        columns) and, with ``error_feedback``, the device-resident residual
        tuple to carry into this chunk's next round (pass it back as
        ``residual``).  Round 0 (or a layout change) starts from zero
        residuals."""
        self._zero_copy_machinery()  # ensures the spec cache exists
        compiled = self._quantized_machinery()
        spec = self._update_spec(global_params, batches, rngs)
        treedef, shapes, dtypes = spec
        n = int(rngs.shape[0])
        if error_feedback:
            sizes = [int(np.prod(s)) if s else 1 for s in shapes]
            if residual is None or not (
                    len(residual) == len(sizes)
                    and all(tuple(r.shape) == (n, sz)
                            for r, sz in zip(residual, sizes))):
                residual = tuple(_zeros_f32(n, sz) for sz in sizes)
        else:
            residual = None
        q, s, res, metrics = compiled(global_params, batches, rngs, residual)
        buf = UpdateBuffer(list(q), treedef, shapes, dtypes,
                           wire="int8", scales=list(s))
        return buf, metrics, (tuple(res) if error_feedback else None)

    def _update_spec(self, global_params, batches, rngs):
        key = (jax.tree.structure(global_params),) + tuple(
            (tuple(leaf.shape), str(leaf.dtype))
            for leaf in jax.tree.leaves(global_params))
        spec = self._spec_cache.get(key)
        if spec is None:
            out = jax.eval_shape(self._cohort_fn, global_params, batches, rngs)
            spec = stacked_spec(out[0])
            self._spec_cache[key] = spec
        return spec

class LogicalTier(_ZeroCopyCohortMixin):
    """Vectorized logical-simulation tier."""

    def __init__(
        self,
        local_train: LocalTrainFn,
        *,
        mesh: jax.sharding.Mesh | None = None,
        data_axis: str = "data",
        cohort_size: int = 64,
        dtype: Any = jnp.float32,
    ):
        self.local_train = local_train
        self.mesh = mesh
        self.data_axis = data_axis
        self.cohort_size = cohort_size
        self.dtype = dtype
        self._compiled = None

        vmapped = jax.vmap(self.local_train, in_axes=(0, 0, 0))
        if self.mesh is not None:
            vmapped = _shard_over_data(vmapped, self.mesh, self.data_axis, 3, 2)

        def cohort(global_params, batches, rngs):
            # Stack INSIDE the compiled function: XLA fuses the cohort
            # broadcast into the consumers instead of materializing an
            # O(cohort x params) copy of the global params per chunk (the
            # eager broadcast was the round engine's largest hidden
            # allocation at big-model scale).
            n = jax.tree.leaves(batches)[0].shape[0]
            cast = lambda x: (x.astype(self.dtype)
                              if jnp.issubdtype(x.dtype, jnp.floating) else x)
            stacked = jax.tree.map(cast, _stack_params(global_params, n))
            return vmapped(stacked, batches, rngs)

        self._cohort_fn = cohort

    def run_cohort(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rng: jax.Array,
        num_samples: np.ndarray,
    ) -> CohortResult:
        if self._compiled is None:
            self._compiled = jax.jit(self._cohort_fn)
        n = int(jax.tree.leaves(batches)[0].shape[0])
        rngs = jax.random.split(rng, n)
        params, metrics = self._compiled(global_params, batches, rngs)
        return CohortResult(
            params=params, metrics=metrics, num_samples=jnp.asarray(num_samples)
        )


class DeviceTier(_ZeroCopyCohortMixin):
    """Calibrated device-simulation tier for ONE device grade.

    Runs the same local computation through a numerically distinct backend
    dtype (the paper's operator discrepancy) and charges virtual time/energy
    via a persistent ``DeviceFleet`` — one vectorized Table-I sample per
    round, per-device RNG streams that *survive* across rounds (a fresh
    ``DeviceModel`` per call would restart every device's jitter every round).

    ``run_cohort`` is the batched execution path: one vmapped XLA dispatch
    simulates a whole chunk of devices, sharded over the mesh ``data`` axis
    with ``shard_map`` when a ``mesh`` is supplied (same contract as
    ``LogicalTier``); ``run_device`` remains as the single-device view (same
    numerics, same fleet).
    """

    def __init__(
        self,
        local_train: LocalTrainFn,
        grade: DeviceGrade,
        *,
        dtype: Any = jnp.bfloat16,
        seed: int = 0,
        train_cost_scale: float = 1.0,
        cohort_size: int = 256,
        jitter: float = 0.08,
        mesh: jax.sharding.Mesh | None = None,
        data_axis: str = "data",
    ):
        self.grade = grade
        self.dtype = dtype
        self.seed = seed
        self.train_cost_scale = train_cost_scale
        self.cohort_size = cohort_size
        self.local_train = local_train
        self.mesh = mesh
        self.data_axis = data_axis
        self._jit = jax.jit(self._device_step)
        self._vjit = None
        self.fleet = DeviceFleet(grade, 0, seed=seed, jitter=jitter)
        self.reports: list[RoundReport] = []

        vmapped = jax.vmap(self._device_step, in_axes=(0, 0, 0))
        if self.mesh is not None:
            vmapped = _shard_over_data(vmapped, self.mesh, self.data_axis, 3, 2)

        def cohort(global_params, batches, rngs):
            n = jax.tree.leaves(batches)[0].shape[0]
            return vmapped(_stack_params(global_params, n), batches, rngs)

        self._cohort_fn = cohort

    # -- numerically-distinct backend: cast in, compute, cast back ---------
    def _device_step(self, global_params: Params, batch: Batch, rng: jax.Array):
        cast_in = lambda x: (
            x.astype(self.dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
        )
        p = jax.tree.map(cast_in, global_params)
        b = jax.tree.map(cast_in, batch)
        new_p, metrics = self.local_train(p, b, rng)
        new_p = jax.tree.map(
            lambda x, ref: x.astype(ref.dtype)
            if jnp.issubdtype(ref.dtype, jnp.floating)
            else x,
            new_p,
            global_params,
        )
        return new_p, metrics

    def run_cohort(
        self,
        global_params: Params,
        batches: Batch,  # leaves shaped (cohort, ...)
        rngs: jax.Array,  # (cohort, key)
    ) -> tuple[Params, dict]:
        """One XLA dispatch simulating a whole device cohort (bf16 backend)."""
        if self._vjit is None:
            self._vjit = jax.jit(self._cohort_fn)
        return self._vjit(global_params, batches, rngs)

    def sample_round(self, device_ids: np.ndarray, round_idx: int
                     ) -> "FleetRoundSample":
        """Vectorized Table-I behavior sample for ``device_ids`` this round."""
        rows = self.fleet.rows_for(np.asarray(device_ids))
        return self.fleet.run_round(
            round_idx, train_cost_scale=self.train_cost_scale, rows=rows)

    def run_device(
        self,
        device_id: int,
        global_params: Params,
        batch: Batch,
        rng: jax.Array,
        round_idx: int,
        *,
        benchmark: bool = False,
    ) -> tuple[Params, dict, RoundReport | None]:
        new_p, metrics = self._jit(global_params, batch, rng)
        report = None
        if benchmark:
            sample = self.sample_round(np.array([device_id]), round_idx)
            report = sample.report(0)
            self.reports.append(report)
        return new_p, metrics, report


# --------------------------------------------------------------------------- #
# Round plans — the allocator's split as an executable object
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GradePlanEntry:
    """One grade's share of a round: the allocator's (x_i, y_i, q_i)."""

    grade: str
    num_logical: int  # x_i — devices emulated on the logical tier
    num_physical: int  # N_i - q_i - x_i — devices on the device tier
    num_benchmarking: int = 0  # q_i — measured devices (device tier, reports)

    def __post_init__(self):
        if min(self.num_logical, self.num_physical, self.num_benchmarking) < 0:
            raise ValueError("plan entry counts must be non-negative")

    @property
    def num_devices(self) -> int:
        """Total devices of this grade simulated in the round (x + y + q)."""
        return self.num_logical + self.num_physical + self.num_benchmarking


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Executable per-grade split of one federated round.

    Built directly from the §IV.B allocator's output — ``from_allocation``
    carries each grade's benchmarking count q_i over from its ``GradeSpec``,
    so the devices producing ``RoundReport``s are exactly the ones the
    allocator excluded from the split.
    """

    entries: tuple[GradePlanEntry, ...]

    def __post_init__(self):
        seen = set()
        for e in self.entries:
            if e.grade in seen:
                raise ValueError(f"duplicate grade {e.grade!r} in plan")
            seen.add(e.grade)

    @classmethod
    def from_allocation(cls, result: AllocationResult,
                        specs: Sequence[GradeSpec]) -> "RoundPlan":
        by_grade = {s.grade: s for s in specs}
        entries = []
        for ga in result.per_grade:
            spec = by_grade.get(ga.grade)
            entries.append(GradePlanEntry(
                grade=ga.grade,
                num_logical=ga.logical_devices,
                num_physical=ga.physical_devices,
                num_benchmarking=(spec.benchmarking_devices
                                  if spec is not None else 0),
            ))
        return cls(tuple(entries))

    def entry(self, grade: str) -> GradePlanEntry:
        for e in self.entries:
            if e.grade == grade:
                return e
        raise KeyError(f"grade {grade!r} not in plan")

    @property
    def grades(self) -> tuple[str, ...]:
        return tuple(e.grade for e in self.entries)

    @property
    def total_devices(self) -> int:
        return sum(e.num_devices for e in self.entries)


@dataclasses.dataclass(frozen=True)
class GradeRoundBreakdown:
    """Per-grade outcome of one round (makespan accounting, paper Fig. 7)."""

    grade: str
    num_logical: int
    num_physical: int
    num_benchmarking: int
    makespan_s: float  # slowest sampled device-round completion of the grade
    mean_duration_s: float  # mean sampled round duration across the grade


class ArrivalMessageView:
    """Scalar-``Message`` compat adapter over mixed round emissions.

    Columnar rounds emit ``ArrivalBatch``es (plus scalar q_i benchmarking
    messages); consumers of ``FederatedRoundOutcome.messages`` — launch
    scripts, fault injection, tests — still see one ``Message`` per device.
    Materialization is lazy and cached: the hot path (DeviceFlow submission,
    aggregation) never touches it, so reading ``.messages`` is the only
    thing that pays the per-row object cost.
    """

    __slots__ = ("_emissions", "_mat")

    def __init__(self, emissions: "list[Message | ArrivalBatch]"):
        self._emissions = emissions
        self._mat: list[Message] | None = None

    def _materialize(self) -> list[Message]:
        if self._mat is None:
            out: list[Message] = []
            for e in self._emissions:
                if isinstance(e, ArrivalBatch):
                    out.extend(e.messages())
                else:
                    out.append(e)
            self._mat = out
        return self._mat

    def __len__(self) -> int:
        return sum(e.n if isinstance(e, ArrivalBatch) else 1
                   for e in self._emissions)

    def __iter__(self):
        return iter(self._materialize())

    def __getitem__(self, i):
        return self._materialize()[i]

    def __repr__(self) -> str:
        return f"ArrivalMessageView(n={len(self)})"


@dataclasses.dataclass
class FederatedRoundOutcome:
    num_logical: int
    num_physical: int
    messages: "list[Message] | ArrivalMessageView"
    reports: list[RoundReport]
    arrival_times: np.ndarray | None = None  # per-message virtual times
    per_grade: dict[str, GradeRoundBreakdown] = dataclasses.field(
        default_factory=dict)
    client_metrics: list = dataclasses.field(default_factory=list)
    # Columnar rounds: the raw ArrivalBatch emissions (empty on the scalar
    # plane).  ``messages`` adapts them back to per-row Message views.
    batches: list[ArrivalBatch] = dataclasses.field(default_factory=list)

    @property
    def makespan_s(self) -> float:
        """Round makespan: the slowest grade's slowest sampled device."""
        return max((b.makespan_s for b in self.per_grade.values()), default=0.0)


class HybridSimulation:
    """Drives one federated round across both tiers and feeds DeviceFlow.

    This is the composition point of the paper: allocation decides the
    per-grade split, every grade's tiers execute the same operator flow, and
    results become DeviceFlow messages whose *dispatch* to the cloud follows
    the task's traffic strategy.

    ``tiers`` maps grade name to that grade's ``DeviceTier`` (each with its
    own fleet).  A single ``DeviceTier`` may still be passed positionally for
    the one-grade case; it is wrapped as ``{tier.grade.name: tier}`` and
    remains reachable as ``sim.device``.

    **Zero-copy rounds** (default): cohort outputs stay stacked on device —
    each chunk's result becomes one ``UpdateBuffer`` and every message
    carries an ``UpdateHandle`` (buffer ref + row) instead of a materialized
    host pytree, so the cohort loop never blocks on ``jax.device_get`` and
    chunk k+1 dispatches while chunk k still computes.  Host pytrees are
    materialized only for the q_i benchmarking devices (whose updates ride
    next to their ``RoundReport`` telemetry) — and at checkpoint time, by
    ``Checkpointer`` itself.  ``zero_copy=False`` keeps the PR 2
    host-materializing path as the correctness/perf reference.

    ``stream_chunks=True`` submits each cohort chunk's messages through
    DeviceFlow *as the chunk dispatches* instead of once at round end — the
    feed for streaming aggregation (``AggregationService(streaming=True)``):
    chunk k's ``fed_reduce`` partial fires while chunk k+1 still computes.
    Trade-off: streamed messages are stamped at the clock's current time, so
    per-message arrival-time fidelity (fleet-sampled queuing delay) is
    traded for pipeline overlap; round makespans and ``round_complete``
    timing still come from the fleet sample.  Benchmarking (q_i) rows are
    held back until their handles materialize and submitted last.

    ``recycle_buffers=True`` additionally donates round k's update buffers
    into round k+1's cohort dispatches: XLA writes the new updates in place
    of the retired ones, so steady-state rounds allocate no buffer-sized
    memory at all (at big-model scale, fresh multi-GB allocations cost a
    kernel page-zeroing pass per round).  Only enable it when every handle
    from round k is consumed before round k+1 runs (realtime dispatch with
    an in-round trigger, as in the quickstart); a handle that outlives its
    round would see its buffer invalidated by the donation.

    ``wire="int8"`` makes quantization a property of the wire: every cohort
    chunk's update is quantized *inside* the cohort jit
    (``run_cohort_quantized``) and emitted as an int8 ``UpdateBuffer`` with
    per-row, per-leaf scale columns — DeviceFlow byte accounting sees the
    true ~4x-smaller quantized footprint, and aggregation dequantizes
    in-reduction (``fed_reduce(..., scales=...)``) without ever
    materializing a dense f32 stack.  ``error_feedback=True`` (default)
    keeps convergence honest: each chunk's quantization error stays
    device-resident and is added back into the same chunk's next-round
    update before quantizing (EF-SGD memory, keyed per task/tier/row-range;
    cleared automatically if the chunking or layout changes).
    ``recycle_buffers`` applies only to the f32 wire (int8 leaves have a
    different storage layout than the donated f32 scratch).

    ``payload_transform`` (a callable ``emission -> emission`` over
    ``Message``/``ArrivalBatch``) rewrites every emission *before* it is
    submitted to DeviceFlow — the hook host-side transforms (e.g. top-k
    compression in ``launch/train.py``) use to ride the columnar plane
    instead of bypassing it.  Transforms must preserve ``device_ids`` /
    row counts (arrival times are indexed through them).

    ``workers=N`` (with ``worker_spec=WorkerSpec(factory, ...)``) shards
    cohort-chunk execution across N spawned worker processes
    (``runtime.workers.FleetWorkerPool``), each running its own jitted
    cohort loop; chunk results return as shared-memory-backed
    ``UpdateBuffer``s and re-enter the emission pipeline unchanged, so
    pooled rounds are bit-identical to in-process ones (both wires,
    error-feedback included) while this coordinator keeps DeviceFlow, fleet
    sampling, and aggregation on the authoritative clock.  Call ``close()``
    (or use the context-manager form) to stop the pool and release its
    segments.  Requires ``zero_copy`` rounds; ``worker_pool=`` injects a
    pre-built (e.g. delay-instrumented) pool instead.
    """

    def __init__(
        self,
        logical: LogicalTier,
        device: "DeviceTier | Mapping[str, DeviceTier] | None" = None,
        deviceflow: DeviceFlow | None = None,
        *,
        tiers: Mapping[str, DeviceTier] | None = None,
        zero_copy: bool = True,
        recycle_buffers: bool = False,
        stream_chunks: bool = False,
        columnar: bool = True,
        wire: str = "f32",
        error_feedback: bool = True,
        payload_transform: "Callable | None" = None,
        workers: int = 0,
        worker_spec=None,
        worker_pool=None,
    ):
        if wire not in ("f32", "int8"):
            raise ValueError(f"unknown wire format {wire!r}")
        if wire == "int8" and not zero_copy:
            raise ValueError(
                "wire='int8' requires zero_copy rounds (quantization is "
                "fused into the cohort jit)")
        # Multi-process fleet execution (runtime.workers): cohort chunks run
        # in N worker processes; this coordinator keeps DeviceFlow, fleet
        # sampling and aggregation on the authoritative clock.  The results
        # come back as the same columnar UpdateBuffers (shared-memory
        # backed), so everything downstream is unchanged.
        self._pool = worker_pool
        if workers and worker_pool is None:
            if worker_spec is None:
                raise ValueError(
                    "workers=N requires worker_spec=WorkerSpec(factory, ...)"
                    " — a picklable module-level factory rebuilding "
                    "(logical, tiers) inside each worker process")
            if not zero_copy:
                raise ValueError(
                    "workers=N requires zero_copy rounds (the transport "
                    "ships UpdateBuffer leaves)")
            from repro.runtime.workers import FleetWorkerPool

            self._pool = FleetWorkerPool(worker_spec, workers)
        self.zero_copy = zero_copy
        self.recycle_buffers = recycle_buffers
        self.stream_chunks = stream_chunks
        self.wire = wire
        self.error_feedback = error_feedback
        self.payload_transform = payload_transform
        # Error-feedback memory: (task, tier, global row range) -> residual
        # leaf tuple, device-resident across rounds.
        self._ef_residuals: dict = {}
        # Columnar message plane: zero-copy chunks emit ONE ArrivalBatch per
        # cohort chunk (struct-of-arrays columns + the chunk's UpdateBuffer)
        # instead of one Message object per device — the difference between
        # O(devices) Python and O(chunks) at the 10^6-device scale.  Only
        # meaningful with zero_copy (batches vectorize UpdateHandle rows);
        # ``columnar=False`` keeps the scalar plane as reference.
        self.columnar = columnar
        self._retired: dict = {}  # (tier id, rows) -> [UpdateBuffer]
        self._staged: dict = {}
        self.logical = logical
        if tiers is not None and device is not None:
            raise ValueError("pass either device or tiers, not both")
        if tiers is None:
            if device is None:
                raise ValueError(
                    "pass a DeviceTier or tiers={grade: DeviceTier}")
            tiers = (device if not isinstance(device, DeviceTier)
                     else {device.grade.name: device})
        self.tiers: dict[str, DeviceTier] = dict(tiers)
        if not self.tiers:
            raise ValueError("at least one device tier is required")
        self.deviceflow = deviceflow

    @property
    def device(self) -> DeviceTier:
        """Legacy single-grade view of ``tiers``."""
        if len(self.tiers) != 1:
            raise ValueError(
                f"{len(self.tiers)} device tiers configured; "
                "use sim.tiers[grade]")
        return next(iter(self.tiers.values()))

    @property
    def pool(self):
        """The ``FleetWorkerPool`` driving multi-process rounds (or None)."""
        return self._pool

    @property
    def fleets(self) -> "dict[str, DeviceFleet]":
        """Per-grade fleets, keyed by grade name — the shape
        ``TaskEngine.state_dict(fleets=...)`` folds into the one-manifest
        runtime checkpoint (fleet RNG counters travel with the engine)."""
        return {name: tier.fleet for name, tier in self.tiers.items()}

    def close(self) -> None:
        """Shut down the worker pool (no-op for single-process rounds)."""
        if self._pool is not None:
            self._pool.close()

    def __enter__(self) -> "HybridSimulation":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- shared per-grade execution ----------------------------------------
    @hot_path
    def _run_split(
        self,
        tier: DeviceTier,
        task_id: int,
        round_idx: int,
        global_params: Params,
        client_batches: Batch,
        num_samples: np.ndarray,
        num_logical: int,
        rng: jax.Array,
        *,
        id_offset: int = 0,
        metrics_out: list | None = None,
        materialize_rows: Sequence[int] = (),
    ) -> "tuple[list[Message | ArrivalBatch], jax.Array]":
        """Run one grade's split: [0, num_logical) through the logical tier,
        the rest through ``tier``'s device backend.  Returns the emitted
        arrivals (``device_id`` offset by ``id_offset``) and the advanced rng.

        Zero-copy mode emits ONE columnar ``ArrivalBatch`` per cohort chunk
        (the chunk's device-resident ``UpdateBuffer`` + struct-of-array
        columns); ``materialize_rows`` names the grade-local rows (the q_i
        benchmarking devices) that are instead emitted as scalar ``Message``s
        whose payloads are materialized to host pytrees *after* every chunk
        has been dispatched, so benchmarking never stalls the cohort
        pipeline.  ``columnar=False`` (or the host path) emits one Message
        per device, as before.
        """
        n_total = int(jax.tree.leaves(client_batches)[0].shape[0])
        if not 0 <= num_logical <= n_total:
            raise ValueError("num_logical out of range")

        def take(tree, lo, hi):
            # Static-bound slice for device leaves: eager ``x[lo:hi]``
            # dispatches a dynamic_slice whose start index ships to device
            # as a runtime scalar — an implicit h2d that trips the
            # @hot_path transfer guard.  ``lax.slice_in_dim`` bakes the
            # bounds into the compiled op instead.
            return jax.tree.map(
                lambda x: jax.lax.slice_in_dim(x, lo, hi)
                if isinstance(x, jax.Array) else x[lo:hi], tree)
        emissions: "list[Message | ArrivalBatch]" = []
        # User extension point: transforms may legitimately move data
        # between host and device, so they run outside the hot-path
        # transfer guard (no-op wrapper when sanitizers are off).
        transform = sanitizers.exempt(self.payload_transform)
        mat_set = set(materialize_rows)
        columnar = self.columnar and self.zero_copy
        bench_pos: dict[int, int] = {}  # grade-local row -> emission index

        def emit_batch(buf: UpdateBuffer, lo, hi):
            # Columnar plane: the whole chunk is ONE struct-of-arrays record
            # sharing the chunk's UpdateBuffer — no per-device objects.  The
            # q_i benchmarking rows split out as scalar Messages (their
            # payloads materialize to host pytrees post-round).
            num_samples_arr = np.asarray(num_samples[lo:hi], np.int64)
            bench = sorted(r for r in mat_set if lo <= r < hi)
            prev = lo
            for r in bench + [hi]:
                if r > prev:
                    emissions.append(ArrivalBatch(
                        task_id, round_idx,
                        rows=np.arange(prev - lo, r - lo, dtype=np.int32),
                        num_samples=num_samples_arr[prev - lo:r - lo],
                        device_ids=np.arange(id_offset + prev,
                                             id_offset + r, dtype=np.int64),
                        buffer=buf))
                if r < hi:
                    bench_pos[r] = len(emissions)
                    emissions.append(Message(
                        task_id=task_id,
                        device_id=id_offset + r,
                        round_idx=round_idx,
                        payload=buf.handle(r - lo),
                        num_samples=int(num_samples[r]),
                    ))
                prev = r + 1

        def emit_handles(buf: UpdateBuffer, lo, hi):
            # Zero-copy scalar plane: the chunk's update buffer stays on
            # device; messages carry (buffer, row) handles.  No device_get,
            # no host pytrees — the next chunk dispatches while this one
            # still computes.
            if columnar:
                emit_batch(buf, lo, hi)
                return
            for j in range(hi - lo):
                emissions.append(
                    Message(
                        task_id=task_id,
                        device_id=id_offset + lo + j,
                        round_idx=round_idx,
                        payload=buf.handle(j),
                        num_samples=int(num_samples[lo + j]),
                    )
                )

        def emit_host(stacked_params, lo, hi):
            # Host reference path (PR 2): block on device_get, flatten once
            # per chunk, per-device payloads as cheap leaf-index views.
            host_params = jax.device_get(stacked_params)
            leaves, treedef = jax.tree.flatten(host_params)
            for j in range(hi - lo):
                emissions.append(
                    Message(
                        task_id=task_id,
                        device_id=id_offset + lo + j,
                        round_idx=round_idx,
                        payload=treedef.unflatten([leaf[j] for leaf in leaves]),
                        num_samples=int(num_samples[lo + j]),
                    )
                )

        stream = self.stream_chunks and self.deviceflow is not None

        def stream_chunk(n_before: int) -> None:
            # Streaming feed: this chunk's arrivals enter DeviceFlow now, so
            # a streaming aggregation service fires the chunk's fed_reduce
            # partial while the next chunk's cohort is still computing.  The
            # q_i benchmarking rows are held back until materialization.
            held = set(bench_pos.values()) if columnar else mat_set
            if transform is not None:
                for i in range(n_before, len(emissions)):
                    if i not in held:
                        emissions[i] = transform(emissions[i])
            fresh = [e for i, e in enumerate(emissions[n_before:],
                                             start=n_before)
                     if i not in held]
            if not fresh:
                return
            if any(isinstance(e, ArrivalBatch) for e in fresh):
                self.deviceflow.submit_arrivals(fresh)
            else:
                self.deviceflow.submit_many(fresh)

        def run_chunk(sim_tier, lo, hi, sub):
            # Same per-device rng derivation in both modes (run_cohort splits
            # the chunk key identically), so zero_copy is numerics-preserving.
            # The h2d transfer of the chunk's batch is EXPLICIT (jnp.asarray;
            # free for already-device leaves): _run_split is a @hot_path, so
            # a numpy leaf reaching the cohort jit directly would be an
            # implicit transfer and trip transfer_guard("disallow").
            chunk = jax.tree.map(jnp.asarray, take(client_batches, lo, hi))
            rngs = jax.random.split(sub, hi - lo)
            if self.zero_copy and self.wire == "int8":
                # Quantized wire: the chunk quantizes inside the cohort jit
                # and its error-feedback residual stays device-resident,
                # keyed by (task, tier, global row range) so the same
                # devices' residual carries into their next round.
                ef_key = (task_id, id(sim_tier), id_offset + lo,
                          id_offset + hi)
                buf, metrics, new_res = sim_tier.run_cohort_quantized(
                    global_params, chunk, rngs,
                    residual=self._ef_residuals.get(ef_key),
                    error_feedback=self.error_feedback)
                if self.error_feedback:
                    self._ef_residuals[ef_key] = new_res
                emit_handles(buf, lo, hi)
            elif self.zero_copy:
                # The chunk's stacked output never leaves the device; the
                # next chunk dispatches while this one still computes.
                prev = None
                key = (id(sim_tier), hi - lo)
                if self.recycle_buffers and self._retired.get(key):
                    prev = self._retired[key].pop()
                buf, metrics = sim_tier.run_cohort_zero_copy(
                    global_params, chunk, rngs, recycle=prev)
                if self.recycle_buffers:
                    self._staged.setdefault(key, []).append(buf)
                emit_handles(buf, lo, hi)
            elif sim_tier is self.logical:
                res = sim_tier.run_cohort(
                    global_params, chunk, sub, num_samples[lo:hi])
                metrics = res.metrics
                emit_host(res.params, lo, hi)
            else:
                out_params, metrics = sim_tier.run_cohort(
                    global_params, chunk, rngs)
                emit_host(out_params, lo, hi)
            if metrics_out is not None:
                metrics_out.append(metrics)

        # The chunk plan IS the rng contract: logical cohorts (chunked by
        # cohort_size) then device cohorts, one ``jax.random.split`` per
        # chunk — walked identically whether chunks run inline or across a
        # worker pool, so multi-process rounds stay bit-identical.
        chunk_plan: list[tuple] = []
        idx = 0
        while idx < num_logical:
            hi = min(idx + self.logical.cohort_size, num_logical)
            rng, sub = jax.random.split(rng)
            chunk_plan.append((self.logical, "logical", idx, hi, sub))
            idx = hi
        # Device tier: vectorized cohorts through the bf16 backend — one
        # vmapped dispatch per chunk instead of one jit call per device.
        idx = num_logical
        while idx < n_total:
            hi = min(idx + tier.cohort_size, n_total)
            rng, sub = jax.random.split(rng)
            chunk_plan.append((tier, tier.grade.name, idx, hi, sub))
            idx = hi

        if self._pool is not None and self.zero_copy:
            # Multi-process path: ship the plan to the worker pool; chunk
            # results come back as shared-memory-backed UpdateBuffers and
            # re-enter the exact emission pipeline below.  Without
            # streaming, emissions assemble in CHUNK order (bit-identical
            # to inline); with streaming, in COMPLETION order, overlapping
            # fed_reduce partials with still-running worker shards.
            from repro.runtime.workers import ChunkSpec

            specs_by_kind: dict[str, tuple] = {}
            for sim_tier, kind, lo, hi, _ in chunk_plan:
                if kind in specs_by_kind:
                    continue
                sim_tier._zero_copy_machinery()  # ensures the spec cache
                abstract = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(
                        (hi - lo,) + tuple(x.shape[1:]), x.dtype),
                    client_batches)
                specs_by_kind[kind] = sim_tier._update_spec(
                    global_params, abstract,
                    jax.ShapeDtypeStruct((hi - lo, 2), np.uint32))
            wchunks = [
                ChunkSpec(i, kind, lo, hi,
                          np.asarray(sub),  # simcheck: ok[R003] key -> worker
                          id_offset=id_offset)
                for i, (_, kind, lo, hi, sub) in enumerate(chunk_plan)]

            def finish(i, buf, metrics):
                _, _, lo, hi, _ = chunk_plan[i]
                n_before = len(emissions)
                emit_handles(buf, lo, hi)
                if metrics_out is not None:
                    metrics_out.append(metrics)
                if stream:
                    stream_chunk(n_before)

            pooled = self._pool.run_chunks(
                task_id=task_id, round_idx=round_idx, params=global_params,
                batches=client_batches, chunks=wchunks,
                specs_by_kind=specs_by_kind, wire=self.wire,
                error_feedback=self.error_feedback,
                on_result=finish if stream else None)
            if not stream:
                for i, (buf, metrics) in enumerate(pooled):
                    finish(i, buf, metrics)
        else:
            for sim_tier, _, lo, hi, sub in chunk_plan:
                n_before = len(emissions)
                run_chunk(sim_tier, lo, hi, sub)
                if stream:
                    stream_chunk(n_before)

        # Deferred host materialization: only the q_i benchmarking devices'
        # updates become host pytrees, after the whole grade has dispatched.
        # (Columnar mode: bench rows live at ``bench_pos[r]``; scalar mode:
        # emission index == grade-local row.)
        for r in materialize_rows:
            i = bench_pos.get(r, r)
            m = emissions[i]
            if isinstance(m.payload, UpdateHandle):
                emissions[i] = dataclasses.replace(
                    m, payload=m.payload.materialize())
        if transform is not None:
            if stream:
                # Streamed chunks transformed at submit time; only the
                # held-back benchmarking rows remain.
                for r in mat_set:
                    i = bench_pos.get(r, r)
                    emissions[i] = transform(emissions[i])
            else:
                emissions = [transform(e) for e in emissions]
        if stream and mat_set:
            self.deviceflow.submit_many(
                [emissions[bench_pos.get(r, r)] for r in sorted(mat_set)])
        return emissions, rng

    # -- grade-partitioned rounds (allocator-driven) -----------------------
    def run_plan_round(
        self,
        task_id: int,
        round_idx: int,
        global_params: Params,
        plan: RoundPlan,
        grade_batches: Mapping[str, Batch],  # per grade: leaves (N_i, ...)
        grade_num_samples: Mapping[str, np.ndarray],  # per grade: (N_i,)
        rng: jax.Array,
        *,
        calibrator=None,
    ) -> FederatedRoundOutcome:
        """Execute one allocator-planned round across every grade.

        Per grade ``g``: rows ``[0, x_g)`` of ``grade_batches[g]`` run on the
        logical tier, rows ``[x_g, x_g + y_g + q_g)`` through grade ``g``'s
        ``DeviceTier``; the LAST ``q_g`` rows are the benchmarking devices and
        materialize ``RoundReport``s.  Each grade's fleet is sampled once;
        the sampled durations become DeviceFlow arrival times (merged across
        grades) and the per-grade makespan breakdown.  ``calibrator``
        (a ``calibration.RuntimeCalibrator``) observes every grade's sample,
        closing the measurement loop back into ``solve_allocation``.

        The plan may change between rounds of one task: an elastic or
        preemptive ``TaskEngine`` re-solves the allocation mid-task (grant
        top-ups and refreeze-downs), which moves devices between tiers but
        never changes a grade's total — batches stay shaped ``(N_i, ...)``
        across every re-plan.
        """
        # Validate the whole plan up front: a failure mid-plan would leave
        # earlier grades' tiers, rng, and the calibrator polluted with a
        # half-executed round.
        per_grade_inputs: list[tuple[GradePlanEntry, Any, np.ndarray, int]] = []
        for entry in plan.entries:
            if entry.grade not in self.tiers:
                raise KeyError(
                    f"plan contains grade {entry.grade!r} but HybridSimulation "
                    f"has tiers for {sorted(self.tiers)}")
            try:
                batches = grade_batches[entry.grade]
                n_samples = np.asarray(grade_num_samples[entry.grade])
            except KeyError:
                raise KeyError(
                    f"grade_batches/grade_num_samples missing grade "
                    f"{entry.grade!r}") from None
            n_total = int(jax.tree.leaves(batches)[0].shape[0])
            if n_total != entry.num_devices:
                raise ValueError(
                    f"grade {entry.grade!r}: batches carry {n_total} devices "
                    f"but the plan requires {entry.num_devices} "
                    f"(x={entry.num_logical} + y={entry.num_physical} + "
                    f"q={entry.num_benchmarking})")
            per_grade_inputs.append((entry, batches, n_samples, n_total))

        emissions: "list[Message | ArrivalBatch]" = []
        reports: list[RoundReport] = []
        arrivals: list[np.ndarray] = []
        breakdown: dict[str, GradeRoundBreakdown] = {}
        client_metrics: list = []
        base = 0.0 if self.deviceflow is None else self.deviceflow.clock.now
        offset = 0
        for entry, batches, n_samples, n_total in per_grade_inputs:
            tier = self.tiers[entry.grade]
            if n_total == 0:
                breakdown[entry.grade] = GradeRoundBreakdown(
                    entry.grade, 0, 0, 0, 0.0, 0.0)
                continue
            grade_emissions, rng = self._run_split(
                tier, task_id, round_idx, global_params, batches, n_samples,
                entry.num_logical, rng, id_offset=offset,
                metrics_out=client_metrics,
                materialize_rows=range(
                    n_total - entry.num_benchmarking, n_total),
            )
            emissions.extend(grade_emissions)

            # Behavioral side: one fleet sample covers the grade (sampled
            # under grade-LOCAL ids so per-device RNG streams stay stable
            # across rounds whatever the plan); the last q_i rows — the
            # allocator-excluded benchmarking devices — also materialize full
            # RoundReports (paper §IV.C) re-stamped with the same global
            # device ids their messages carry.
            sample = tier.sample_round(np.arange(n_total), round_idx)
            for k in range(n_total - entry.num_benchmarking, n_total):
                rep = dataclasses.replace(
                    sample.report(k), device_id=offset + k)
                reports.append(rep)
                tier.reports.append(rep)
            if calibrator is not None:
                calibrator.observe_fleet(sample)
            offsets_s = sample.arrival_offsets_s()
            arrivals.append(base + offsets_s)
            breakdown[entry.grade] = GradeRoundBreakdown(
                grade=entry.grade,
                num_logical=entry.num_logical,
                num_physical=entry.num_physical,
                num_benchmarking=entry.num_benchmarking,
                makespan_s=float(offsets_s.max()),
                mean_duration_s=float(offsets_s.mean()),
            )
            offset += n_total

        arrival_times = (np.concatenate(arrivals) if arrivals else None)
        batches = [e for e in emissions if isinstance(e, ArrivalBatch)]
        if self.deviceflow is not None and emissions:
            if not self.stream_chunks:  # streamed rounds already submitted
                if batches:
                    # Columnar plane: per-row arrival times indexed straight
                    # from the batch's device_ids column — no per-row objects.
                    ts = np.concatenate([
                        arrival_times[e.device_ids]
                        if isinstance(e, ArrivalBatch)
                        else arrival_times[e.device_id:e.device_id + 1]
                        for e in emissions])
                    self.deviceflow.submit_arrivals(emissions, ts=ts)
                else:
                    self.deviceflow.submit_many(emissions, ts=arrival_times)
            # The round ends when the slowest device reports, not at clock.now.
            self.deviceflow.round_complete(
                task_id, t=float(np.max(arrival_times)))
        if self.recycle_buffers:
            self._retired, self._staged = self._staged, {}
        return FederatedRoundOutcome(
            num_logical=sum(e.num_logical for e in plan.entries),
            num_physical=sum(e.num_physical + e.num_benchmarking
                             for e in plan.entries),
            messages=(ArrivalMessageView(emissions) if batches
                      else emissions),
            batches=batches,
            reports=reports,
            arrival_times=arrival_times,
            per_grade=breakdown,
            client_metrics=client_metrics,
        )

    # -- legacy single-grade path ------------------------------------------
    def run_round(
        self,
        task_id: int,
        round_idx: int,
        global_params: Params,
        client_batches: Batch,  # leaves (num_clients, ...)
        num_samples: np.ndarray,  # (num_clients,)
        num_logical: int,
        rng: jax.Array,
        *,
        benchmark_devices: int = 0,
        arrival_times: np.ndarray | None = None,
    ) -> FederatedRoundOutcome:
        """Single-grade round against ``sim.device`` (legacy shape).

        Unlike the plan path, ``benchmark_devices`` picks the FIRST n
        device-tier rows and does not reduce ``num_physical`` — the historic
        ``HybridSimulation(logical, device)`` contract.
        """
        tier = self.device
        n_total = int(jax.tree.leaves(client_batches)[0].shape[0])
        n_bench_rows = min(max(benchmark_devices, 0), n_total - num_logical)
        metrics: list = []
        emissions, _ = self._run_split(
            tier, task_id, round_idx, global_params, client_batches,
            np.asarray(num_samples), num_logical, rng, metrics_out=metrics,
            materialize_rows=range(num_logical, num_logical + n_bench_rows))
        reports: list[RoundReport] = []

        # Behavioral side: one vectorized fleet sample covers every simulated
        # device this round — Table-I durations become arrival times, and the
        # benchmarking subset materializes full RoundReports (paper §IV.C).
        sample: FleetRoundSample | None = None
        if n_total > 0:
            sample = tier.sample_round(np.arange(n_total), round_idx)
        n_bench = min(benchmark_devices, n_total - num_logical)
        for k in range(n_bench):
            rep = sample.report(num_logical + k)
            reports.append(rep)
            tier.reports.append(rep)

        breakdown: dict[str, GradeRoundBreakdown] = {}
        if arrival_times is None and sample is not None:
            base = 0.0 if self.deviceflow is None else self.deviceflow.clock.now
            arrival_times = base + sample.arrival_offsets_s()
        if sample is not None:
            offsets_s = sample.arrival_offsets_s()
            breakdown[tier.grade.name] = GradeRoundBreakdown(
                grade=tier.grade.name,
                num_logical=num_logical,
                num_physical=n_total - num_logical,
                num_benchmarking=n_bench,
                makespan_s=float(offsets_s.max()),
                mean_duration_s=float(offsets_s.mean()),
            )

        batches = [e for e in emissions if isinstance(e, ArrivalBatch)]
        if self.deviceflow is not None:
            if not self.stream_chunks:  # streamed rounds already submitted
                if batches:
                    ts = (None if arrival_times is None else np.concatenate([
                        arrival_times[e.device_ids]
                        if isinstance(e, ArrivalBatch)
                        else arrival_times[e.device_id:e.device_id + 1]
                        for e in emissions]))
                    self.deviceflow.submit_arrivals(emissions, ts=ts)
                else:
                    self.deviceflow.submit_many(emissions, ts=arrival_times)
            # The round ends when the slowest device reports, not at clock.now.
            t_end = (float(np.max(arrival_times))
                     if arrival_times is not None and len(arrival_times)
                     else None)
            self.deviceflow.round_complete(task_id, t=t_end)
        if self.recycle_buffers:
            self._retired, self._staged = self._staged, {}
        return FederatedRoundOutcome(
            num_logical=num_logical,
            num_physical=n_total - num_logical,
            messages=(ArrivalMessageView(emissions) if batches
                      else emissions),
            batches=batches,
            reports=reports,
            arrival_times=arrival_times,
            per_grade=breakdown,
            client_metrics=metrics,
        )
