"""Fleet-driven runtime calibration — closing the §IV.C → §IV.B loop.

The paper measures real phones (PhoneMgr over ADB) and feeds the measured
per-grade round statistics back into the hybrid allocator as the
``GradeRuntime`` constants (alpha_i, beta_i, lambda_i).  Here the measurement
source is the calibrated stochastic ``DeviceFleet`` — every simulated round
produces a ``FleetRoundSample``, and the q_i benchmarking devices materialize
full ``RoundReport``s.  ``RuntimeCalibrator`` accumulates those observations
per grade and produces *measured* runtimes, so ``solve_allocation`` and the
task scheduler run on data instead of hand-coded constants (the
virtual-vs-real discrepancy IoTSim-Edge's behavior-modeling critique warns
about).

Estimation contract (all in virtual seconds):

* ``lambda_i`` — mean APK_LAUNCH stage duration: the on-phone compute
  framework's startup cost, paid once per device batch.
* ``beta_i`` — mean device round duration *excluding* startup: the serial
  per-batch cost of a phone in ``ceil(y/m) * beta + lambda``.
* ``alpha_i`` — mean of the logical bundle-group durations recorded via
  ``observe_logical`` when the caller measured any; otherwise the mean
  TRAINING stage duration — the logical tier simulates the training
  computation only, with no APK lifecycle around it.

``sample_runtimes`` draws one *observed round* per grade instead of the mean,
so allocation can be driven by sampled (not mean) durations — e.g. to stress
the makespan estimate against round-to-round jitter.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.allocation import GradeRuntime
from repro.core.devicemodel import (
    GRADES,
    DeviceGrade,
    FleetRoundSample,
    RoundReport,
    Stage,
    startup_duration_s,
    training_duration_s,
)

def table1_runtime(grade: DeviceGrade, *, train_cost_scale: float = 1.0
                   ) -> GradeRuntime:
    """Deterministic Table-I prior: what calibration converges to at scale.

    Used for grades with no observations yet (cold-start allocation before
    the first round has produced any fleet samples).
    """
    lam = startup_duration_s(grade)
    train = training_duration_s(grade, train_cost_scale=train_cost_scale)
    other = sum(grade.cost(s).duration_min for s in Stage
                if s not in (Stage.APK_LAUNCH, Stage.TRAINING)) * 60.0
    return GradeRuntime(alpha=train, beta=train + other, lam=lam)


@dataclasses.dataclass
class _GradeObservations:
    """Raw per-round duration draws for one grade (seconds)."""

    total_s: list = dataclasses.field(default_factory=list)
    launch_s: list = dataclasses.field(default_factory=list)
    train_s: list = dataclasses.field(default_factory=list)
    logical_s: list = dataclasses.field(default_factory=list)

    @property
    def num_device_rounds(self) -> int:
        return len(self.total_s)


class RuntimeCalibrator:
    """Accumulates fleet/report observations and estimates ``GradeRuntime``s.

    Feed it from the round engine (``HybridSimulation.run_plan_round(...,
    calibrator=...)``), from raw ``FleetRoundSample``s, or from benchmarking
    devices' ``RoundReport``s; read back measured runtimes with ``runtime`` /
    ``runtimes_for``, or plug it straight into ``TaskRunner`` (it exposes the
    ``for_task`` adapter the scheduler consumes).
    """

    def __init__(self, *, prior: Mapping[str, GradeRuntime] | None = None,
                 min_rounds: int = 1):
        if min_rounds < 1:
            raise ValueError("min_rounds must be >= 1")
        self._obs: dict[str, _GradeObservations] = defaultdict(_GradeObservations)
        self._prior = dict(prior or {})
        self.min_rounds = min_rounds

    # -- observation ingestion ---------------------------------------------
    def observe_fleet(self, sample: FleetRoundSample) -> None:
        """Ingest one vectorized round: every device row is one observation."""
        if np.asarray(sample.stage_duration_min).size == 0:
            return
        obs = self._obs[sample.grade]
        obs.total_s.extend((sample.total_duration_min * 60.0).tolist())
        obs.launch_s.extend(sample.stage_duration_s(Stage.APK_LAUNCH).tolist())
        obs.train_s.extend(sample.stage_duration_s(Stage.TRAINING).tolist())

    def observe_report(self, report: RoundReport) -> None:
        """Ingest one benchmarking device's round (paper §IV.C measurement)."""
        obs = self._obs[report.grade]
        obs.total_s.append(report.total_duration_min * 60.0)
        obs.launch_s.append(report.stage_duration_min[Stage.APK_LAUNCH] * 60.0)
        obs.train_s.append(report.stage_duration_min[Stage.TRAINING] * 60.0)

    def observe_logical(self, grade: str, duration_s: float) -> None:
        """Record one measured logical bundle-group round duration (alpha)."""
        if duration_s <= 0:
            raise ValueError("logical round duration must be positive")
        self._obs[grade].logical_s.append(float(duration_s))

    # -- introspection ------------------------------------------------------
    def num_observations(self, grade: str) -> int:
        return self._obs[grade].num_device_rounds if grade in self._obs else 0

    @property
    def grades(self) -> tuple[str, ...]:
        return tuple(sorted(self._obs))

    def is_calibrated(self, grade: str) -> bool:
        return self.num_observations(grade) >= self.min_rounds

    # -- estimation ---------------------------------------------------------
    def _fallback(self, grade: str) -> GradeRuntime:
        if grade in self._prior:
            return self._prior[grade]
        if grade in GRADES:
            return table1_runtime(GRADES[grade])
        raise KeyError(
            f"grade {grade!r} has no observations, no prior, and no Table-I "
            "default — observe a fleet round or pass a prior runtime")

    def runtime(self, grade: str) -> GradeRuntime:
        """Measured ``GradeRuntime`` for ``grade`` (prior/Table-I fallback).

        Device-side rounds measure beta/lambda (and the alpha default);
        ``observe_logical`` recordings override alpha even when no device
        rounds have been seen yet (beta/lambda then come from the fallback).
        """
        obs = self._obs.get(grade)
        logical_s = obs.logical_s if obs is not None else []
        if not self.is_calibrated(grade):
            fb = self._fallback(grade)
            if not logical_s:
                return fb
            return GradeRuntime(alpha=float(np.mean(logical_s)),
                                beta=fb.beta, lam=fb.lam)
        lam = float(np.mean(obs.launch_s))
        beta = float(np.mean(obs.total_s)) - lam
        alpha = (float(np.mean(logical_s)) if logical_s
                 else float(np.mean(obs.train_s)))
        return GradeRuntime(alpha=alpha, beta=beta, lam=lam)

    def runtimes_for(self, grades: Iterable) -> list[GradeRuntime]:
        """Runtimes aligned with ``grades`` (names or ``GradeSpec``-likes)."""
        names = [g if isinstance(g, str) else g.grade for g in grades]
        return [self.runtime(name) for name in names]

    def for_task(self, task) -> list[GradeRuntime]:
        """Adapter matching ``TaskRunner``'s ``runtimes`` callable contract."""
        return self.runtimes_for(task.grades)

    def sample_for_task(self, task, rng: np.random.Generator
                        ) -> list[GradeRuntime]:
        """Sampled (not mean) runtimes for a task's grades.

        The event engine calls this when constructed with a
        ``duration_rng``: each scheduled round's timestamp is solved from one
        *observed* round per grade, so event times carry the fleet's measured
        round-to-round jitter instead of collapsing to the mean (the
        Monte-Carlo makespan direction from the PR 2 notes).
        """
        return self.sample_runtimes(task.grades, rng)

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Resume-safe observation state (plain floats, JSON-friendly).

        A checkpointed ``TaskEngine`` re-solves allocations on restore with
        whatever runtimes provider it is given; when that provider is a
        calibrator, restoring these observations is what makes the resumed
        timeline reproduce the saved one (``table1_runtime`` cold-start
        fallbacks would otherwise replace the measured runtimes mid-task).
        """
        return {
            grade: {"total_s": list(obs.total_s),
                    "launch_s": list(obs.launch_s),
                    "train_s": list(obs.train_s),
                    "logical_s": list(obs.logical_s)}
            for grade, obs in self._obs.items()
        }

    def load_state_dict(self, d: Mapping) -> None:
        self._obs.clear()
        for grade, obs in d.items():
            self._obs[grade] = _GradeObservations(
                total_s=[float(x) for x in obs["total_s"]],
                launch_s=[float(x) for x in obs["launch_s"]],
                train_s=[float(x) for x in obs["train_s"]],
                logical_s=[float(x) for x in obs["logical_s"]],
            )

    def sample_runtimes(self, grades: Iterable, rng: np.random.Generator
                        ) -> list[GradeRuntime]:
        """Draw one observed round per grade instead of the mean.

        Feeding these into ``solve_allocation`` makes the makespan estimate
        reflect sampled (not mean) durations; grades without observations
        fall back to their prior/Table-I runtime.
        """
        out = []
        names = [g if isinstance(g, str) else g.grade for g in grades]
        for name in names:
            if not self.is_calibrated(name):
                out.append(self._fallback(name))
                continue
            obs = self._obs[name]
            i = int(rng.integers(len(obs.total_s)))
            lam = obs.launch_s[i]
            beta = max(obs.total_s[i] - lam, 1e-9)
            alpha = (obs.logical_s[int(rng.integers(len(obs.logical_s)))]
                     if obs.logical_s else obs.train_s[i])
            out.append(GradeRuntime(alpha=alpha, beta=beta, lam=lam))
        return out


# --------------------------------------------------------------------------- #
# Monte-Carlo schedule estimation (sampled timelines, not the mean)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ScheduleEstimate:
    """Distribution of one scheduling policy across N sampled timelines.

    Every array is one value per sampled timeline; the per-task dicts are
    keyed by ``task_id``.  Tasks that never completed in a sample carry
    ``nan`` there (stranded — e.g. nothing fits after a pool shrink), so
    ``nanmean``/``nanpercentile`` are the right reductions.
    """

    makespan_s: np.ndarray  # (n_samples,)
    queueing_delay_s: dict[int, np.ndarray]  # task_id -> (n_samples,)
    grant_utilization: dict[int, np.ndarray]  # task_id -> (n_samples,)

    @property
    def mean_makespan_s(self) -> float:
        return float(np.nanmean(self.makespan_s))

    @property
    def p95_makespan_s(self) -> float:
        return float(np.nanpercentile(self.makespan_s, 95))

    def mean_queueing_delay_s(self, task_id: int) -> float:
        return float(np.nanmean(self.queueing_delay_s[task_id]))

    def mean_grant_utilization(self, task_id: int) -> float:
        return float(np.nanmean(self.grant_utilization[task_id]))


def monte_carlo_schedules(
    tasks: Sequence,
    pool,
    runtimes,
    *,
    arrivals: Mapping[int, float] | None = None,
    modes: Sequence[bool] = (False, True),
    n_samples: int = 32,
    seed: int = 0,
    elastic: bool = True,
) -> dict[bool, ScheduleEstimate]:
    """Monte-Carlo makespan comparison: preemptive vs non-preemptive.

    Replays the same task set through a pure virtual-time ``TaskEngine``
    (no ``round_runner`` — round durations come from allocations solved on
    runtimes *sampled per round* via ``sample_for_task``/``duration_rng``)
    ``n_samples`` times per scheduling mode, each sample on an independent
    rng stream.  Both modes of sample ``i`` share one seed, so the
    comparison is paired: the same drawn timeline, scheduled two ways.

    ``tasks`` are template ``Task``s (re-submitted per sample — the engine
    never mutates them); ``pool`` is the ``ResourcePool`` to contend for;
    ``runtimes`` is anything ``TaskEngine`` accepts, but only a
    ``RuntimeCalibrator`` (with observations) gives the samples any spread.
    ``arrivals`` maps ``task_id`` to its submission time (default: all at
    t=0).  ``modes`` selects the preemptive flags to run (default: both).

    Returns ``{preemptive_flag: ScheduleEstimate}`` — per-task
    queueing-delay and grant-utilization distributions plus the makespan
    distribution, the quantitative case for (or against) preemption on a
    given workload.
    """
    # Engine imports live here: calibration is otherwise scheduler-free, and
    # the estimator is the one place the measurement loop drives scheduling.
    from repro.core.scheduler import ResourceManager, TaskEngine

    if n_samples < 1:
        raise ValueError("n_samples must be >= 1")
    arrivals = dict(arrivals or {})
    out: dict[bool, ScheduleEstimate] = {}
    for preemptive in modes:
        mk = np.full(n_samples, np.nan)
        qd = {t.task_id: np.full(n_samples, np.nan) for t in tasks}
        gu = {t.task_id: np.full(n_samples, np.nan) for t in tasks}
        for i in range(n_samples):
            engine = TaskEngine(
                ResourceManager(pool.copy()), runtimes,
                elastic=elastic, preemptive=preemptive,
                duration_rng=np.random.default_rng(
                    np.random.SeedSequence([seed, i])),
            )
            for t in tasks:
                engine.submit(t, at=arrivals.get(t.task_id))
            engine.run_until()
            mk[i] = engine.makespan
            for ex in engine.completed:
                qd[ex.task.task_id][i] = ex.queueing_delay_s
                gu[ex.task.task_id][i] = ex.grant_utilization
        out[preemptive] = ScheduleEstimate(
            makespan_s=mk, queueing_delay_s=qd, grant_utilization=gu)
    return out


def calibrate_runtimes(
    *,
    samples: Sequence[FleetRoundSample] = (),
    reports: Sequence[RoundReport] = (),
    logical_durations: Mapping[str, Sequence[float]] | None = None,
    prior: Mapping[str, GradeRuntime] | None = None,
) -> dict[str, GradeRuntime]:
    """One-shot calibration: observations in, per-grade ``GradeRuntime``s out.

    Returns measured runtimes for every grade that appears in the
    observations.  Convenience wrapper over ``RuntimeCalibrator`` for the
    common batch case (e.g. ``calibrate_runtimes(reports=tier.reports)``).
    """
    cal = RuntimeCalibrator(prior=prior)
    for s in samples:
        cal.observe_fleet(s)
    for r in reports:
        cal.observe_report(r)
    for grade, durs in (logical_durations or {}).items():
        for d in durs:
            cal.observe_logical(grade, d)
    return {g: cal.runtime(g) for g in cal.grades}
