"""SimDC core: the paper's contribution as composable JAX modules."""
from repro.core.allocation import (
    AllocationResult,
    GradeRuntime,
    fixed_ratio_allocation,
    solve_allocation,
    solve_allocation_bruteforce,
)
from repro.core.deviceflow import Delivery, DeviceFlow, Message, Shelf, VirtualClock
from repro.core.federation import (
    AggregationService,
    ClientCountTrigger,
    SampleThresholdTrigger,
    ScheduledTrigger,
    fedavg_delta,
    polynomial_staleness,
    weighted_average,
)
from repro.core.scheduler import (
    ResourceManager,
    ResourcePool,
    TaskManager,
    TaskRunner,
    TaskScheduler,
)
from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchPoint,
    TimeIntervalStrategy,
    TimePointStrategy,
    discretize_curve,
)
from repro.core.task import GradeSpec, OperatorFlow, Task, TaskQueue, register_operator
from repro.core.traffic_curves import TrafficCurve, right_tailed_normal, table2_curves

__all__ = [
    "AllocationResult", "GradeRuntime", "fixed_ratio_allocation",
    "solve_allocation", "solve_allocation_bruteforce",
    "Delivery", "DeviceFlow", "Message", "Shelf", "VirtualClock",
    "AggregationService", "ClientCountTrigger", "SampleThresholdTrigger",
    "ScheduledTrigger", "fedavg_delta", "polynomial_staleness", "weighted_average",
    "ResourceManager", "ResourcePool", "TaskManager", "TaskRunner", "TaskScheduler",
    "AccumulatedStrategy", "DispatchPoint", "TimeIntervalStrategy",
    "TimePointStrategy", "discretize_curve",
    "GradeSpec", "OperatorFlow", "Task", "TaskQueue", "register_operator",
    "TrafficCurve", "right_tailed_normal", "table2_curves",
]
