"""SimDC core: the paper's contribution as composable JAX modules."""
from repro.core.allocation import (
    AllocationResult,
    GradeRuntime,
    fixed_ratio_allocation,
    solve_allocation,
    solve_allocation_bruteforce,
)
from repro.core.calibration import (
    RuntimeCalibrator,
    ScheduleEstimate,
    calibrate_runtimes,
    monte_carlo_schedules,
    table1_runtime,
)
from repro.core.deviceflow import Delivery, DeviceFlow, Message, Shelf, VirtualClock
from repro.core.federation import (
    AggregationService,
    ClientCountTrigger,
    SampleThresholdTrigger,
    ScheduledTrigger,
    fedavg_delta,
    fused_fedavg_delta,
    handles_align,
    polynomial_staleness,
    weighted_average,
)
from repro.core.updates import (
    UpdateBuffer,
    UpdateHandle,
    materialize_handles,
)
from repro.core.scheduler import (
    DrainResult,
    ResourceManager,
    ResourcePool,
    StrandedTasksError,
    TaskEngine,
    TaskExecution,
    TaskManager,
    TaskRunner,
    TaskScheduler,
    TaskState,
)
from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchPoint,
    TimeIntervalStrategy,
    TimePointStrategy,
    discretize_curve,
)
from repro.core.simulation import (
    DeviceTier,
    FederatedRoundOutcome,
    GradePlanEntry,
    GradeRoundBreakdown,
    HybridSimulation,
    LogicalTier,
    RoundPlan,
)
from repro.core.serving import (
    ContinuousBatchingEngine,
    ContinuousServer,
    RequestRecord,
    ServeCostModel,
    ServingReport,
)
from repro.core.task import GradeSpec, OperatorFlow, Task, TaskQueue, register_operator
from repro.core.traffic_curves import (
    TrafficCurve,
    arrival_quantiles,
    diurnal,
    right_tailed_normal,
    table2_curves,
)

__all__ = [
    "AllocationResult", "GradeRuntime", "fixed_ratio_allocation",
    "solve_allocation", "solve_allocation_bruteforce",
    "RuntimeCalibrator", "calibrate_runtimes", "table1_runtime",
    "Delivery", "DeviceFlow", "Message", "Shelf", "VirtualClock",
    "DeviceTier", "FederatedRoundOutcome", "GradePlanEntry",
    "GradeRoundBreakdown", "HybridSimulation", "LogicalTier", "RoundPlan",
    "AggregationService", "ClientCountTrigger", "SampleThresholdTrigger",
    "ScheduledTrigger", "fedavg_delta", "fused_fedavg_delta",
    "handles_align", "polynomial_staleness", "weighted_average",
    "UpdateBuffer", "UpdateHandle", "materialize_handles",
    "DrainResult", "ResourceManager", "ResourcePool", "StrandedTasksError",
    "TaskEngine", "TaskExecution", "TaskManager", "TaskRunner", "TaskScheduler",
    "AccumulatedStrategy", "DispatchPoint", "TimeIntervalStrategy",
    "TimePointStrategy", "discretize_curve",
    "GradeSpec", "OperatorFlow", "Task", "TaskQueue", "register_operator",
    "ContinuousBatchingEngine", "ContinuousServer", "RequestRecord",
    "ServeCostModel", "ServingReport",
    "TrafficCurve", "arrival_quantiles", "diurnal", "right_tailed_normal",
    "table2_curves",
]
