"""While-trip-aware HLO text analyzer for roofline terms.

Why text parsing: XLA's ``compiled.cost_analysis()`` counts every ``while``
body ONCE, but our programs scan over layers and microbatches — so FLOPs/bytes
must be multiplied by trip counts, and collective operand bytes are not in
cost_analysis at all.  This module parses the *optimized, partitioned* HLO
(per-device program, shard-local shapes) and walks the call graph:

  cost(entry) = Σ top-level ops + Σ_{while} trips × cost(body ∪ cond)
                               + Σ_{fusion|call} cost(callee)

Trip counts are recovered from the loop-condition computations (the
``s32[] constant(N)`` bound); a caller-supplied fallback covers exotic loops.

Byte accounting: per top-level op, ``operands + outputs`` — fusion call sites
count only their boundary tensors (internal intermediates live in
registers/VMEM), which models TPU fusion better than XLA:CPU's per-op count;
the calibration test (tests/test_roofline.py) pins both flops and bytes
against an unrolled ``cost_analysis`` ground truth.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(
    r"(pred|bf16|f8e4m3fn|f8e5m2|f8e4m3|f16|f32|f64|s8|s16|s32|s64"
    r"|u8|u16|u32|u64|c64|c128)\[([\d,]*)\]"
)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<op>[a-z][a-z0-9\-]*)\((?P<rest>.*)$"
)
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\{\s*$")
_ARG_RE = re.compile(r"%([\w.\-]+)")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes_and_dims(type_str: str) -> tuple[int, list[list[int]]]:
    total = 0
    dims_list = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        shape = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_list.append(shape)
    return total, dims_list


@dataclasses.dataclass
class Op:
    name: str
    op: str
    out_bytes: int
    out_dims: list[list[int]]
    args: list[str]
    attrs: str
    param_idx: int = -1
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    symbols: dict[str, Op]
    root: Op | None = None


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] += int(v * mult)


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for line in text.splitlines():
            if not line.strip():
                continue
            if not line[0].isspace():
                hm = _HEADER_RE.match(line)
                if hm:
                    is_entry = line.startswith("ENTRY")
                    cur = Computation(hm.group("name"), [], {})
                    self.computations[cur.name] = cur
                    if is_entry:
                        self.entry = cur.name
                    continue
                if line.startswith("}"):
                    cur = None
                continue
            if cur is None:
                continue
            om = _OP_RE.match(line)
            if not om:
                continue
            out_bytes, out_dims = _type_bytes_and_dims(om.group("type"))
            # split args from attrs: args end at the matching close paren.
            rest = om.group("rest")
            depth = 1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            args_str, attrs = rest[:i], rest[i + 1:]
            op = Op(
                name=om.group("name"),
                op=om.group("op"),
                out_bytes=out_bytes,
                out_dims=out_dims,
                args=_ARG_RE.findall(args_str),
                attrs=attrs,
            )
            if op.op == "parameter":
                pm = re.match(r"\s*(\d+)", args_str)
                if pm:
                    op.param_idx = int(pm.group(1))
            if line.lstrip().startswith("ROOT"):
                op.is_root = True
                cur.root = op
            cur.ops.append(op)
            cur.symbols[op.name] = op

    # ----------------------------------------------------------------- #
    def trip_count(self, cond_name: str, default: int = 1) -> int:
        """Loop bound = the integer constant in the condition computation
        (``s32[] constant(N)`` compared against the induction variable);
        values are recorded at parse time by ``_attach_const_vals``."""
        comp = self.computations.get(cond_name)
        if comp is None:
            return default
        vals = getattr(comp, "_const_vals", [])
        ints = [v for v in vals if v > 1]
        return max(ints) if ints else default

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = 1
        for shape in op.out_dims:
            for d in shape:
                out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        if not m or not op.args:
            return 2.0 * out_elems  # degenerate
        cdims = [int(x) for x in m.group(1).split(",") if x]
        lhs = comp.symbols.get(op.args[0])
        contract = 1
        if lhs is not None and lhs.out_dims:
            for c in cdims:
                if c < len(lhs.out_dims[0]):
                    contract *= lhs.out_dims[0][c]
        return 2.0 * out_elems * contract

    def _operand_bytes(self, comp: Computation, op: Op) -> int:
        total = 0
        for a in op.args:
            sym = comp.symbols.get(a)
            if sym is not None:
                total += sym.out_bytes
        return total

    def cost(self, comp_name: str | None = None,
             trip_default: int = 1, scoped: bool = False) -> Costs:
        name = comp_name or self.entry
        key = (name, scoped)
        if key in self._memo:
            return self._memo[key]
        comp = self.computations.get(name)
        out = Costs()
        if comp is None:
            return out
        self._memo[key] = out  # pre-insert (cycles impossible but cheap)
        in_scope = scoped or (comp is not None and any(
            "pallas_kernel_region" in o.attrs for o in comp.ops))
        for op in comp.ops:
            op_scoped = in_scope or "pallas_kernel_region" in op.attrs
            if op.op == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = self.trip_count(cm.group(1), trip_default) if cm else 1
                if bm:
                    out.add(self.cost(bm.group(1), trip_default, op_scoped),
                            trips)
                continue
            if op.op in ("fusion", "call", "async-start"):
                cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                callee = self.computations.get(cm.group(1)) if cm else None
                if cm:
                    child = self.cost(cm.group(1), trip_default, op_scoped)
                    out.flops += child.flops
                    for k, v in child.collective_bytes.items():
                        out.collective_bytes[k] += v
                    for k, v in child.collective_count.items():
                        out.collective_count[k] += v
                if not op_scoped:
                    out.bytes += self._fusion_bytes(comp, op, callee)
                continue
            if op.op == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", op.attrs):
                    if m.group(1) in self.computations:
                        out.add(self.cost(m.group(1), trip_default, op_scoped),
                                1.0)
                if not op_scoped:
                    out.bytes += op.out_bytes + self._operand_bytes(comp, op)
                continue
            base = op.op.removesuffix("-start")
            if base in COLLECTIVES:
                b = self._operand_bytes(comp, op)
                out.collective_bytes[base] += b
                out.collective_count[base] += 1
                out.bytes += op.out_bytes + b
                continue
            if op.op == "dot":
                out.flops += self._dot_flops(comp, op)
            if op.op not in _SKIP_BYTES_OPS and not op.op.endswith("-done"):
                if op_scoped:
                    # Pallas-kernel region on the TPU target: intermediates
                    # (scores, decay matrices, online-softmax state) stay in
                    # VMEM.  HBM traffic is operand streaming only — modeled
                    # as the slice loads (KV/x chunk streams).
                    if op.op in ("dynamic-slice", "slice", "gather"):
                        out.bytes += op.out_bytes
                    continue
                out.bytes += self._op_bytes(comp, op)
        return out

    def _fusion_bytes(self, comp: Computation, op: Op,
                      callee: Computation | None) -> int:
        """Boundary traffic of a fusion call site.

        Scan-carry fusions take the FULL stacked (layers, ...) cache/weight
        tensor as an operand but only touch one layer's slice inside; charging
        the full operand overstated decode memory ~150x.  Rules per operand:
        * consumed only via (dynamic-)slice/gather in the callee → charge the
          slice outputs;
        * pass-through alias (callee root is a dynamic-update-slice writing
          into that operand) → charge the update region twice (read+write);
        * otherwise → full operand bytes.
        """
        if callee is None:
            return op.out_bytes + self._operand_bytes(comp, op)
        params = {p.param_idx: p.name for p in callee.ops if p.op == "parameter"}
        root = callee.root
        total = 0
        out_bytes = op.out_bytes
        alias_param = None
        if root is not None and root.op == "dynamic-update-slice" and root.args:
            upd = callee.symbols.get(root.args[1]) if len(root.args) > 1 else None
            if upd is not None:
                out_bytes = 2 * upd.out_bytes
                alias_param = root.args[0]
        for i, a in enumerate(op.args):
            sym = comp.symbols.get(a)
            full = sym.out_bytes if sym is not None else 0
            pname = params.get(i)
            if pname is None:
                total += full
                continue
            if pname == alias_param:
                continue  # in-place carry: already charged via update region
            consumers = [o for o in callee.ops if pname in o.args]
            if consumers and all(
                o.op in ("dynamic-slice", "slice", "gather")
                for o in consumers
            ):
                total += sum(o.out_bytes for o in consumers)
            else:
                total += full
        return out_bytes + total

    def _op_bytes(self, comp: Computation, op: Op) -> int:
        """Bytes-accessed model per op.

        Slicing/gather ops touch only the slice, not the full operand;
        dynamic-update-slice writes only the update region (XLA emits it
        in-place).  Naive out+operands accounting overstated decode memory
        ~100x (full KV-cache "read" per per-layer slice).
        """
        if op.op in ("dynamic-slice", "slice", "gather"):
            return 2 * op.out_bytes  # read slice + write slice
        if op.op in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(op.args) >= 2:
                sym = comp.symbols.get(op.args[1])
                if sym is not None:
                    upd = sym.out_bytes
            return 2 * upd if upd else 2 * op.out_bytes
        if op.op == "broadcast":
            return op.out_bytes
        return op.out_bytes + self._operand_bytes(comp, op)


def _attach_const_vals(module: HloModule, text: str) -> None:
    """Record integer constant values per computation (trip-count bounds)."""
    cur = None
    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            hm = _HEADER_RE.match(line)
            cur = module.computations.get(hm.group("name")) if hm else None
            continue
        if cur is None:
            continue
        m = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = s(?:32|64)\[\] constant\((\d+)\)",
                     line)
        if m:
            if not hasattr(cur, "_const_vals"):
                cur._const_vals = []  # type: ignore[attr-defined]
            cur._const_vals.append(int(m.group(1)))  # type: ignore[attr-defined]


def normalize_cost_analysis(ca: Any) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns a list with one properties-dict per partition; newer
    versions return the dict directly.  Always hand back a plain dict (empty
    when the backend reports nothing).
    """
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def analyze_hlo(text: str) -> dict:
    """Parse one per-device HLO module; return flop/byte/collective totals."""
    mod = HloModule(text)
    _attach_const_vals(mod, text)
    costs = mod.cost()
    return {
        "flops": costs.flops,
        "bytes": costs.bytes,
        "collective_bytes": dict(costs.collective_bytes),
        "collective_count": dict(costs.collective_count),
    }


# --------------------------------------------------------------------------- #
# Roofline terms (TPU v5e constants per the assignment)
# --------------------------------------------------------------------------- #
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

# Effective on-wire multiplier per collective kind (ring algorithms):
# all-reduce = reduce-scatter + all-gather ≈ 2x payload.
_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def roofline_terms(analysis: dict) -> dict:
    coll = sum(
        v * _COLL_FACTOR.get(k, 1.0)
        for k, v in analysis["collective_bytes"].items()
    )
    return {
        "compute_s": analysis["flops"] / PEAK_FLOPS,
        "memory_s": analysis["bytes"] / HBM_BW,
        "collective_s": coll / ICI_BW,
    }


def dominant_term(terms: dict) -> str:
    return max(
        (("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
         ("collective", terms["collective_s"])),
        key=lambda kv: kv[1],
    )[0]
