"""Public entry point for decode attention (single-token, KV cache).

Besides the attention op itself this module carries the KV-*arena* slot
paths used by continuous batching (``core.serving``): a fixed-capacity cache
of shape ``(slots, max_len, kv, d)`` where each row is one request's cache
residency.  Slot writes use out-of-bounds indices as padding sentinels
(``mode="drop"``), so the jitted update has one static shape regardless of
how many requests were admitted this iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import (
    combine_partials,
    decode_attention_partial,
    decode_attention_ref,
)

__all__ = [
    "decode_attention",
    "decode_attention_partial",
    "combine_partials",
    "decode_attention_ref",
    "scatter_prefill_rows",
    "scatter_decode_token",
    "gather_slots",
    "tuned_block_k",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "impl"))
def decode_attention(
    q: jax.Array,  # (b, h, d)
    k_cache: jax.Array,  # (b, s, kv, d)
    v_cache: jax.Array,
    lengths: jax.Array,  # (b,)
    *,
    scale: float | None = None,
    block_k: int = 512,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale, block_k=block_k,
            interpret=not _on_tpu(),
        )
    if impl == "pallas_interpret":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale, block_k=block_k,
            interpret=True,
        )
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")


# --------------------------------------------------------------------------- #
# KV-arena slot paths (continuous batching)
# --------------------------------------------------------------------------- #
def scatter_prefill_rows(cache: jax.Array, rows: jax.Array,
                         slot_ids: jax.Array) -> jax.Array:
    """Write freshly prefilled K/V rows into their arena slots.

    ``cache`` is ``(slots, max_len, kv, d)``; ``rows`` is ``(m, s, kv, d)``
    with ``s <= max_len``; ``slot_ids`` is ``(m,) int32``.  Entries with
    ``slot_ids[i] >= slots`` are padding — their writes drop, so a single
    jitted shape serves any number of admissions.  Rows ``[s:max_len)`` of a
    reused slot keep the previous occupant's stale K/V; they are dead by
    construction because the slot's length counter is reset to ``s``.
    """
    s = rows.shape[1]
    return cache.at[slot_ids, :s].set(rows, mode="drop")


def scatter_decode_token(cache: jax.Array, kv_tok: jax.Array,
                         write_pos: jax.Array) -> jax.Array:
    """Write one decoded token's K/V at each slot's own cache position.

    ``cache`` is ``(slots, max_len, kv, d)``; ``kv_tok`` is ``(slots, kv, d)``;
    ``write_pos`` is ``(slots,) int32`` — per-slot ragged positions.  Inactive
    slots pass ``write_pos >= max_len`` and their writes drop.
    """
    slots = cache.shape[0]
    return cache.at[jnp.arange(slots, dtype=jnp.int32), write_pos].set(
        kv_tok, mode="drop")


def gather_slots(cache: jax.Array, slot_ids: jax.Array) -> jax.Array:
    """Gather ``(m, max_len, kv, d)`` slot rows (e.g. to migrate or inspect a
    request's cache residency); out-of-bounds ids fill with zeros."""
    return cache.at[slot_ids].get(mode="fill", fill_value=0)


def tuned_block_k(max_len: int, *, head_dim: int = 128,
                  vmem_budget_bytes: int = 1 << 18) -> int:
    """Pick the flash-decoding K-block for an arena-scale cache.

    At arena scale the cache is ``slots * max_len`` rows; each grid step
    streams one ``(block_k, d)`` K tile plus its V tile through VMEM.  Pick
    the largest power-of-two block whose two f32 tiles fit the budget
    (default 256 KiB — conservative slice of the ~16 MiB VMEM so the q/o
    tiles and double-buffering fit alongside), clamped to the padded cache
    length so short caches stay a single block.
    """
    if max_len < 1:
        raise ValueError("max_len must be >= 1")
    best = 128
    for cand in (256, 512, 1024):
        if 2 * cand * head_dim * 4 <= vmem_budget_bytes:
            best = cand
    padded = max(128, 1 << (max_len - 1).bit_length())
    return min(best, padded)
