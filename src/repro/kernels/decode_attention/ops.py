"""Public entry point for decode attention (single-token, KV cache)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import (
    combine_partials,
    decode_attention_partial,
    decode_attention_ref,
)

__all__ = [
    "decode_attention",
    "decode_attention_partial",
    "combine_partials",
    "decode_attention_ref",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "impl"))
def decode_attention(
    q: jax.Array,  # (b, h, d)
    k_cache: jax.Array,  # (b, s, kv, d)
    v_cache: jax.Array,
    lengths: jax.Array,  # (b,)
    *,
    scale: float | None = None,
    block_k: int = 512,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale, block_k=block_k,
            interpret=not _on_tpu(),
        )
    if impl == "pallas_interpret":
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths, scale=scale, block_k=block_k,
            interpret=True,
        )
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")
