"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(
    q: jax.Array,  # (b, h, d) — one new token per sequence
    k_cache: jax.Array,  # (b, s, kv, d)
    v_cache: jax.Array,  # (b, s, kv, d)
    lengths: jax.Array,  # (b,) int32 — valid cache entries per sequence
    *,
    scale: float | None = None,
) -> jax.Array:
    b, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = (d ** -0.5) if scale is None else scale
    return _decode_scoped(q, k_cache, v_cache, lengths, scale, b, kv, g, d)


def _decode_scoped(q, k_cache, v_cache, lengths, scale, b, kv, g, d):
    """Kernel-region scope: executes as the Pallas flash-decoding kernel on
    TPU (scores in VMEM; HBM traffic = one cache stream + q/o)."""
    import jax
    with jax.named_scope("pallas_kernel_region"):
        return _decode_impl(q, k_cache, v_cache, lengths, scale, b, kv, g, d)


def _decode_impl(q, k_cache, v_cache, lengths, scale, b, kv, g, d):
    # Keep the cache in its storage dtype; accumulate in f32 on the MXU —
    # casting the cache to f32 would triple decode HBM traffic (§Perf).
    qg = (q.reshape(b, kv, g, d) * scale).astype(q.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(k_cache.shape[1])[None] < lengths[:, None]  # (b, s)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, kv * g, d).astype(q.dtype)
    # length-0 rows (a retired / never-filled KV-arena slot): the all-masked
    # softmax degenerates to uniform weights over garbage — return exact
    # zeros instead, matching the Pallas kernel's empty-accumulator output.
    return jnp.where(lengths[:, None, None] > 0, o, jnp.zeros_like(o))


def decode_attention_partial(
    q: jax.Array,  # (b, h, d)
    k_cache: jax.Array,  # (b, s_shard, kv, d) — one *shard* of the cache
    v_cache: jax.Array,
    lengths: jax.Array,  # (b,) valid entries in THIS shard
    *,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decoding partial results for cross-shard combination.

    Returns ``(o_partial, m, l)`` where the final output across shards is
    ``sum_i o_i * exp(m_i - m) * l_i / sum_i exp(m_i - m) * l_i`` — the
    sequence-parallel decode combine used by ``distribution.steps`` (psum over
    the ``sp`` axis).  o_partial is the *unnormalized-but-locally-normalized*
    softmax output of this shard.
    """
    b, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, kv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    mask = jnp.arange(k_cache.shape[1])[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(axis=-1)  # (b, kv, g)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return (
        o.reshape(b, h, d),
        m.reshape(b, h),
        l.reshape(b, h),
    )


def combine_partials(
    os: jax.Array,  # (n_shards, b, h, d)
    ms: jax.Array,  # (n_shards, b, h)
    ls: jax.Array,  # (n_shards, b, h)
    out_dtype=None,
) -> jax.Array:
    m = ms.max(axis=0)  # (b, h)
    w = jnp.exp(ms - m[None])  # (n, b, h)
    l = (ls * w).sum(axis=0)
    o = (os * w[..., None]).sum(axis=0)
    out = o / jnp.maximum(l, 1e-37)[..., None]
    return out.astype(out_dtype or os.dtype)
