"""Pallas TPU flash-decoding kernel: one query token vs a long KV cache.

Decode is memory-bound: the entire KV cache must stream HBM→VMEM once per
step, and the MXU work per block is tiny.  The TPU adaptation therefore
optimizes for *streaming*:

* grid ``(batch, kv_heads, num_kv_blocks)`` — KV blocks innermost so the
  (m, l, acc) online-softmax state for all ``g = h/kv`` grouped query heads
  rides in VMEM scratch across the stream;
* all ``g`` query heads of a KV group are processed together as the rows of a
  single ``(g, d) x (d, block_k)`` MXU op, amortizing each streamed KV block
  over the whole group (the GPU flash-decoding equivalent splits over SMs and
  combines in a second pass — on TPU the sequential grid does the combine for
  free within a core, while the *cross-shard* combine for a sequence-sharded
  cache is a 3-scalar psum handled in ``distribution.steps``);
* variable cache lengths are masked in-kernel from a per-batch ``lengths``
  input so padded cache tail blocks contribute exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, nk: int, block_k: int, scale: float,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0]  # (g, d)
        k = k_ref[0, :, 0, :]  # (block_k, d)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            (q * scale).astype(q.dtype), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (g, block_k)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # (b, h, d)
    k_cache: jax.Array,  # (b, s, kv, d)
    v_cache: jax.Array,  # (b, s, kv, d)
    lengths: jax.Array,  # (b,) int32
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    assert h % kvh == 0
    g = h // kvh
    scale = (d ** -0.5) if scale is None else scale
    block_k = min(block_k, s)
    nk = -(-s // block_k)
    s_p = nk * block_k
    if s_p != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
    qg = q.reshape(b, kvh, g, d)

    kernel = functools.partial(
        _decode_kernel, nk=nk, block_k=block_k, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ki: (bi,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, ki: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)
