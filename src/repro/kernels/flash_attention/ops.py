"""Public entry point for flash attention.

Dispatch: Pallas kernel on TPU backends (or when ``interpret`` is forced for
validation), lowerable chunked-jnp implementation elsewhere (CPU dry-runs,
grad support).  The chunked implementation is the same online-softmax math,
so the two paths are interchangeable bit-for-tolerance (tests enforce this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_chunked, attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "q_offset", "scale", "block_q", "block_k", "impl"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "auto",  # auto | pallas | pallas_interpret | chunked | ref
) -> jax.Array:
    """Multi-head/GQA attention: q (b,sq,h,d), k/v (b,sk,kv,d) -> (b,sq,h,d)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked"
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale,
            block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
        )
    if impl == "pallas_interpret":
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale,
            block_q=block_q, block_k=block_k, interpret=True,
        )
    if impl == "chunked":
        # No q-chunking on the lowerable path: a python loop of static
        # q-slices over the sp-sharded sequence dim makes GSPMD emit a
        # collective-permute/all-to-all per slice (§Perf iteration 5:
        # 188 GB/step of cp+a2a on phi3-medium train_4k).  The kv-chunk scan
        # alone bounds the working set; on-chip q-blocking lives in the
        # Pallas kernel where it belongs.
        return attention_chunked(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale,
            q_chunk=q.shape[1], kv_chunk=block_k * 8,
        )
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, q_offset=q_offset, scale=scale)
    raise ValueError(f"unknown impl {impl!r}")
