"""Pallas TPU flash attention (GQA, causal) — prefill/training kernel.

TPU adaptation notes (vs the CUDA FlashAttention algorithm):
* the grid is ``(batch, q_heads, num_q_blocks, num_kv_blocks)`` with the KV
  block dimension innermost — TPU grids execute sequentially over the last
  axis, so the online-softmax running state (m, l, acc) lives in **VMEM
  scratch** that persists across KV steps (no atomics / shared-memory
  reductions as on GPU);
* block shapes are MXU-aligned: ``block_q x head_dim`` and
  ``block_k x head_dim`` tiles feed the 128x128 systolic array directly;
* GQA is expressed in the BlockSpec ``index_map`` — the kv-head index is
  ``q_head // group_size``, so no materialized ``repeat`` of K/V ever leaves
  HBM (the XLA baseline pays that cost; see EXPERIMENTS.md §Perf).

VMEM budget per grid step (bf16 inputs, f32 scratch):
``block_q*d*2 + 2*block_k*d*2 + block_q*block_k*4 (transient) +
block_q*(4 + 4 + 4*d)`` — at the default 128/128 blocks and d=128 this is
~0.33 MB, far under the ~16 MB/core VMEM limit, leaving room for Mosaic's
double-buffering of the K/V streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, scale: float, nk: int, block_q: int, block_k: int,
    q_offset: int, kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k
    if causal:
        # Skip KV blocks strictly above the causal diagonal.
        should_compute = k_start <= q_start + block_q - 1
    else:
        should_compute = k_start < kv_len

    @pl.when(should_compute)
    def _compute():
        q = q_ref[0, :, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            (q * scale).astype(q.dtype), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (block_q, block_k)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        valid = kpos < kv_len
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            valid &= qpos >= kpos
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (b, sq, h, d)
    k: jax.Array,  # (b, sk, kv, d)
    v: jax.Array,  # (b, sk, kv, d)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    assert h % kvh == 0, "q heads must be a multiple of kv heads"
    g = h // kvh
    scale = (d ** -0.5) if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    sq_p, sk_p = nq * block_q, nk * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=scale,
        nk=nk,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
        kv_len=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
