"""Pure-jnp oracle for flash attention (GQA, optional causal).

``attention_ref`` is the numerically-straightforward O(S^2)-memory oracle the
Pallas kernel is tested against.  ``attention_chunked`` is a lowerable
online-softmax implementation with O(S * chunk) working set used by the model
code on non-TPU backends and inside dry-run lowering (it is what the TPU
kernel computes, expressed in jnp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _expand_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(b, s, kv, d) -> (b, s, kv, group, d) view helper count."""
    return num_q_heads // k.shape[2]


def attention_ref(
    q: jax.Array,  # (b, sq, h, d)
    k: jax.Array,  # (b, sk, kv, d)
    v: jax.Array,  # (b, sk, kv, d)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    assert h % kv == 0, "q heads must be a multiple of kv heads"
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, sq, kv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def attention_chunked(
    q: jax.Array,  # (b, sq, h, d)
    k: jax.Array,  # (b, sk, kv, d)
    v: jax.Array,  # (b, sk, kv, d)
    *,
    causal: bool = True,
    q_offset: int = 0,
    scale: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax chunked attention; identical math to the Pallas kernel.

    Never materializes more than (q_chunk x kv_chunk) scores per (b, kv-head,
    group).  Fully lowerable on any backend; causal blocks are skipped via the
    scan bound when chunk alignment allows.

    The body is wrapped in ``named_scope("pallas_kernel_region")``: on the TPU
    target this region executes as the Pallas flash kernel (scores never
    leave VMEM), and the roofline analyzer uses kernel-boundary byte
    accounting for ops under this scope.
    """
    return _attention_chunked_scoped(
        q, k, v, causal=causal, q_offset=q_offset, scale=scale,
        q_chunk=q_chunk, kv_chunk=kv_chunk)


def _attention_chunked_scoped(q, k, v, *, causal, q_offset, scale, q_chunk,
                              kv_chunk):
    with jax.named_scope("pallas_kernel_region"):
        return _attention_chunked_impl(
            q, k, v, causal=causal, q_offset=q_offset, scale=scale,
            q_chunk=q_chunk, kv_chunk=kv_chunk)


def _attention_chunked_impl(q, k, v, *, causal, q_offset, scale, q_chunk,
                            kv_chunk):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = (d ** -0.5) if scale is None else scale
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    # Pad to multiples (masked out below).
    sq_p, sk_p = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, q_chunk, kvh, g, d)

    def q_block(qi, qc):
        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)

        def kv_block(carry, kj):
            m, l, o = carry
            kc = jax.lax.dynamic_slice_in_dim(kp, kj * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(vp, kj * kv_chunk, kv_chunk, 1)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs",
                (qc * scale).astype(q.dtype),
                kc,
                preferred_element_type=jnp.float32,
            )
            qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            valid = (kpos < sk)[None, :] & (qpos < q_offset + sq)[:, None]
            if causal:
                valid &= qpos[:, None] >= kpos[None, :]
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            mn = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - mn[..., None])
            alpha = jnp.exp(m - mn)
            ln = l * alpha + p.sum(-1)
            on = o * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(q.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (mn, ln, on), None

        if causal:
            # Only blocks with kj*kv_chunk <= q_offset + (qi+1)*q_chunk - 1.
            hi = jnp.minimum(
                (q_offset + (qi + 1) * q_chunk + kv_chunk - 1) // kv_chunk, nk
            )
            (m, l, o), _ = jax.lax.scan(
                lambda c, kj: jax.lax.cond(
                    kj < hi, lambda: kv_block(c, kj), lambda: (c, None)
                ),
                (m0, l0, o0),
                jnp.arange(nk),
            )
        else:
            (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), jnp.arange(nk))
        l = jnp.maximum(l, 1e-37)
        out = (o / l[..., None]).astype(q.dtype)  # (b, kvh, g, q_chunk, d)
        return out.transpose(0, 3, 1, 2, 4)  # (b, q_chunk, kvh, g, d)

    outs = [q_block(qi, qp[:, qi]) for qi in range(nq)]
    out = jnp.concatenate(outs, axis=1)[:, :sq]
    return out.reshape(b, sq, h, d)
