"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the Mamba2 "state-space duality" algorithm (the CUDA
original splits work across thread blocks with a separate state-passing pass;
Triton kernels recompute decays per block):

* grid ``(batch, heads, num_chunks)`` — chunks innermost and *sequential*,
  so the running state ``S (P x N)`` lives in f32 VMEM scratch across chunk
  steps: the inter-chunk recurrence costs zero extra HBM traffic (the GPU
  version round-trips chunk states through global memory);
* the intra-chunk quadratic part is three MXU matmuls —
  ``C @ B^T (Q x Q)``, ``M @ X (Q x P)``, state injection ``C @ S^T`` — all
  on 64/128-aligned tiles;
* decays are computed in f32 on the VPU from a single in-chunk cumsum; the
  ``exp(L_t - L_s)`` matrix is built once per chunk in VMEM.

VMEM per step (Q=chunk, P=headdim, N=state): inputs ``Q*(P+2N+1)*4`` +
scratch ``P*N*4`` + transient ``Q*Q*4`` ≈ 0.25 MB at Q=128, P=64, N=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_scr,
    *, nc: int, q: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    xc = x_ref[0, :, 0, :].astype(jnp.float32)  # (q, p)
    dtc = dt_ref[0, :, 0].astype(jnp.float32)  # (q,)
    A = a_ref[0]  # scalar (this head's decay rate)
    Bc = b_ref[0, :, 0, :].astype(jnp.float32)  # (q, n)
    Cc = c_ref[0, :, 0, :].astype(jnp.float32)  # (q, n)

    alog = dtc * A
    L = jnp.cumsum(alog)  # (q,) inclusive
    # Intra-chunk quadratic part.
    CB = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, q) = C_t . B_s
    decay = jnp.exp(L[:, None] - L[None, :])
    tpos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    M = jnp.where(tpos >= spos, CB * decay, 0.0) * dtc[None, :]
    y = jax.lax.dot_general(
        M, xc, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, p)
    # Inter-chunk contribution from the carried state.
    S = s_scr[...]  # (p, n)
    y += jnp.exp(L)[:, None] * jax.lax.dot_general(
        Cc, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, n) . (p, n)^T -> (q, p)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # State update.
    Lq = L[-1]
    w = jnp.exp(Lq - L) * dtc  # (q,)
    s_scr[...] = jnp.exp(Lq) * S + jax.lax.dot_general(
        xc * w[:, None], Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (p, n)

    @pl.when(ci == nc - 1)
    def _emit_state():
        sfin_ref[0, 0] = s_scr[...]


def ssd_scan_pallas(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, l, g, n)
    C: jax.Array,  # (b, l, g, n)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    rep = h // g
    assert l % chunk == 0, "length must be a multiple of the chunk size"
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, nc=nc, q=chunk)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,), memory_space=pltpu.SMEM),
            pl.BlockSpec(
                (1, chunk, 1, n), lambda bi, hi, ci, rep=rep: (bi, ci, hi // rep, 0)
            ),
            pl.BlockSpec(
                (1, chunk, 1, n), lambda bi, hi, ci, rep=rep: (bi, ci, hi // rep, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C)
    return y, s_fin
