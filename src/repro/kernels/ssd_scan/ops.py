"""Public entry point for the Mamba2 SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ref import ssd_chunked, ssd_decode_step, ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas

__all__ = ["ssd_scan", "ssd_decode_step", "ssd_ref", "ssd_chunked"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h) positive
    A: jax.Array,  # (h,) negative
    B: jax.Array,  # (b, l, g, n)
    C: jax.Array,  # (b, l, g, n)
    *,
    chunk: int = 64,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked"
    l = x.shape[1]
    chunk = min(chunk, l)
    if l % chunk:
        # Pad to a chunk multiple with identity steps: dt=0 gives decay
        # exp(0)=1 and zero input contribution, so y/state are exact.
        pad = chunk - l % chunk
        padt = lambda a: jax.numpy.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        y, s = ssd_scan(padt(x), padt(dt), A, padt(B), padt(C),
                        chunk=chunk, impl=impl)
        return y[:, :l], s
    if impl == "pallas":
        return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=not _on_tpu())
    if impl == "pallas_interpret":
        return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    if impl == "chunked":
        return ssd_chunked(x, dt, A, B, C, chunk=chunk)
    if impl == "ref":
        return ssd_ref(x, dt, A, B, C)
    raise ValueError(f"unknown impl {impl!r}")
