"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Per head ``h`` with state ``S in R^{P x N}`` (P = head dim, N = state dim):

    a_t = exp(dt_t * A_h)                       (scalar decay, A_h < 0)
    S_t = a_t * S_{t-1} + dt_t * x_t (x) B_t     (outer product update)
    y_t = S_t @ C_t  (+ D_h * x_t skip)

``ssd_ref`` is the sequential-scan oracle; ``ssd_chunked`` is the chunked
(SSD) algorithm — quadratic within a chunk, linear across chunks — which is
what the Pallas kernel implements and what the model code lowers on non-TPU
backends.  ``ssd_decode_step`` is the O(1) single-token state update used by
``serve_step``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h) — positive (post-softplus)
    A: jax.Array,  # (h,) — negative
    B: jax.Array,  # (b, l, g, n)
    C: jax.Array,  # (b, l, g, n)
    *,
    init_state: jax.Array | None = None,  # (b, h, p, n)
) -> tuple[jax.Array, jax.Array]:
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (b, l, h, n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    S0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        a = jnp.exp(dtt * A[None])  # (b, h)
        S = a[..., None, None] * S + (dtt[..., None] * xt)[..., None] * Bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", S, Ct)
        return S, y

    xs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        Bh.transpose(1, 0, 2, 3),
        Ch.transpose(1, 0, 2, 3),
    )
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), S_fin.astype(jnp.float32)


def ssd_chunked(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, l, g, n)
    C: jax.Array,  # (b, l, g, n)
    *,
    chunk: int = 64,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: O(L/Q) sequential steps of O(Q^2) intra-chunk work.

    Wrapped in the ``pallas_kernel_region`` scope: the TPU target runs this as
    the ssd_scan Pallas kernel (state + decay matrices VMEM-resident).
    """
    with jax.named_scope("pallas_kernel_region"):
        return _ssd_chunked_impl(x, dt, A, B, C, chunk=chunk,
                                 init_state=init_state)


def _ssd_chunked_impl(x, dt, A, B, C, *, chunk, init_state):
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert l % chunk == 0, "length must be a multiple of the chunk size"
    nc, q = l // chunk, chunk
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32).reshape(b, nc, q, h, n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32).reshape(b, nc, q, h, n)
    S0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def chunk_step(S, inp):
        xc, dtc, Bc, Cc = inp  # (b,q,h,p), (b,q,h), (b,q,h,n), (b,q,h,n)
        alog = dtc * A[None, None]  # (b, q, h) — log decay per step
        L = jnp.cumsum(alog, axis=1)  # inclusive cumsum
        # Intra-chunk: M[t,s] = (C_t . B_s) exp(L_t - L_s) dt_s  for s <= t.
        CB = jnp.einsum("bqhn,bshn->bhqs", Cc, Bc)
        decay = jnp.exp(L.transpose(0, 2, 1)[:, :, :, None]
                        - L.transpose(0, 2, 1)[:, :, None, :])
        causal = jnp.tril(jnp.ones((q, q), bool))
        M = jnp.where(causal[None, None], CB * decay, 0.0)
        M = M * dtc.transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhqs,bshp->bqhp", M, xc)
        # Inter-chunk: y_t += exp(L_t) * (S0 @ C_t).
        y += jnp.exp(L)[..., None] * jnp.einsum("bhpn,bqhn->bqhp", S, Cc)
        # State update: S' = exp(L_Q) S + sum_s exp(L_Q - L_s) dt_s x_s (x) B_s.
        Lq = L[:, -1]  # (b, h)
        w = jnp.exp(Lq[:, None] - L) * dtc  # (b, q, h)
        S_new = jnp.exp(Lq)[..., None, None] * S + jnp.einsum(
            "bqhp,bqhn->bhpn", w[..., None] * xc, Bc
        )
        return S_new, y

    xs = (
        xf.transpose(1, 0, 2, 3, 4),
        dtf.transpose(1, 0, 2, 3),
        Bh.transpose(1, 0, 2, 3, 4),
        Ch.transpose(1, 0, 2, 3, 4),
    )
    S_fin, ys = jax.lax.scan(chunk_step, S0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    return y.astype(x.dtype), S_fin


def ssd_decode_step(
    x: jax.Array,  # (b, h, p)
    dt: jax.Array,  # (b, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, g, n)
    C: jax.Array,  # (b, g, n)
    state: jax.Array,  # (b, h, p, n)
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD update (serving decode path)."""
    h = x.shape[1]
    rep = h // B.shape[1]
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(dt.astype(jnp.float32) * A[None])
    state = a[..., None, None] * state + (
        (dt.astype(jnp.float32)[..., None] * x.astype(jnp.float32))[..., None]
        * Bh[..., None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state
