from repro.kernels.fed_reduce.ops import fed_reduce, fed_reduce_ref

__all__ = ["fed_reduce", "fed_reduce_ref"]
