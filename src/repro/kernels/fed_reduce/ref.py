"""Pure-jnp reference for the fused federated weighted reduction.

``fed_reduce_ref`` is the single-leaf oracle (f32 accumulation, like the
kernel) and also the fast CPU execution path when no TPU is attached
(``impl="auto"`` outside TPU) — one fused XLA op, not a Python loop.

Perf note: keep the operand 2-D at the call site.  A >2-D ``stack`` forces
the ``reshape`` below into the compiled graph, which knocks XLA CPU off the
BLAS matmul path for the reduction (~40x slower); the round engine's
``UpdateBuffer`` stores leaves as ``(rows, size)`` for exactly this reason.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fed_reduce_ref(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted row-sum ``out = sum_i weights[i] * stack[i]`` in f32.

    ``stack``: (n, ...) — any trailing shape; ``weights``: (n,).  Returns the
    trailing shape in float32 (accumulation dtype; callers cast).
    """
    n = stack.shape[0]
    # The astype also serves the fused dequantize-and-reduce path (int8
    # stacks with scales pre-folded into ``weights`` by ``ops.fed_reduce``):
    # XLA fuses the convert into the dot's operand read, so the int8 stack
    # is never materialized as a dense f32 copy in HBM.
    flat = stack.reshape(n, -1).astype(jnp.float32)
    out = jnp.tensordot(weights.astype(jnp.float32), flat, axes=1)
    return out.reshape(stack.shape[1:])
