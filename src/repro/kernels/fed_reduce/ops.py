"""Public entry point for the fused federated update reduction.

``fed_reduce`` reduces one stacked ``(rows, ...)`` leaf to an *unnormalized*
weighted sum, so partial reductions over several buffers can be combined
before dividing by the total weight (see ``federation.fused_fedavg_delta``,
which maps it over every ``(rows, size)`` leaf of an ``UpdateBuffer``).

Implementations:

* ``pallas`` — the TPU kernel (MXU matmul accumulation, f32);
* ``pallas_interpret`` — the same kernel under the Pallas interpreter, the
  CPU-CI correctness path;
* ``ref`` — fused jnp ``tensordot`` (also the fast CPU execution path);
* ``auto`` — ``pallas`` on TPU, ``ref`` elsewhere.

**Mesh sharding.**  ``fed_reduce(..., mesh=...)`` shards the row dimension
over the mesh's ``dp`` axis with ``shard_map`` + ``psum``: each fleet shard
reduces its slice of the stacked rows with the selected implementation, then
the per-shard partial sums combine across the axis.  Rows are zero-weight
padded up to shard divisibility — padding contributes exactly 0 to the
weighted sum, so the sharded result matches the unsharded one bit-for-bit
per shard and within accumulation tolerance across shards.

**Fused dequantize-and-reduce.**  ``fed_reduce(stack, weights, scales=...)``
consumes a *quantized* int8 stack (``UpdateBuffer(wire="int8")`` leaves):
``out[d] = sum_i weights[i] * scales[i] * stack[i, d]``.  Because symmetric
per-row quantization is linear per row, the per-row scales fold straight
into the weight vector (``weights * scales``) **before** the reduction — the
MXU/BLAS matmul consumes the int8 rows directly (cast per-block in VMEM on
the kernel path, convert-fused-into-dot on the jnp ref path), and no dense
f32 copy of the stack is ever materialized.  The mesh path pads the folded
weights with zeros exactly like the unquantized path, so padding rows still
contribute exactly 0.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.sanitizers import hot_path
from repro.kernels.fed_reduce.fed_reduce import fed_reduce_pallas
from repro.kernels.fed_reduce.ref import fed_reduce_ref

__all__ = ["fed_reduce", "fed_reduce_ref", "tuned_blocks"]

# int8 min tile on TPU is (32, 128); f32/bf16 tiles are coarser but (32, 128)
# stays legal for every dtype the wire formats produce, so it is the blocking
# floor everywhere.
_MIN_BLOCK_N = 32
_MIN_BLOCK_D = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tuned_blocks(rows: int, size: int, dtype,
                 *, vmem_budget_bytes: int = 1 << 20) -> tuple[int, int]:
    """Pick ``(block_n, block_d)`` for ``fed_reduce_pallas`` from the stack
    shape and wire dtype (mirrors ``decode_attention.ops.tuned_block_k``).

    Each grid step streams one ``(block_n, block_d)`` stack tile at its
    *wire* width — 1 byte/element for a quantized int8 stack, 2 for bf16,
    4 for f32 — plus the f32 weight slice and accumulator.  Pick the largest
    power-of-two blocks whose tile fits the budget (default 1 MiB — a
    conservative slice of the ~16 MiB VMEM leaving room for
    double-buffering; f32 lands on the kernel's historical (256, 512)
    default), growing ``block_n`` first: taller tiles amortize the
    f32 accumulator re-read across more rows, and an int8 stack affords a
    4x taller tile than f32 for the same HBM traffic.  Blocks clamp to the
    padded stack shape so small cohorts stay a single tile instead of
    padding rows/columns 8x past the data.

    ``FED_REDUCE_BLOCKS="<block_n>,<block_d>"`` in the environment overrides
    the table outright (bench sweeps, regression pinning).
    """
    override = os.environ.get("FED_REDUCE_BLOCKS")
    if override:
        try:
            bn, bd = (int(v) for v in override.split(","))
        except ValueError:
            raise ValueError(
                f"FED_REDUCE_BLOCKS must be 'block_n,block_d', "
                f"got {override!r}") from None
        return bn, bd
    if rows < 1 or size < 1:
        raise ValueError(f"need rows, size >= 1, got ({rows}, {size})")
    itemsize = jnp.dtype(dtype).itemsize
    block_n, block_d = _MIN_BLOCK_N, _MIN_BLOCK_D
    grow_n = True  # alternate, rows first
    while True:
        cand_n, cand_d = (2 * block_n, block_d) if grow_n \
            else (block_n, 2 * block_d)
        tile = cand_n * cand_d * itemsize + cand_n * 4 + cand_d * 4
        if tile > vmem_budget_bytes or cand_n > 1024 or cand_d > 2048:
            if grow_n:  # rows capped out; try one more column doubling
                grow_n = False
                continue
            break
        block_n, block_d = cand_n, cand_d
        grow_n = not grow_n
    pad_n = max(_MIN_BLOCK_N, 1 << (rows - 1).bit_length())
    pad_d = max(_MIN_BLOCK_D, 1 << (size - 1).bit_length())
    return min(block_n, pad_n), min(block_d, pad_d)


def _fed_reduce_local(stack: jax.Array, weights: jax.Array,
                      impl: str) -> jax.Array:
    if impl == "ref":
        return fed_reduce_ref(stack, weights)
    if impl in ("pallas", "pallas_interpret"):
        n = stack.shape[0]
        flat = stack.reshape(n, -1)
        bn, bd = tuned_blocks(n, flat.shape[1], stack.dtype)
        out = fed_reduce_pallas(
            flat, weights, block_n=bn, block_d=bd,
            interpret=(impl == "pallas_interpret" or not _on_tpu()))
        return out.reshape(stack.shape[1:])
    raise ValueError(f"unknown impl {impl!r}")


@hot_path
def fed_reduce(stack: jax.Array, weights: jax.Array, *,
               scales: jax.Array | None = None,
               impl: str = "auto", mesh=None,
               axis: str = "dp") -> jax.Array:
    """Weighted row-sum ``sum_i weights[i] * stack[i]`` -> f32 ``stack[0]``
    shape.  ``stack``: (n, ...); ``weights``: (n,).

    ``scales`` (f32 ``(n,)``, from a quantized ``UpdateBuffer`` scale
    column) selects the fused dequantize-and-reduce variant:
    ``sum_i weights[i] * scales[i] * stack[i]`` over an int8 stack, with the
    scales folded into the weight vector so the reduction itself is
    unchanged (module docstring).

    ``mesh`` (a ``jax.sharding.Mesh`` containing ``axis``) distributes the
    row reduction across fleet shards; ``None`` keeps the single-device
    path.
    """
    # Explicit h2d up front: callers may hand numpy stacks (tests, host
    # emission paths), and the reduction must stay implicit-transfer-free
    # under transfer_guard("disallow").
    stack = jnp.asarray(stack)
    weights = jnp.asarray(weights)
    if scales is not None:
        scales = jnp.asarray(scales)
    if stack.ndim < 1 or stack.shape[0] != weights.shape[0]:
        raise ValueError(
            f"stack rows {stack.shape} must match weights {weights.shape}")
    if scales is not None:
        if scales.shape != weights.shape:
            raise ValueError(
                f"scales {scales.shape} must match weights {weights.shape}")
        # Per-row dequantization is linear, so it folds into the MXU weight
        # vector; a zero weight still zeroes the whole row.
        weights = weights.astype(jnp.float32) * scales.astype(jnp.float32)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if mesh is None:
        return _fed_reduce_local(stack, weights, impl)
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    shards = int(mesh.shape[axis])
    n = int(stack.shape[0])
    pad = (-n) % shards
    if pad:
        # Zero-weight rows contribute exactly 0 to the weighted sum.  The
        # pad rows are built on host and device_put explicitly: an eager
        # jnp.zeros broadcasts a host scalar, an implicit transfer under
        # the @hot_path guard.
        stack = jnp.concatenate(
            [stack,
             jnp.asarray(np.zeros((pad,) + stack.shape[1:], stack.dtype))])
        weights = jnp.concatenate(
            [weights, jnp.asarray(np.zeros((pad,), weights.dtype))])
    row_spec = P(axis, *([None] * (stack.ndim - 1)))
    # Shard the operands onto the mesh EXPLICITLY: letting shard_map
    # reshard a single-device operand is an implicit transfer and trips
    # the @hot_path transfer guard.
    stack = jax.device_put(stack, NamedSharding(mesh, row_spec))
    weights = jax.device_put(weights, NamedSharding(mesh, P(axis)))

    def _shard_reduce(s, w):
        return jax.lax.psum(_fed_reduce_local(s, w, impl), axis)

    return shard_map(
        _shard_reduce, mesh=mesh, in_specs=(row_spec, P(axis)),
        out_specs=P(*([None] * (stack.ndim - 1))))(stack, weights)
