"""Public entry point for the fused federated update reduction.

``fed_reduce`` reduces one stacked ``(rows, ...)`` leaf to an *unnormalized*
weighted sum, so partial reductions over several buffers can be combined
before dividing by the total weight (see ``federation.fused_fedavg_delta``,
which maps it over every ``(rows, size)`` leaf of an ``UpdateBuffer``).

Implementations:

* ``pallas`` — the TPU kernel (MXU matmul accumulation, f32);
* ``pallas_interpret`` — the same kernel under the Pallas interpreter, the
  CPU-CI correctness path;
* ``ref`` — fused jnp ``tensordot`` (also the fast CPU execution path);
* ``auto`` — ``pallas`` on TPU, ``ref`` elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fed_reduce.fed_reduce import fed_reduce_pallas
from repro.kernels.fed_reduce.ref import fed_reduce_ref

__all__ = ["fed_reduce", "fed_reduce_ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fed_reduce(stack: jax.Array, weights: jax.Array, *,
               impl: str = "auto") -> jax.Array:
    """Weighted row-sum ``sum_i weights[i] * stack[i]`` -> f32 ``stack[0]``
    shape.  ``stack``: (n, ...); ``weights``: (n,)."""
    if stack.ndim < 1 or stack.shape[0] != weights.shape[0]:
        raise ValueError(
            f"stack rows {stack.shape} must match weights {weights.shape}")
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return fed_reduce_ref(stack, weights)
    if impl in ("pallas", "pallas_interpret"):
        n = stack.shape[0]
        flat = stack.reshape(n, -1)
        out = fed_reduce_pallas(
            flat, weights,
            interpret=(impl == "pallas_interpret" or not _on_tpu()))
        return out.reshape(stack.shape[1:])
    raise ValueError(f"unknown impl {impl!r}")
