"""Public entry point for the fused federated update reduction.

``fed_reduce`` reduces one stacked ``(rows, ...)`` leaf to an *unnormalized*
weighted sum, so partial reductions over several buffers can be combined
before dividing by the total weight (see ``federation.fused_fedavg_delta``,
which maps it over every ``(rows, size)`` leaf of an ``UpdateBuffer``).

Implementations:

* ``pallas`` — the TPU kernel (MXU matmul accumulation, f32);
* ``pallas_interpret`` — the same kernel under the Pallas interpreter, the
  CPU-CI correctness path;
* ``ref`` — fused jnp ``tensordot`` (also the fast CPU execution path);
* ``auto`` — ``pallas`` on TPU, ``ref`` elsewhere.

**Mesh sharding.**  ``fed_reduce(..., mesh=...)`` shards the row dimension
over the mesh's ``dp`` axis with ``shard_map`` + ``psum``: each fleet shard
reduces its slice of the stacked rows with the selected implementation, then
the per-shard partial sums combine across the axis.  Rows are zero-weight
padded up to shard divisibility — padding contributes exactly 0 to the
weighted sum, so the sharded result matches the unsharded one bit-for-bit
per shard and within accumulation tolerance across shards.

**Fused dequantize-and-reduce.**  ``fed_reduce(stack, weights, scales=...)``
consumes a *quantized* int8 stack (``UpdateBuffer(wire="int8")`` leaves):
``out[d] = sum_i weights[i] * scales[i] * stack[i, d]``.  Because symmetric
per-row quantization is linear per row, the per-row scales fold straight
into the weight vector (``weights * scales``) **before** the reduction — the
MXU/BLAS matmul consumes the int8 rows directly (cast per-block in VMEM on
the kernel path, convert-fused-into-dot on the jnp ref path), and no dense
f32 copy of the stack is ever materialized.  The mesh path pads the folded
weights with zeros exactly like the unquantized path, so padding rows still
contribute exactly 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.fed_reduce.fed_reduce import fed_reduce_pallas
from repro.kernels.fed_reduce.ref import fed_reduce_ref

__all__ = ["fed_reduce", "fed_reduce_ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fed_reduce_local(stack: jax.Array, weights: jax.Array,
                      impl: str) -> jax.Array:
    if impl == "ref":
        return fed_reduce_ref(stack, weights)
    if impl in ("pallas", "pallas_interpret"):
        n = stack.shape[0]
        flat = stack.reshape(n, -1)
        out = fed_reduce_pallas(
            flat, weights,
            interpret=(impl == "pallas_interpret" or not _on_tpu()))
        return out.reshape(stack.shape[1:])
    raise ValueError(f"unknown impl {impl!r}")


def fed_reduce(stack: jax.Array, weights: jax.Array, *,
               scales: jax.Array | None = None,
               impl: str = "auto", mesh=None,
               axis: str = "dp") -> jax.Array:
    """Weighted row-sum ``sum_i weights[i] * stack[i]`` -> f32 ``stack[0]``
    shape.  ``stack``: (n, ...); ``weights``: (n,).

    ``scales`` (f32 ``(n,)``, from a quantized ``UpdateBuffer`` scale
    column) selects the fused dequantize-and-reduce variant:
    ``sum_i weights[i] * scales[i] * stack[i]`` over an int8 stack, with the
    scales folded into the weight vector so the reduction itself is
    unchanged (module docstring).

    ``mesh`` (a ``jax.sharding.Mesh`` containing ``axis``) distributes the
    row reduction across fleet shards; ``None`` keeps the single-device
    path.
    """
    if stack.ndim < 1 or stack.shape[0] != weights.shape[0]:
        raise ValueError(
            f"stack rows {stack.shape} must match weights {weights.shape}")
    if scales is not None:
        if scales.shape != weights.shape:
            raise ValueError(
                f"scales {scales.shape} must match weights {weights.shape}")
        # Per-row dequantization is linear, so it folds into the MXU weight
        # vector; a zero weight still zeroes the whole row.
        weights = weights.astype(jnp.float32) * scales.astype(jnp.float32)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if mesh is None:
        return _fed_reduce_local(stack, weights, impl)
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    shards = int(mesh.shape[axis])
    n = int(stack.shape[0])
    pad = (-n) % shards
    if pad:
        # Zero-weight rows contribute exactly 0 to the weighted sum.
        stack = jnp.concatenate(
            [stack, jnp.zeros((pad,) + stack.shape[1:], stack.dtype)])
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,), weights.dtype)])
    row_spec = P(axis, *([None] * (stack.ndim - 1)))

    def _shard_reduce(s, w):
        return jax.lax.psum(_fed_reduce_local(s, w, impl), axis)

    return shard_map(
        _shard_reduce, mesh=mesh, in_specs=(row_spec, P(axis)),
        out_specs=P(*([None] * (stack.ndim - 1))))(stack, weights)
