"""Pallas TPU kernel for the staleness-weighted federated update reduction.

One round of FedAvg-style aggregation over a *device-resident* stacked update
buffer is a weighted segment-sum: ``out[d] = sum_i w[i] * U[i, d]`` with the
per-row weights ``w`` carrying the normalized sample counts x staleness
discounts (zero for rows not selected into this aggregation).  The host path
walks a Python list of per-device pytrees leaf-by-leaf; this kernel replaces
that chain with a single fused reduction per leaf:

* grid ``(d_tiles, n_chunks)`` — row chunks innermost and *sequential*, so the
  ``(1, block_d)`` f32 accumulator lives in the output VMEM block across chunk
  steps (the classic matmul accumulation pattern — zero extra HBM traffic for
  the running sum);
* the inner product is one MXU ``(1, block_n) @ (block_n, block_d)`` matmul
  per grid step, accumulated in f32 whatever the stack dtype (bf16 updates
  still reduce exactly like the f32 host reference within tolerance);
* rows are padded with zero *weights* (not zero rows), so padding never
  contributes to the sum and the caller can slice the column padding off.

The same kernel serves the **fused dequantize-and-reduce** path: an int8
stack (quantized ``UpdateBuffer`` leaves) streams HBM→VMEM at 1 byte/element
and is cast to f32 per ``(block_n, block_d)`` block at the MXU input — the
per-row scales arrive pre-folded into the weight vector (``ops.fed_reduce``
``scales=``), so dequantization costs zero extra passes and no dense f32
copy of the stack ever exists.  block_n=256 / block_d=512 are multiples of
the int8 (32, 128) min tile, so the quantized path keeps the same blocking.

VMEM per step: ``block_n * block_d * 4`` stack bytes + ``block_n * 4`` weight
bytes + ``block_d * 4`` accumulator ≈ 0.5 MB at block_n=256, block_d=512
(4x less stack traffic from HBM when the stack is int8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fed_reduce_kernel(w_ref, x_ref, o_ref):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)  # (1, block_n)
    x = x_ref[...].astype(jnp.float32)  # (block_n, block_d)
    o_ref[...] += jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def fed_reduce_pallas(
    stack: jax.Array,  # (n, d)
    weights: jax.Array,  # (n,)
    *,
    block_n: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Weighted row-sum ``weights @ stack`` -> (d,) float32."""
    n, d = stack.shape
    # Pad rows to a chunk multiple (zero weights -> no contribution) and
    # columns to a lane-aligned tile multiple (sliced off below).
    n_pad = -n % block_n
    d_pad = -d % block_d
    if n_pad:
        stack = jnp.pad(stack, ((0, n_pad), (0, 0)))
    if d_pad:
        stack = jnp.pad(stack, ((0, 0), (0, d_pad)))
    w = jnp.pad(weights.astype(jnp.float32), (0, n_pad)).reshape(1, -1)
    gn = (n + n_pad) // block_n
    gd = (d + d_pad) // block_d

    out = pl.pallas_call(
        _fed_reduce_kernel,
        grid=(gd, gn),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda di, ni: (0, ni)),
            pl.BlockSpec((block_n, block_d), lambda di, ni: (ni, di)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda di, ni: (0, di)),
        out_shape=jax.ShapeDtypeStruct((1, d + d_pad), jnp.float32),
        interpret=interpret,
    )(w, stack)
    return out[0, :d]
