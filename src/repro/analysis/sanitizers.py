"""Opt-in runtime sanitizers for the simulation/serving hot paths.

Enable with ``SIMDC_SANITIZE=1`` in the environment (or ``pytest
--sanitize``, which sets it).  Everything here is a no-op when disabled, so
the hot paths pay only a truthiness check per call.

Four sanitizers, each catching a bug class the repo has actually shipped:

* ``@hot_path`` wraps the decode loop, the zero-copy round pipeline, and
  the fused aggregation dispatch in ``jax.transfer_guard("disallow")``:
  any *implicit* host<->device transfer (a stray numpy operand reaching a
  jit, an ``int()`` on a device scalar) raises instead of silently
  serializing the dispatch stream.  Explicit transfers (``jnp.asarray``,
  ``jax.device_put``, ``jax.device_get``) stay legal.  The decorator also
  marks the function for the R003 lint (:mod:`repro.analysis.lint`).
* :func:`poison_donated` — after ``donate_argnums`` hands an
  ``UpdateBuffer``'s leaves to XLA, touching the buffer again fails deep in
  XLA with an unhelpful "buffer donated" error.  Poisoning swaps the
  object's class so any leaf access raises :class:`UseAfterDonateError`
  naming the donation site.  Probe with ``__simdc_donated__`` (class attr)
  without touching the leaves.
* :class:`SegmentLeakError` — ``FleetWorkerPool.close()`` raises it when a
  shared-memory segment cannot unmap because an exported numpy view
  outlived its ``UpdateBuffer`` (the documented lifetime rule in
  ``runtime/workers``).
* :class:`ClockMonotonicityError` — ``VirtualClock.schedule`` normally
  clamps past timestamps to ``now``; under sanitize it raises, because a
  past timestamp means some component computed an event time from stale
  state.
"""
from __future__ import annotations

import contextlib
import functools
import os

__all__ = [
    "enabled", "force", "override", "hot_path", "exempt",
    "SanitizerError", "UseAfterDonateError", "SegmentLeakError",
    "ClockMonotonicityError", "poison_donated",
]

_ENV = "SIMDC_SANITIZE"
_FORCED: bool | None = None


def enabled() -> bool:
    """True when sanitizers are active (env var or :func:`force`)."""
    if _FORCED is not None:
        return _FORCED
    return os.environ.get(_ENV, "").strip().lower() not in (
        "", "0", "false", "off", "no")


def force(value: bool | None) -> None:
    """Override the env var (``None`` restores env-driven behavior)."""
    global _FORCED
    _FORCED = value


@contextlib.contextmanager
def override(value: bool):
    """Temporarily force sanitizers on/off (tests)."""
    prev = _FORCED
    force(value)
    try:
        yield
    finally:
        force(prev)


class SanitizerError(RuntimeError):
    """Base class for every simcheck runtime sanitizer failure."""


class UseAfterDonateError(SanitizerError):
    """A donated ``UpdateBuffer``'s leaves were accessed after donation."""


class SegmentLeakError(SanitizerError):
    """A worker-pool shared-memory segment outlived pool teardown."""


class ClockMonotonicityError(SanitizerError):
    """An event was scheduled in the virtual past."""


def hot_path(fn):
    """Mark ``fn`` as a dispatch hot path (lint rule R003) and, when
    sanitizers are enabled, run it under ``jax.transfer_guard("disallow")``
    so implicit host<->device transfers raise at the offending op."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if not enabled():
            return fn(*args, **kwargs)
        import jax
        with jax.transfer_guard("disallow"):
            return fn(*args, **kwargs)

    wrapper.__simdc_hot_path__ = True
    return wrapper


def exempt(fn):
    """Wrap a *user* callback (payload transforms, custom hooks) so it runs
    outside the hot-path transfer guard: extension points may legitimately
    convert between host and device, and only platform code is held to the
    implicit-transfer-free invariant.  ``None`` passes through."""
    if fn is None:
        return None

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if not enabled():
            return fn(*args, **kwargs)
        import jax
        with jax.transfer_guard("allow"):
            return fn(*args, **kwargs)

    return wrapper


# --------------------------------------------------------------------------
# use-after-donate poisoning

_POISONED: dict[type, type] = {}


def _poisoned_class(cls: type) -> type:
    def _dead(self, *_args, **_kwargs):
        raise UseAfterDonateError(
            f"{cls.__name__} was donated to a jit (its 2-D leaves are dead "
            "XLA buffers); rebuild the buffer from the jit outputs instead "
            "of reusing the donated object")

    # An empty-__slots__ subclass keeps the instance layout identical, so
    # __class__ assignment is legal; the property shadows the parent's
    # leaves2d slot descriptor, so every leaf access (materialize,
    # state_dict, handle, ...) raises at the attribute read.
    return type(f"_Donated{cls.__name__}", (cls,), {
        "__slots__": (),
        "__simdc_donated__": True,
        "leaves2d": property(_dead, _dead, _dead),
    })


def poison_donated(buf):
    """Swap ``buf``'s class so leaf access raises UseAfterDonateError.

    Idempotent; returns ``buf``.  Only called on the zero-copy recycle path
    when :func:`enabled`, so production runs never pay for it.
    """
    cls = type(buf)
    if getattr(cls, "__simdc_donated__", False):
        return buf
    poisoned = _POISONED.get(cls)
    if poisoned is None:
        poisoned = _POISONED[cls] = _poisoned_class(cls)
    buf.__class__ = poisoned
    return buf
