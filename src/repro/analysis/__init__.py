"""simcheck: project-specific invariant lint + opt-in runtime sanitizers.

Two layers over the same fidelity invariants (ROADMAP "footguns" list):

* ``python -m repro.analysis.lint src tests`` — AST lint, rules R001-R006
  (:mod:`repro.analysis.lint`).  Pure stdlib; importing it never touches jax.
* ``SIMDC_SANITIZE=1`` (or ``pytest --sanitize``) — runtime sanitizers
  (:mod:`repro.analysis.sanitizers`): ``transfer_guard("disallow")`` on the
  ``@hot_path`` functions, use-after-donate poisoning, worker segment-leak
  audit, virtual-clock monotonicity.

``hot_path`` lives in :mod:`repro.analysis.sanitizers` and is re-exported
here lazily so the lint CLI stays jax-free.
"""
from __future__ import annotations

__all__ = ["hot_path", "sanitizers"]


def __getattr__(name):
    if name in ("hot_path", "sanitizers"):
        import importlib

        mod = importlib.import_module("repro.analysis.sanitizers")
        return mod if name == "sanitizers" else mod.hot_path
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
