"""simcheck lint: AST rules for the repo's fidelity invariants.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src tests
    PYTHONPATH=src python -m repro.analysis.lint --rules R001,R005 src

Exit status is nonzero iff any finding survives suppression.  Every finding
prints as ``path:line: RULE message`` so editors and CI logs can jump to it.

Rules (each is a footgun this repo has actually hit — ROADMAP.md):

* **R001** ``jax.jit(..., donate_argnums=...)`` without ``keep_unused=True``.
  Without it, an argument the traced function never reads is dropped from
  the compiled signature and its donation *silently no-ops* — the zero-copy
  recycle path quietly degrades to a fresh allocation per round.
* **R002** wall-clock (``time.time``/``time.monotonic``/``datetime.now``)
  in simulation-domain modules (any path containing a ``core`` directory).
  Simulation components must read time from ``VirtualClock`` so replays and
  checkpoint restores are bit-deterministic.
* **R003** host syncs (``int()``/``float()`` on array expressions,
  ``.item()``, ``np.asarray``/``np.array``, ``jax.device_get``) inside
  functions decorated ``@hot_path``.  A host sync inside the decode loop or
  round pipeline serializes the dispatch stream.  Shape arithmetic
  (``.shape``/``.ndim``/``.size``/``len``) is exempt; nested ``def``s are
  not scanned (emission helpers run on host-side data by design).
* **R004** ``state_dict``/``load_state_dict`` key symmetry per class: every
  string key written by ``state_dict`` must be consumed on restore, and
  every key the reader hard-requires (plain ``d["k"]`` subscript) must be
  written.  Dynamic consumption (``**kwargs`` splats, ``.items()`` loops)
  or dynamic production (dict comprehensions, ``**`` merges) waives the
  corresponding direction.
* **R005** shared-memory lifecycle: ``SharedMemory(create=True)`` with no
  ``close``/``unlink``/``finalize`` on the enclosing function, class, or
  module scope; and *any* ``resource_tracker.unregister`` call (the repo
  doctrine is double-close beats leak — see ``runtime/workers._attach_shm``).
* **R006** heuristic: a ``reshape`` to >=3 dims inside a jit-referenced
  cohort/reduction function.  Aggregation operands must stay ``(rows,
  size)`` 2-D so XLA lowers the weighted sum to one BLAS/MXU matmul; a 3-D+
  operand knocked the seed repo ~40x off that path.

Suppress a finding with a trailing ``# simcheck: ok`` comment (optionally
rule-qualified: ``# simcheck: ok[R003]`` or ``ok[R003,R006]``).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import pathlib
import sys
from typing import Iterable

__all__ = ["Finding", "lint_file", "lint_source", "lint_paths", "main",
           "RULES"]

RULES = {
    "R001": "donated jit without keep_unused=True (donation can no-op)",
    "R002": "wall-clock call in a simulation-domain (VirtualClock) module",
    "R003": "host sync inside a @hot_path function",
    "R004": "state_dict/load_state_dict key asymmetry",
    "R005": "shared-memory segment without a close/unlink/finalize path",
    "R006": "3-D+ reshape on a reduction operand inside a cohort jit",
}

# Directories never walked by default: fixture corpora are deliberately bad.
EXCLUDE_DIRS = {"__pycache__", "lint_fixtures", ".git", ".venv",
                "build", "dist", ".eggs"}

_SUPPRESS_TOKEN = "# simcheck: ok"


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# --------------------------------------------------------------------------
# helpers

def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name of a call target ('jax.jit', 'time.time')."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _kw(call: ast.Call, name: str) -> ast.keyword | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw
    return None


def _is_true(node: ast.expr | None) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


def _decorator_name(dec: ast.expr) -> str:
    if isinstance(dec, ast.Call):
        dec = dec.func
    return _dotted(dec).rsplit(".", 1)[-1]


def _shape_exempt(node: ast.expr) -> bool:
    """True if the expression is shape/size arithmetic, not array data."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in (
                "shape", "ndim", "size", "itemsize", "nbytes"):
            return True
        if isinstance(sub, ast.Call) and _dotted(sub.func) == "len":
            return True
    return False


def _reshape_rank(call: ast.Call) -> int:
    """Target rank of a ``.reshape``/``jnp.reshape`` call, 0 if unknown."""
    args = list(call.args)
    if _dotted(call.func) in ("jnp.reshape", "jax.numpy.reshape",
                              "np.reshape", "numpy.reshape") and args:
        args = args[1:]
    if len(args) == 1 and isinstance(args[0], (ast.Tuple, ast.List)):
        return len(args[0].elts)
    if len(args) >= 2:
        return len(args)
    return 0  # single non-tuple arg (e.g. x.reshape(g.shape)): rank unknown


def _suppressed(lines: list[str], finding: Finding) -> bool:
    if not 1 <= finding.line <= len(lines):
        return False
    text = lines[finding.line - 1]
    idx = text.find(_SUPPRESS_TOKEN)
    if idx < 0:
        return False
    rest = text[idx + len(_SUPPRESS_TOKEN):].strip()
    if rest.startswith("["):
        rules = rest[1:rest.index("]")] if "]" in rest else rest[1:]
        return finding.rule in {r.strip() for r in rules.split(",")}
    return True  # bare "# simcheck: ok" suppresses every rule on the line


# --------------------------------------------------------------------------
# rule implementations (each: (path, tree, lines) -> iterator of findings)

def _r001_donated_jits(path, tree, lines):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if not (name == "jit" or name.endswith(".jit")):
            continue
        donate = _kw(node, "donate_argnums") or _kw(node, "donate_argnames")
        if donate is None:
            continue
        keep = _kw(node, "keep_unused")
        if not (keep is not None and _is_true(keep.value)):
            yield Finding(
                path, node.lineno, "R001",
                "jit with donate_argnums but no keep_unused=True: donation "
                "silently no-ops for args the traced fn never reads")


_WALL_CLOCK = {"time.time", "time.time_ns", "time.monotonic",
               "time.monotonic_ns", "time.perf_counter",
               "time.perf_counter_ns", "datetime.now", "datetime.utcnow",
               "datetime.datetime.now", "datetime.datetime.utcnow",
               "date.today", "datetime.date.today"}


def _simulation_domain(path: str) -> bool:
    return "core" in pathlib.PurePath(path).parts


def _r002_wall_clock(path, tree, lines):
    if not _simulation_domain(path):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in _WALL_CLOCK:
            yield Finding(
                path, node.lineno, "R002",
                f"wall-clock {_dotted(node.func)}() in a simulation-domain "
                "module; inject the VirtualClock instead")


_HOST_ARRAY_FNS = {"np.asarray", "numpy.asarray", "np.array", "numpy.array",
                   "onp.asarray", "onp.array"}
_DEVICE_GET_FNS = {"jax.device_get", "device_get"}


def _hot_path_body(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    """Yield nodes of fn's body without descending into nested defs."""
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _r003_host_syncs(path, tree, lines):
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not any(_decorator_name(d) == "hot_path"
                   for d in fn.decorator_list):
            continue
        for node in _hot_path_body(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            msg = None
            if name in ("int", "float") and node.args and \
                    not _shape_exempt(node.args[0]):
                msg = f"{name}() on an array expression forces a host sync"
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                msg = ".item() forces a device->host sync"
            elif name in _HOST_ARRAY_FNS:
                msg = f"{name}() materializes device data on host"
            elif name in _DEVICE_GET_FNS:
                msg = f"{name}() is a blocking device->host transfer"
            if msg is not None:
                yield Finding(
                    path, node.lineno, "R003",
                    f"in @hot_path {fn.name}(): {msg}")


def _string_keys(fn: ast.AST):
    """(key, line, strict) triples for every dict-key-ish string literal.

    ``strict`` marks hard requirements: plain ``d["k"]`` subscripts.  Keys
    from dict displays, ``.get``/``.pop`` (which carry defaults), and ``"k"
    in d`` tests are collected but tolerated as reader-side extras.
    """
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    yield k.value, k.lineno, False
        elif isinstance(node, ast.Subscript):
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                yield sl.value, node.lineno, isinstance(node.ctx, ast.Load)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("get", "pop", "setdefault") and \
                node.args and isinstance(node.args[0], ast.Constant) and \
                isinstance(node.args[0].value, str):
            yield node.args[0].value, node.lineno, False
        elif isinstance(node, ast.Compare) and \
                isinstance(node.left, ast.Constant) and \
                isinstance(node.left.value, str) and \
                any(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops):
            yield node.left.value, node.lineno, False


def _dynamic_access(fn: ast.AST) -> bool:
    """True if the function consumes/produces dict keys it never names."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if any(kw.arg is None for kw in node.keywords):  # Fn(**m)
                return True
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                    "items", "keys", "values", "update"):
                return True
        if isinstance(node, ast.Dict) and any(k is None for k in node.keys):
            return True  # {**base, ...}
        if isinstance(node, (ast.DictComp,)):
            return True
    return False


def _r004_state_dict_symmetry(path, tree, lines):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        fns = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        writer, reader = fns.get("state_dict"), fns.get("load_state_dict")
        if writer is None or reader is None:
            continue
        written = {}
        for key, line, _ in _string_keys(writer):
            written.setdefault(key, line)
        read, read_strict = {}, {}
        for key, line, strict in _string_keys(reader):
            read.setdefault(key, line)
            if strict:
                read_strict.setdefault(key, line)
        if not _dynamic_access(reader):
            for key, line in sorted(written.items(), key=lambda kv: kv[1]):
                if key not in read:
                    yield Finding(
                        path, line, "R004",
                        f"{cls.name}.state_dict writes {key!r} but "
                        "load_state_dict never consumes it")
        if not _dynamic_access(writer):
            for key, line in sorted(read_strict.items(),
                                    key=lambda kv: kv[1]):
                if key not in written:
                    yield Finding(
                        path, line, "R004",
                        f"{cls.name}.load_state_dict requires {key!r} but "
                        "state_dict never writes it")


def _enclosing_index(tree):
    """Map each node id to its chain of enclosing function/class defs."""
    chains: dict[int, tuple[ast.AST, ...]] = {}

    def visit(node, chain):
        chains[id(node)] = chain
        child_chain = chain
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            child_chain = chain + (node,)
        for child in ast.iter_child_nodes(node):
            visit(child, child_chain)

    visit(tree, ())
    return chains


def _scope_attr_names(scope: ast.AST) -> set[str]:
    names = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, ast.Name):
            names.add(node.id)
    return names


_LIFECYCLE_NAMES = {"close", "unlink", "finalize", "cleanup"}


def _r005_shm_lifecycle(path, tree, lines):
    chains = _enclosing_index(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name.endswith("resource_tracker.unregister") or \
                name == "unregister":
            yield Finding(
                path, node.lineno, "R005",
                "resource_tracker.unregister defeats the double-close "
                "doctrine; attach with track=False semantics instead "
                "(see runtime/workers._attach_shm)")
            continue
        if not (name == "SharedMemory" or name.endswith(".SharedMemory")):
            continue
        if not _is_true(getattr(_kw(node, "create"), "value", None)):
            continue
        # Lifecycle may live on the enclosing function, its class (paired
        # acquire/cleanup methods), or the module (caller-managed helpers).
        scopes = list(chains.get(id(node), ())) + [tree]
        if not any(_scope_attr_names(s) & _LIFECYCLE_NAMES for s in scopes):
            yield Finding(
                path, node.lineno, "R005",
                "SharedMemory(create=True) with no close/unlink/finalize "
                "in scope: the segment outlives its creator")


_R006_NAME_HINTS = ("cohort", "reduce", "aggregate", "fedavg")


def _jit_referenced_fns(tree) -> set[str]:
    """Names of module functions passed to (or decorated by) a jit call."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            if callee == "jit" or callee.endswith(".jit"):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        names.add(arg.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _decorator_name(dec) == "jit":
                    names.add(node.name)
    return names


def _r006_reduction_reshapes(path, tree, lines):
    jitted = _jit_referenced_fns(tree)
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        lowered = fn.name.lower()
        if fn.name not in jitted:
            continue
        if not any(h in lowered for h in _R006_NAME_HINTS):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and (
                    (isinstance(node.func, ast.Attribute) and
                     node.func.attr == "reshape") or
                    _dotted(node.func).endswith("reshape")):
                rank = _reshape_rank(node)
                if rank >= 3:
                    yield Finding(
                        path, node.lineno, "R006",
                        f"{rank}-D reshape inside cohort jit {fn.name}(): "
                        "reduction operands must stay (rows, size) 2-D to "
                        "hit the BLAS/MXU matmul path")


_RULE_FNS = {
    "R001": _r001_donated_jits,
    "R002": _r002_wall_clock,
    "R003": _r003_host_syncs,
    "R004": _r004_state_dict_symmetry,
    "R005": _r005_shm_lifecycle,
    "R006": _r006_reduction_reshapes,
}


# --------------------------------------------------------------------------
# driver

def lint_source(source: str, path: str = "<string>",
                rules: Iterable[str] | None = None) -> list[Finding]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 1, "R000",
                        f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    findings: list[Finding] = []
    for rule in (rules or sorted(_RULE_FNS)):
        findings.extend(_RULE_FNS[rule](path, tree, lines))
    findings = [f for f in findings if not _suppressed(lines, f)]
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings


def lint_file(path: str | pathlib.Path,
              rules: Iterable[str] | None = None) -> list[Finding]:
    p = pathlib.Path(path)
    return lint_source(p.read_text(), str(p), rules)


def _walk(paths: Iterable[str | pathlib.Path]):
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_file():
            if p.suffix == ".py":
                yield p
            continue
        for f in sorted(p.rglob("*.py")):
            if not EXCLUDE_DIRS & set(f.parts):
                yield f


def lint_paths(paths: Iterable[str | pathlib.Path],
               rules: Iterable[str] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for f in _walk(paths):
        findings.extend(lint_file(f, rules))
    return findings


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="simcheck invariant linter (rules R001-R006)")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset, e.g. R001,R005")
    args = parser.parse_args(argv)
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in _RULE_FNS]
        if unknown:
            parser.error(f"unknown rules {unknown}; have {sorted(_RULE_FNS)}")
    findings = lint_paths(args.paths, rules)
    for f in findings:
        print(f)
    counts = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    if findings:
        by_rule = ", ".join(f"{r}={n}" for r, n in sorted(counts.items()))
        print(f"simcheck: {len(findings)} finding(s) ({by_rule})")
        return 1
    print("simcheck: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
