import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (a) the sharding config is coherent (no mismatched
specs, no unsupported collectives), (b) the program fits memory
(``memory_analysis``), and (c) yields the cost/collective numbers the
roofline analysis (EXPERIMENTS.md §Roofline) reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, choose_mesh_plan
from repro.configs.registry import get_config, lm_arch_ids
from repro.distribution.sharding import derive_logical_mesh
from repro.distribution.steps import (
    build_prefill_step,
    build_serve_step,
    build_train_step,
)
from repro.launch.mesh import make_production_mesh

SKIPPED_LONG = {
    # long_500k requires a sub-quadratic path; these are pure full-attention
    # (see DESIGN.md §6).
    "phi3_medium_14b", "llama3_2_3b", "qwen2_7b", "nemotron_4_15b",
    "granite_moe_3b_a800m", "phi3_5_moe_42b_a6_6b", "internvl2_26b",
    "seamless_m4t_medium",
}


def cell_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch in SKIPPED_LONG:
        return False, "pure full-attention arch at 524k context (DESIGN.md §6)"
    return True, ""


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: pathlib.Path, step_override=None) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    if step_override:
        import dataclasses
        cfg = dataclasses.replace(cfg, **step_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = choose_mesh_plan(cfg, model_axis=mesh.devices.shape[-1])
    lmesh = derive_logical_mesh(mesh, plan)

    if shape.kind == "train":
        fn, in_sh, out_sh, in_specs = build_train_step(cfg, lmesh, shape)
        donate = (0,)  # train state updated in place
    elif shape.kind == "prefill":
        fn, in_sh, out_sh, in_specs = build_prefill_step(cfg, lmesh, shape)
        donate = ()
    else:
        fn, in_sh, out_sh, in_specs = build_serve_step(cfg, lmesh, shape)
        donate = (1,)  # KV/SSM caches updated in place

    with lmesh.mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate, keep_unused=True)
        t1 = time.time()
        lowered = jitted.lower(*in_specs)
        t_lower = time.time() - t1
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    from repro.roofline.hlo_analysis import normalize_cost_analysis

    ma = compiled.memory_analysis()
    ca = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "plan": {"tp": plan.tp, "sp": plan.sp, "kv_dup": plan.kv_dup,
                 "fsdp": plan.fsdp and shape.kind == "train"},
        "ok": True,
        "seconds": {"lower": round(t_lower, 1), "compile": round(t_compile, 1),
                    "total": round(time.time() - t0, 1)},
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "cost_analysis": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collective_op_counts": {
            k: hlo.count(f" {k}(") + hlo.count(f" {k}-start(")
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")
        },
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}"
    (out_dir / f"{stem}.json").write_text(json.dumps(rec, indent=1))
    # HLO text is large; store compressed for the roofline analyzer.
    import gzip
    with gzip.open(out_dir / f"{stem}.hlo.txt.gz", "wt") as f:
        f.write(hlo)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args(argv)
    out_dir = pathlib.Path(args.out)

    cells: list[tuple[str, str]] = []
    archs = lm_arch_ids() if (args.all or not args.arch) else (args.arch,)
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        a_norm = a.replace("-", "_").replace(".", "_")
        from repro.configs.registry import ALIASES
        a_norm = ALIASES.get(a, a_norm).replace("-", "_").replace(".", "_")
        for s in shapes:
            cells.append((a_norm, s))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape_name in cells:
        ok, why = cell_supported(arch, shape_name)
        if not ok:
            print(f"SKIP  {arch} x {shape_name}: {why}", flush=True)
            continue
        for mp in meshes:
            tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp, out_dir=out_dir)
                print(
                    f"PASS  {tag}  compile={rec['seconds']['compile']}s "
                    f"flops/dev={rec['cost_analysis']['flops']:.3e} "
                    f"temp/dev={rec['memory']['temp_bytes'] / 1e9:.2f}GB",
                    flush=True,
                )
            except Exception as e:
                failures += 1
                print(f"FAIL  {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
