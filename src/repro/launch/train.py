"""Federated LM training driver — SimDC end-to-end on the LM substrate.

The cloud model is one of the assigned architectures; simulated device cohorts
produce update messages that flow through **DeviceFlow** under a configurable
traffic strategy; the **aggregation trigger** (sample-threshold or scheduled)
gates the global update; the cloud-side trainer runs distributed
``train_step``s with checkpoint/restart.

Two modes:
  --mode cloud      pure datacenter pretraining loop (no federation) — the
                    substrate driver used by examples/lm_pretrain.py.
  --mode federated  full SimDC loop (default).

At container scale use ``--smoke`` (reduced configs, CPU-sized cohorts); on a
real cluster the same flags ride on the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ShapeConfig, choose_mesh_plan
from repro.configs.registry import get_config
from repro.checkpoint.checkpointer import Checkpointer
from repro.core.allocation import solve_allocation
from repro.core.calibration import RuntimeCalibrator
from repro.core.deviceflow import ArrivalBatch, DeviceFlow, Message
from repro.core.devicemodel import GRADES
from repro.core.federation import (
    AggregationService,
    ClientCountTrigger,
    SampleThresholdTrigger,
    ScheduledTrigger,
)
from repro.core.scheduler import ResourceManager, ResourcePool, TaskEngine
from repro.core.simulation import (
    DeviceTier,
    HybridSimulation,
    LogicalTier,
    RoundPlan,
)
from repro.core.strategies import AccumulatedStrategy, TimeIntervalStrategy
from repro.core.task import GradeSpec, OperatorFlow, Task
from repro.core.traffic_curves import right_tailed_normal
from repro.core.updates import UpdateBuffer, UpdateHandle
from repro.data.tokens import TokenPipeline
from repro.distribution.sharding import derive_logical_mesh, make_fleet_mesh
from repro.distribution.steps import build_train_step, init_train_state
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_model
from repro.optim.compression import (
    topk_compress,
    topk_compress_rows,
    topk_init,
)
from repro.runtime.fault_tolerance import TrainingSupervisor


def make_small_shape(cfg, *, seq_len=128, global_batch=8, microbatches=2):
    return ShapeConfig("local", seq_len, global_batch, "train",
                       microbatches=microbatches)


def _make_local_train(api, cfg, client_lr):
    """One SGD epoch on the client model — shared between the coordinator's
    tiers and spawned workers so pooled chunks stay bit-identical."""

    def local_train(params, batch, _rng):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, cfg)[0])(params)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - client_lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        return new, loss

    return local_train


def _federated_worker_tiers(*, arch, grades, seed, client_lr, cohort):
    """Module-level ``WorkerSpec`` factory (spawn pickles it by reference):
    rebuilds the coordinator's tiers from plain kwargs inside each worker."""
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    local_train = _make_local_train(api, cfg, client_lr)
    return (LogicalTier(local_train, cohort_size=cohort),
            {g: DeviceTier(local_train, GRADES[g], seed=seed)
             for g in grades})


def cloud_training(args) -> dict:
    """Datacenter pretraining loop with checkpoint/restart."""
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        shape = make_small_shape(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    plan = choose_mesh_plan(cfg, model_axis=mesh.devices.shape[-1])
    lmesh = derive_logical_mesh(mesh, plan)
    step_fn, in_sh, out_sh, _ = build_train_step(cfg, lmesh, shape)

    with lmesh.mesh:
        jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,), keep_unused=True)
        state = init_train_state(cfg, seed=args.seed)
        pipe = TokenPipeline(cfg.vocab_size, shape.seq_len,
                             shape.global_batch, seed=args.seed)
        ckpt = Checkpointer(args.checkpoint_dir)
        losses = []

        def one_step(state, step):
            b = next(pipe)
            n, mb = shape.microbatches, shape.global_batch // shape.microbatches
            batch = {
                "tokens": b.tokens.reshape(n, mb, -1),
                "targets": b.targets.reshape(n, mb, -1),
                "mask": b.mask.reshape(n, mb, -1),
            }
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            return state

        sup = TrainingSupervisor(ckpt, checkpoint_every=args.checkpoint_every)
        state, _ = sup.run(state, one_step, args.steps,
                           extra_fn=lambda: {"pipeline": pipe.state_dict()})
    return {"final_loss": losses[-1] if losses else None, "losses": losses}


def federated_training(args) -> dict:
    """SimDC federated loop: grade-partitioned rounds -> DeviceFlow -> FedAvg.

    Clients are split across the requested device grades; each round the
    hybrid allocator re-solves the per-grade logical/device split on
    *fleet-calibrated* runtimes (Table-I priors seed round 0, every round's
    fleet samples re-measure them), and ``HybridSimulation.run_plan_round``
    executes the plan — per-grade cohorts, fleet-sampled arrival times.
    """
    cfg = get_config(args.arch, smoke=True)  # clients train the reduced model
    api = get_model(cfg)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    global_params = api.init(key, cfg)

    trigger = (
        SampleThresholdTrigger(args.sample_threshold)
        if args.trigger == "samples"
        else ScheduledTrigger(args.trigger_period)
    )
    # --fleet-shards N shards cohort execution and the fused fed_reduce over
    # an explicit ("dp", "mp") fleet mesh (redco-style data parallelism
    # across fleet shards).
    fleet_mesh = (make_fleet_mesh(args.fleet_shards)
                  if args.fleet_shards else None)
    svc = AggregationService(global_params, trigger=trigger, mesh=fleet_mesh)
    flow = DeviceFlow(svc, seed=args.seed)
    task_id = 0
    if args.traffic == "realtime":
        flow.register_task(task_id, AccumulatedStrategy(
            thresholds=(1,), failure_prob=args.dropout))
    else:
        flow.register_task(task_id, TimeIntervalStrategy(
            curve=right_tailed_normal(args.sigma), interval=args.round_seconds,
            failure_prob=args.dropout))

    local_train = _make_local_train(api, cfg, args.client_lr)

    # Grade partition: clients split evenly across the requested grades, one
    # DeviceTier (with its own behavioral fleet) per grade.
    grade_names = [g.strip() for g in args.grades.split(",") if g.strip()]
    cohort = args.clients_per_round
    per_grade = [cohort // len(grade_names)] * len(grade_names)
    per_grade[0] += cohort - sum(per_grade)
    specs = [
        GradeSpec(g, n, logical_bundles=max(1, n // 2), bundles_per_device=1,
                  physical_devices=max(1, n // 4))
        for g, n in zip(grade_names, per_grade)
    ]
    # Every round flows through the columnar plane: run_plan_round submits
    # one ArrivalBatch per cohort chunk straight into DeviceFlow.  Top-k
    # compression rides it as a ``payload_transform`` (per-emission host
    # hook) instead of bypassing the plane with a manual scalar submit loop.
    comp_residuals: dict = {}

    def compress_emission(e):
        if isinstance(e, ArrivalBatch) and e.buffer is not None:
            # Bench splits leave multiple batches sharing one buffer with
            # disjoint row ranges — slice this batch's rows out first.
            stacked = e.buffer.materialize()
            stacked = jax.tree.map(
                lambda l: l[np.asarray(e.rows)], stacked)
            # Error-feedback memory keyed by the chunk identity (first
            # device id + width is stable across rounds for a fixed plan).
            key = (e.task_id, int(e.device_ids[0]), e.n)
            kept, res, nnz = topk_compress_rows(
                stacked, comp_residuals.get(key),
                fraction=args.compress_fraction)
            comp_residuals[key] = res
            # Wire size per row = kept (value, int32 index) pairs; floor at
            # one entry so nbytes=0 never reads as "unset".
            return ArrivalBatch(
                e.task_id, e.round_idx,
                rows=np.arange(e.n, dtype=np.int64),
                created_t=e.created_t,
                nbytes=np.maximum(nnz, 1) * 8,
                num_samples=e.num_samples, device_ids=e.device_ids,
                buffer=UpdateBuffer.from_stacked(kept))
        if isinstance(e, Message):
            payload = (e.payload.materialize()
                       if isinstance(e.payload, UpdateHandle)
                       else e.payload)
            kept, _, stats = topk_compress(
                payload, topk_init(payload),
                fraction=args.compress_fraction)
            return dataclasses.replace(
                e, payload=kept,
                size_bytes=max(stats["nonzero"], 1) * 8)
        return e

    # --workers N shards cohort execution across N spawned processes
    # (runtime.workers): each worker runs its own jitted cohort loop and
    # ships chunk results back through shared-memory segments.  Process
    # sharding and mesh sharding are alternative scale-out axes — pick one.
    worker_kw = {}
    if args.workers:
        if args.fleet_shards:
            raise SystemExit(
                "--workers is incompatible with --fleet-shards: process "
                "sharding and fleet-mesh sharding are alternative scale-out "
                "axes")
        from repro.runtime.workers import WorkerSpec
        worker_kw = dict(
            workers=args.workers,
            worker_spec=WorkerSpec(
                _federated_worker_tiers,
                kwargs=dict(arch=args.arch, grades=tuple(grade_names),
                            seed=args.seed, client_lr=args.client_lr,
                            cohort=cohort)))
    sim = HybridSimulation(
        LogicalTier(local_train, cohort_size=cohort,
                    mesh=fleet_mesh, data_axis="dp"),
        tiers={g: DeviceTier(local_train, GRADES[g], seed=args.seed,
                             mesh=fleet_mesh, data_axis="dp")
               for g in grade_names},
        deviceflow=flow,
        wire=args.wire_format,
        error_feedback=(args.error_feedback == "on"),
        payload_transform=compress_emission if args.compress else None,
        **worker_kw)
    cal = RuntimeCalibrator()  # Table-I prior until fleets report in

    losses = []
    seq = 64
    for rnd in range(args.rounds):
        # Re-solve the split on the latest measured runtimes (paper §IV.B/C).
        plan = RoundPlan.from_allocation(
            solve_allocation(specs, cal.runtimes_for(specs)), specs)
        grade_batches, grade_counts = {}, {}
        for spec in specs:
            toks = rng.integers(
                1, cfg.vocab_size,
                size=(spec.num_devices, seq + 1)).astype(np.int32)
            grade_batches[spec.grade] = {
                "tokens": jnp.asarray(toks[:, None, :-1]),
                "targets": jnp.asarray(toks[:, None, 1:]),
                "mask": jnp.ones((spec.num_devices, 1, seq), jnp.float32),
            }
            grade_counts[spec.grade] = np.full(spec.num_devices, seq)
        outcome = sim.run_plan_round(
            task_id, rnd, svc.global_params, plan, grade_batches,
            grade_counts, jax.random.PRNGKey(rnd), calibrator=cal)
        # Per-device losses, flattened across chunks — chunks have unequal
        # sizes, so averaging chunk means would bias toward small chunks.
        losses.append(float(np.concatenate(
            [np.asarray(jax.tree.leaves(m)[0]).reshape(-1)
             for m in outcome.client_metrics]).mean()))

        # Columnar plane: run_plan_round already submitted the round's
        # ArrivalBatches (+ bench messages) with fleet-sampled times;
        # --compress and --wire-format int8 both ride it.
        round_end = float(np.max(outcome.arrival_times))
        # Rule-based dispatch points extend up to round_seconds past the
        # round end (= the slowest arrival); the run window must cover them
        # or the round's deliveries slip into the next window.
        flow.run(round_end + args.round_seconds)
        svc.tick(flow.clock.now)
        lat = svc.history[-1].mean_latency_s if svc.history else 0.0
        print(f"round {rnd:3d} client-loss {losses[-1]:.4f} "
              f"aggregations {len(svc.history)} "
              f"mean-latency {lat:.1f}s "
              f"shelf {len(flow.shelf(task_id))}", flush=True)
    # Drain capacity-spill dispatches scheduled past the last window.
    flow.run()
    svc.tick(flow.clock.now)
    shelf = flow.shelf(task_id)
    out = {"losses": losses, "aggregations": len(svc.history),
           "wire_bytes_received": shelf.total_bytes_received,
           "wire_bytes_dispatched": shelf.total_bytes_dispatched}
    if sim.pool is not None:
        st = sim.pool.stats
        print(f"workers: {args.workers} chunks {st['chunks']} "
              f"segments {st['segments_created']} "
              f"(reused {st['segment_reuses']}) "
              f"shipped {st['bytes_shipped'] / 1e6:.1f}MB "
              f"redispatched {st['redispatched_chunks']}", flush=True)
        out["worker_chunks"] = st["chunks"]
        out["worker_segment_reuses"] = st["segment_reuses"]
    sim.close()  # workers are daemonic — explicit close just recycles shm now
    return out


class _TaskRouter:
    """DeviceFlow deliver callback fanning out to per-task services."""

    def __init__(self):
        self.services: dict[int, AggregationService] = {}

    def __call__(self, d):
        # Delivery.task_id spans both planes (scalar message or columnar
        # batch) without materializing per-row adapter objects.
        self.services[d.task_id](d)


def multi_task_federated(args) -> dict:
    """``--tasks N``: event-driven multi-task rounds on one shared pool.

    N federated CTR-style LM tasks contend for a resource pool sized to fit
    roughly half of them at full demand; the ``TaskEngine`` interleaves
    their rounds on the shared ``VirtualClock`` (elastic grants let tasks
    run on a partial share and top back up as others finish), each round
    executes through ``HybridSimulation.run_plan_round`` with chunk
    streaming, and every task aggregates through its own *streaming*
    ``AggregationService``.  Reports per-task completion times plus the
    interleaved makespan vs the serial (back-to-back) estimate.
    """
    cfg = get_config(args.arch, smoke=True)
    api = get_model(cfg)
    rng = np.random.default_rng(args.seed)
    seq = 64
    n_clients = args.clients_per_round

    def local_train(params, batch, _rng):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, cfg)[0])(params)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - args.client_lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        return new, loss

    spec = GradeSpec("High", n_clients, logical_bundles=max(1, n_clients // 2),
                     bundles_per_device=1,
                     physical_devices=max(1, n_clients // 4))
    # --priorities "5,1,1" pins per-task scheduling priorities (cycled to
    # --tasks length); default keeps the earlier-submitted-is-more-urgent
    # ordering.  With --preemptive, a later high-priority arrival reclaims
    # lower-priority grants at their round boundaries instead of waiting.
    if args.priorities:
        prios = [int(p) for p in args.priorities.split(",") if p.strip()]
        priorities = [prios[i % len(prios)] for i in range(args.tasks)]
    else:
        priorities = [args.tasks - i for i in range(args.tasks)]
    tasks = [Task(OperatorFlow(("train",)), (spec,), rounds=args.rounds,
                  priority=priorities[i]) for i in range(args.tasks)]
    # Pool fits about half the fleet at full demand (plus a spare bundle for
    # elastic partial grants): later tasks run on what is free and rebalance
    # up as earlier ones finish.
    fit = max(1, -(-args.tasks // 2))
    rm = ResourceManager(ResourcePool(
        {"High": spec.logical_bundles * fit + 1},
        {"High": spec.physical_devices * fit}))

    router = _TaskRouter()
    flow = DeviceFlow(router, seed=args.seed)
    for task in tasks:
        router.services[task.task_id] = AggregationService(
            api.init(jax.random.PRNGKey(args.seed + task.task_id), cfg),
            trigger=ClientCountTrigger(n_clients), streaming=True)
        flow.register_task(task.task_id, AccumulatedStrategy(
            thresholds=(1,), failure_prob=args.dropout))

    sim = HybridSimulation(
        LogicalTier(local_train, cohort_size=max(2, n_clients // 2)),
        tiers={"High": DeviceTier(local_train, GRADES["High"],
                                  seed=args.seed)},
        deviceflow=flow, stream_chunks=True)
    cal = RuntimeCalibrator()

    measured_total = [0.0]  # Σ measured round durations = serial makespan

    def round_runner(task, round_idx, allocation, t):
        svc = router.services[task.task_id]
        plan = RoundPlan.from_allocation(allocation, task.grades)
        toks = rng.integers(1, cfg.vocab_size,
                            size=(n_clients, seq + 1)).astype(np.int32)
        batches = {"tokens": jnp.asarray(toks[:, None, :-1]),
                   "targets": jnp.asarray(toks[:, None, 1:]),
                   "mask": jnp.ones((n_clients, 1, seq), jnp.float32)}
        outcome = sim.run_plan_round(
            task.task_id, round_idx, svc.global_params, plan,
            {"High": batches}, {"High": np.full(n_clients, seq)},
            jax.random.PRNGKey(1000 * task.task_id + round_idx),
            calibrator=cal)
        measured_total[0] += outcome.makespan_s
        return outcome.makespan_s  # measured duration times the next event

    engine = TaskEngine(rm, cal, round_runner=round_runner,
                        clock=flow.clock, elastic=True,
                        preemptive=args.preemptive)
    t0 = time.perf_counter()
    for i, task in enumerate(tasks):
        # Staggered arrivals (--arrival-gap) make priority meaningful: a
        # high-priority task arriving late must preempt, not just sort first.
        engine.submit(task, at=i * args.arrival_gap or None)
    result = engine.drain()
    wall_s = time.perf_counter() - t0
    serial_est = measured_total[0]  # back-to-back = sum of round durations
    for ex in result:
        print(f"task {ex.task.task_id}: prio={ex.task.priority} "
              f"rounds={ex.rounds_done} "
              f"start={ex.started_t:.0f}s finish={ex.finished_t:.0f}s "
              f"queue-delay={ex.queueing_delay_s:.0f}s "
              f"grant-util={ex.grant_utilization:.2f} "
              f"reallocations={ex.reallocations} "
              f"preemptions={ex.preemptions} "
              f"aggregations={len(router.services[ex.task.task_id].history)}",
              flush=True)
    print(f"interleaved makespan {engine.makespan:.0f}s vs serial estimate "
          f"{serial_est:.0f}s ({serial_est / max(engine.makespan, 1e-9):.2f}x)"
          f"; stranded={len(result.stranded)}; wall {wall_s:.1f}s", flush=True)
    top_prio = max(priorities)
    hi_delays = [ex.queueing_delay_s for ex in result
                 if ex.task.priority == top_prio]
    return {"makespan_s": engine.makespan, "serial_estimate_s": serial_est,
            "completed": len(result), "stranded": len(result.stranded),
            "top_priority_queueing_delay_s": max(hi_delays, default=0.0)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--mode", choices=("cloud", "federated"), default="federated")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--tasks", type=int, default=1,
                    help="number of contending federated tasks; >1 runs the "
                         "event-driven multi-task engine on one shared pool")
    ap.add_argument("--priorities", default="",
                    help="comma-separated per-task scheduling priorities "
                         "(cycled to --tasks), e.g. '5,1,1'")
    ap.add_argument("--preemptive", action="store_true",
                    help="let higher-priority tasks refreeze lower-priority "
                         "grants down at round boundaries")
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="virtual seconds between successive task arrivals "
                         "(task i submits at i*gap)")
    ap.add_argument("--clients-per-round", type=int, default=8)
    ap.add_argument("--grades", default="High",
                    help="comma-separated device grades, e.g. High,Low")
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--trigger", choices=("samples", "scheduled"),
                    default="samples")
    ap.add_argument("--sample-threshold", type=int, default=256)
    ap.add_argument("--trigger-period", type=float, default=30.0)
    ap.add_argument("--traffic", choices=("realtime", "curve"),
                    default="realtime")
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--round-seconds", type=float, default=60.0)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--workers", type=int, default=0,
                    help="shard cohort execution across N worker processes "
                         "(shared-memory columnar transport; 0 = in-process); "
                         "federated single-task mode only")
    ap.add_argument("--fleet-shards", type=int, default=0,
                    help="shard cohorts + fed_reduce over a ('dp','mp') "
                         "fleet mesh with this many data shards (0 = off)")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--compress-fraction", type=float, default=0.01)
    ap.add_argument("--wire-format", choices=("f32", "int8"), default="f32",
                    help="update wire format: int8 fuses symmetric per-row "
                         "quantization into the cohort jit (~4x fewer bytes "
                         "per round) with dequantize-and-reduce aggregation")
    ap.add_argument("--error-feedback", choices=("on", "off"), default="on",
                    help="carry int8 quantization residuals device-resident "
                         "across rounds (EF-SGD); only affects "
                         "--wire-format int8")
    ap.add_argument("--checkpoint-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.mode == "cloud":
        out = cloud_training(args)
    elif args.tasks > 1:
        out = multi_task_federated(args)
    else:
        out = federated_training(args)
    print("DONE", {k: v for k, v in out.items() if k != "losses"})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
