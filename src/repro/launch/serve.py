"""Device-cloud serving driver: DeviceFlow replays request traffic against an
LM inference service — the paper's "fluctuating access load" concern (§I
challenge 2, system level).

Two serving modes over the same virtual timeline:

* ``BatchedServer`` — the fixed-batch baseline: drains the arrival queue into
  fixed-size decode batches (a batch fires the moment it fills; ``drain``
  flushes the residual partial batch).  The greedy decode loop is ONE jitted
  ``lax.scan`` dispatch per batch (``fused=True``); the per-token dispatch
  loop is kept as a correctness reference.
* ``ContinuousServer`` + ``ContinuousBatchingEngine`` (``core.serving``) —
  slot-based continuous batching over a KV-cache arena: requests join at
  iteration boundaries and retire individually, so nobody waits for
  batch-mates.  Token-identical to the fixed-batch reference.

Both modes charge virtual service time from one ``ServeCostModel`` and
produce ``ServingReport`` p50/p99 latency, time-to-first-token, and goodput
against an SLO — the information a cloud autoscaler would consume.  With
``--co-train`` the diurnal peak also submits a high-priority serving burst
to a ``TaskEngine(preemptive=True)`` sharing the flow's clock, preempting
background training the way SimDC's traffic controller co-schedules
device-cloud load (preemption gated by the admission cost model).

Handle-style payload accounting (round-engine parity): request tokens are
stacked into one device-resident ``UpdateBuffer`` and every message carries
an ``UpdateHandle`` row whose ``nbytes`` is the prompt's real wire size — so
DeviceFlow byte accounting (``Shelf.total_bytes_*``) covers serving traffic
exactly like training updates.  Plain host-dict payloads (``{"tokens":
ndarray}``) remain supported.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.allocation import GradeRuntime
from repro.core.deviceflow import Delivery, DeviceFlow, Message, VirtualClock
from repro.core.scheduler import ResourceManager, ResourcePool, TaskEngine
from repro.core.serving import (
    ContinuousBatchingEngine,
    ContinuousServer,
    RequestRecord,
    ServeCostModel,
    ServingReport,
)
from repro.core.strategies import TimeIntervalStrategy
from repro.core.task import GradeSpec, OperatorFlow, Task
from repro.core.traffic_curves import diurnal, right_tailed_normal
from repro.core.updates import UpdateBuffer, UpdateHandle
from repro.models.registry import get_model


def stack_requests(token_rows: np.ndarray) -> UpdateBuffer:
    """Stack request prompts ``(n, prompt_len)`` into one device-resident
    token buffer; ``buf.handle(i)`` is request ``i``'s message payload."""
    return UpdateBuffer.from_stacked(
        {"tokens": jnp.asarray(np.asarray(token_rows, np.int32))})


@dataclasses.dataclass
class ServeMetrics:
    t: float
    queue_depth: int
    batch_size: int
    tokens_decoded: int


class BatchedServer:
    """Greedy-decodes fixed-size batches from an arrival queue (baseline).

    The queue is a ``deque`` (O(1) pops — the old ``list.pop(0)`` made batch
    assembly O(n²) under deep backlogs) and ``drain`` flushes the residual
    partial batch, so off-peak traffic can no longer strand ``len(queue) <
    batch_size`` requests forever.  Per-request latency is accounted on the
    virtual timeline via ``cost_model`` (service starts at ``max(arrival of
    batch-completing request, busy_until)``), making the baseline directly
    comparable to the continuous engine.
    """

    def __init__(self, cfg, *, batch_size: int, prompt_len: int,
                 decode_tokens: int, max_len: int, seed: int = 0,
                 cost_model: ServeCostModel | None = None, fused: bool = True):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = self.api.init(jax.random.PRNGKey(seed), cfg)
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.max_len = max_len
        self.fused = fused
        self.cost = cost_model or ServeCostModel()
        self.queue: collections.deque[tuple[Message, float]] = collections.deque()
        self.metrics: list[ServeMetrics] = []
        self.records: list[RequestRecord] = []
        self.busy_until = 0.0
        self._prefill = jax.jit(
            lambda p, t: self.api.prefill(p, t, cfg, max_len))
        self._decode = jax.jit(
            lambda p, tok, c: self.api.decode_step(p, tok, cfg, c))

        def fused_decode(p, tok, caches):
            def body(carry, _):
                tok, caches = carry
                logits, caches = self.api.decode_step(p, tok, cfg, caches)
                nxt = jnp.argmax(
                    logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
                return (nxt, caches), nxt
            (_, _), toks = jax.lax.scan(
                body, (tok, caches), None, length=decode_tokens)
            return toks  # (decode_tokens, batch)

        self._decode_scan = jax.jit(fused_decode)

    # DeviceFlow delivery callback: a request message arrives.
    def __call__(self, d: Delivery) -> None:
        self.queue.append((d.message, d.t))
        while len(self.queue) >= self.batch_size:
            self._serve_batch(d.t)

    def _gather_prompts(self, batch: list[Message]) -> jnp.ndarray:
        """(batch, prompt_len) int32 prompt tokens from message payloads.

        Same-buffer handle payloads take the device gather fast path (no
        host round-trip); anything else stacks on host as before.
        """
        if (all(isinstance(m.payload, UpdateHandle) for m in batch)
                and len({id(m.payload.buffer) for m in batch}) == 1):
            leaf = batch[0].payload.buffer.leaves2d[0]  # (rows, prompt_len)
            rows = jnp.asarray([m.payload.row for m in batch])
            return jnp.take(leaf, rows, axis=0)[:, : self.prompt_len]
        tokens = [(m.payload.materialize()["tokens"]
                   if isinstance(m.payload, UpdateHandle) else
                   m.payload["tokens"]) for m in batch]
        return jnp.stack(
            [jnp.asarray(tk[: self.prompt_len]) for tk in tokens])

    def _decode_tokens_loop(self, tok, caches) -> jnp.ndarray:
        """Reference path: one jit dispatch + host-synced argmax per token
        (kept for correctness tests against the fused ``lax.scan``)."""
        out = []
        for _ in range(self.decode_tokens):
            logits, caches = self._decode(self.params, tok, caches)
            tok = jnp.argmax(
                logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.stack(out)  # (decode_tokens, batch)

    def _serve_batch(self, t: float, size: int | None = None) -> None:
        size = self.batch_size if size is None else size
        batch = [self.queue.popleft() for _ in range(size)]
        prompts = self._gather_prompts([m for m, _ in batch])
        logits, caches = self._prefill(self.params, prompts)
        first = jnp.argmax(
            logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
        if self.fused:
            toks = self._decode_scan(self.params, first, caches)
        else:
            toks = self._decode_tokens_loop(first, caches)
        first_host = np.asarray(first)
        toks_host = np.asarray(toks)  # (decode_tokens, size)
        # Virtual-time accounting: the whole batch is serialized behind any
        # in-flight batch and finishes together — the structural latency
        # penalty continuous batching removes.
        start = max(t, self.busy_until)
        first_token_t = start + self.cost.prefill_s(size)
        finish = first_token_t + self.decode_tokens * self.cost.decode_s(size)
        self.busy_until = finish
        for i, (m, arrival_t) in enumerate(batch):
            rec = RequestRecord(request_id=m.device_id, arrival_t=arrival_t)
            rec.start_t = start
            rec.first_token_t = first_token_t
            rec.finish_t = finish
            rec.decoded = self.decode_tokens
            rec.tokens = [int(first_host[i])] + [int(x) for x in toks_host[:, i]]
            self.records.append(rec)
        self.metrics.append(ServeMetrics(
            t=t, queue_depth=len(self.queue),
            batch_size=size, tokens_decoded=self.decode_tokens * size,
        ))

    def drain(self, t: float) -> None:
        """Serve everything still queued: full batches first, then the
        residual partial batch (previously stranded forever)."""
        while len(self.queue) >= self.batch_size:
            self._serve_batch(t)
        if self.queue:
            self._serve_batch(t, size=len(self.queue))

    def report(self, *, horizon_s: float | None = None) -> ServingReport:
        if horizon_s is None:
            horizon_s = max((r.finish_t for r in self.records
                             if r.finish_t is not None), default=0.0)
        return ServingReport(records=list(self.records), horizon_s=horizon_s)


# --------------------------------------------------------------------------- #
# Traffic + reporting helpers
# --------------------------------------------------------------------------- #
def run_trace(server, *, requests: int, prompt_len: int, vocab_size: int,
              curve, interval: float, seed: int = 0, clock=None):
    """Replay ``requests`` prompts through DeviceFlow on ``curve`` into
    ``server`` (either serving mode); returns the flow (clock drained)."""
    flow = DeviceFlow(server, clock=clock, seed=seed)
    flow.register_task(0, TimeIntervalStrategy(curve=curve, interval=interval))
    rng = np.random.default_rng(seed)
    buf = stack_requests(rng.integers(
        1, vocab_size, size=(requests, prompt_len)))
    for i in range(requests):
        flow.submit(Message(
            task_id=0, device_id=i, round_idx=0, payload=buf.handle(i)))
    flow.round_complete(0)
    flow.run()
    if isinstance(server, BatchedServer):
        server.drain(flow.clock.now)
    return flow


def co_serving_schedule(*, peak_t: float, train_rounds: int = 8,
                        train_round_s: float = 120.0,
                        serve_rounds: int = 3, serve_round_s: float = 30.0,
                        serve_priority: int = 5,
                        cost_model_gate: bool = True):
    """Serve-over-train preemption at the diurnal peak (SimDC co-serving).

    Background training (priority 0) holds the whole pool; a high-priority
    serving-burst task arrives at ``peak_t`` and — when the admission cost
    model judges the priority-weighted benefit to exceed the victim's
    re-timed lost work — preempts training at its next round boundary.
    Returns the drained ``TaskEngine`` for inspection.
    """
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 2}))
    flow = OperatorFlow(("serve",))

    def runtimes(task):
        per_round = serve_round_s if task.priority >= serve_priority \
            else train_round_s
        return [GradeRuntime(alpha=per_round, beta=per_round, lam=0.0)
                for _ in task.grades]

    eng = TaskEngine(rm, runtimes, preemptive=True,
                     preemption_cost_model=cost_model_gate)
    train = Task(flow, (GradeSpec("High", 10, logical_bundles=8,
                                  physical_devices=2),),
                 rounds=train_rounds, priority=0)
    burst = Task(flow, (GradeSpec("High", 10, logical_bundles=8,
                                  physical_devices=2),),
                 rounds=serve_rounds, priority=serve_priority)
    eng.submit(train)
    eng.submit(burst, at=peak_t)
    eng.drain()
    return eng


def print_report(name: str, rep: ServingReport, slo_s: float) -> None:
    s = rep.summary(slo_s)
    print(f"  {name:12s} p50={s['p50_latency_s'] * 1e3:8.1f}ms "
          f"p99={s['p99_latency_s'] * 1e3:8.1f}ms "
          f"ttft_p99={s['p99_ttft_s'] * 1e3:8.1f}ms "
          f"goodput={s['goodput_rps']:6.2f} req/s "
          f"(SLO {slo_s * 1e3:.0f}ms attained {s['slo_attainment'] * 100:.1f}%)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--mode", choices=("fixed", "continuous", "both"),
                    default="both")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=4,
                    help="fixed-batch size AND continuous slot count")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--curve", choices=("diurnal", "right_normal"),
                    default="diurnal")
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="sigma for --curve right_normal")
    ap.add_argument("--interval", type=float, default=60.0)
    ap.add_argument("--slo", type=float, default=30.0,
                    help="request latency SLO in virtual seconds")
    ap.add_argument("--represented-users", type=float, default=2e6,
                    help="real users each simulated request stands for "
                         "(reporting only)")
    ap.add_argument("--co-train", action="store_true",
                    help="run the serve-over-train preemption schedule at "
                         "the curve peak")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    max_len = args.prompt_len + args.decode_tokens + 1
    curve = (diurnal() if args.curve == "diurnal"
             else right_tailed_normal(args.sigma))
    cost = ServeCostModel()

    reports: dict[str, ServingReport] = {}
    horizon = 0.0
    if args.mode in ("fixed", "both"):
        server = BatchedServer(
            cfg, batch_size=args.batch_size, prompt_len=args.prompt_len,
            decode_tokens=args.decode_tokens, max_len=max_len,
            seed=args.seed, cost_model=cost)
        flow = run_trace(server, requests=args.requests,
                         prompt_len=args.prompt_len,
                         vocab_size=cfg.vocab_size, curve=curve,
                         interval=args.interval, seed=args.seed)
        reports["fixed"] = server.report()
        horizon = max(horizon, reports["fixed"].horizon_s)
        shelf = flow.shelf(0)
        print(f"fixed-batch: {len(server.metrics)} batches, "
              f"{sum(m.tokens_decoded for m in server.metrics)} tokens; "
              f"request traffic {shelf.total_bytes_dispatched / 1024:.1f} KiB")
    if args.mode in ("continuous", "both"):
        engine = ContinuousBatchingEngine(
            cfg, slots=args.batch_size, prompt_len=args.prompt_len,
            decode_tokens=args.decode_tokens, max_len=max_len,
            seed=args.seed, cost_model=cost)
        clock = VirtualClock()
        server = ContinuousServer(engine, clock)
        run_trace(server, requests=args.requests,
                  prompt_len=args.prompt_len, vocab_size=cfg.vocab_size,
                  curve=curve, interval=args.interval, seed=args.seed,
                  clock=clock)
        reports["continuous"] = engine.report()
        horizon = max(horizon, reports["continuous"].horizon_s)
        occ = max((it.n_active for it in engine.iterations), default=0)
        print(f"continuous: {len(engine.iterations)} iterations, "
              f"peak slot occupancy {occ}/{engine.slots}")

    scale = args.represented_users / max(args.requests, 1)
    print(f"\nserving report ({args.requests} requests standing for "
          f"{args.represented_users:.0f} users, x{scale:.0f} traffic scale):")
    for name, rep in reports.items():
        rep.horizon_s = horizon or rep.horizon_s
        print_report(name, rep, args.slo)
    if len(reports) == 2:
        f, c = reports["fixed"], reports["continuous"]
        if c.p99_latency_s > 0:
            print(f"  p99 latency cut: {f.p99_latency_s / c.p99_latency_s:.2f}x")

    if args.co_train:
        peak_t = horizon * 0.5 if horizon else 300.0
        eng = co_serving_schedule(peak_t=peak_t)
        train_ex = next(ex for ex in eng.completed if ex.task.priority == 0)
        burst_ex = next(ex for ex in eng.completed if ex.task.priority > 0)
        print(f"\nco-training: serving burst at t={peak_t:.1f}s "
              f"queued {burst_ex.queueing_delay_s:.1f}s; training preempted "
              f"{train_ex.preemptions}x, decisions "
              f"{train_ex.preemption_decisions}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
