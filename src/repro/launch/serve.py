"""Device-cloud serving driver: DeviceFlow replays request traffic against a
batched prefill+decode loop — the paper's "fluctuating access load" concern
(§I challenge 2, system level) applied to LM inference.

Requests arrive on a user-defined traffic curve; a batcher drains the queue
into fixed-size decode batches; per-tick throughput/queue-depth metrics come
back — exactly the information a cloud autoscaler would consume.

Handle-style payload accounting (round-engine parity): request tokens are
stacked into one device-resident ``UpdateBuffer`` and every message carries
an ``UpdateHandle`` row whose ``nbytes`` is the prompt's real wire size — so
DeviceFlow byte accounting (``Shelf.total_bytes_*``) covers serving traffic
exactly like training updates, and same-buffer batches gather their prompt
rows on device instead of re-stacking host lists.  Plain host-dict payloads
(``{"tokens": ndarray}``) remain supported.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.deviceflow import Delivery, DeviceFlow, Message
from repro.core.strategies import TimeIntervalStrategy
from repro.core.traffic_curves import right_tailed_normal
from repro.core.updates import UpdateBuffer, UpdateHandle
from repro.models.registry import get_model


def stack_requests(token_rows: np.ndarray) -> UpdateBuffer:
    """Stack request prompts ``(n, prompt_len)`` into one device-resident
    token buffer; ``buf.handle(i)`` is request ``i``'s message payload."""
    return UpdateBuffer.from_stacked(
        {"tokens": jnp.asarray(np.asarray(token_rows, np.int32))})


@dataclasses.dataclass
class ServeMetrics:
    t: float
    queue_depth: int
    batch_size: int
    tokens_decoded: int


class BatchedServer:
    """Greedy-decodes fixed-size batches from an arrival queue."""

    def __init__(self, cfg, *, batch_size: int, prompt_len: int,
                 decode_tokens: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.params = self.api.init(jax.random.PRNGKey(seed), cfg)
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.max_len = max_len
        self.queue: list[Message] = []
        self.metrics: list[ServeMetrics] = []
        self._prefill = jax.jit(
            lambda p, t: self.api.prefill(p, t, cfg, max_len))
        self._decode = jax.jit(
            lambda p, tok, c: self.api.decode_step(p, tok, cfg, c))

    # DeviceFlow delivery callback: a request message arrives.
    def __call__(self, d: Delivery) -> None:
        self.queue.append(d.message)
        while len(self.queue) >= self.batch_size:
            self._serve_batch(d.t)

    def _gather_prompts(self, batch: list[Message]) -> jnp.ndarray:
        """(batch, prompt_len) int32 prompt tokens from message payloads.

        Same-buffer handle payloads take the device gather fast path (no
        host round-trip); anything else stacks on host as before.
        """
        if (all(isinstance(m.payload, UpdateHandle) for m in batch)
                and len({id(m.payload.buffer) for m in batch}) == 1):
            leaf = batch[0].payload.buffer.leaves2d[0]  # (rows, prompt_len)
            rows = jnp.asarray([m.payload.row for m in batch])
            return jnp.take(leaf, rows, axis=0)[:, : self.prompt_len]
        tokens = [(m.payload.materialize()["tokens"]
                   if isinstance(m.payload, UpdateHandle) else
                   m.payload["tokens"]) for m in batch]
        return jnp.stack(
            [jnp.asarray(tk[: self.prompt_len]) for tk in tokens])

    def _serve_batch(self, t: float) -> None:
        batch = [self.queue.pop(0) for _ in range(self.batch_size)]
        prompts = self._gather_prompts(batch)
        logits, caches = self._prefill(self.params, prompts)
        tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
        n = 0
        for _ in range(self.decode_tokens):
            logits, caches = self._decode(self.params, tok, caches)
            tok = jnp.argmax(
                logits[:, : self.cfg.vocab_size], axis=-1).astype(jnp.int32)
            n += self.batch_size
        self.metrics.append(ServeMetrics(
            t=t, queue_depth=len(self.queue),
            batch_size=self.batch_size, tokens_decoded=n,
        ))

    def drain(self, t: float) -> None:
        while len(self.queue) >= self.batch_size:
            self._serve_batch(t)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--interval", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    server = BatchedServer(
        cfg, batch_size=args.batch_size, prompt_len=args.prompt_len,
        decode_tokens=args.decode_tokens,
        max_len=args.prompt_len + args.decode_tokens + 1, seed=args.seed)

    flow = DeviceFlow(server, seed=args.seed)
    flow.register_task(0, TimeIntervalStrategy(
        curve=right_tailed_normal(args.sigma), interval=args.interval))

    rng = np.random.default_rng(args.seed)
    # Handle payloads: one device-resident token buffer, one row per request
    # — Message.size_bytes is the prompt's real wire size, so the shelf's
    # byte counters below report actual serving traffic.
    buf = stack_requests(rng.integers(
        1, cfg.vocab_size, size=(args.requests, args.prompt_len)))
    for i in range(args.requests):
        flow.submit(Message(
            task_id=0, device_id=i, round_idx=0, payload=buf.handle(i)))
    flow.round_complete(0)
    flow.run()
    server.drain(flow.clock.now)

    total = sum(m.tokens_decoded for m in server.metrics)
    shelf = flow.shelf(0)
    print(f"served {len(server.metrics)} batches, {total} tokens; "
          f"peak queue {max((m.queue_depth for m in server.metrics), default=0)}; "
          f"request traffic {shelf.total_bytes_dispatched / 1024:.1f} KiB "
          f"({shelf.total_bytes_dispatched // max(shelf.total_dispatched, 1)} "
          f"B/request)")
    for m in server.metrics[:10]:
        print(f"  t={m.t:7.2f}s queue={m.queue_depth:3d} "
              f"decoded={m.tokens_decoded}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
