"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16x16 = 256 chips; multi-pod: 2 pods x 256 =
512 chips with a leading ``pod`` axis (data parallelism over DCI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist —
    used by distributed *tests*, never by the dry-run."""
    return jax.make_mesh((data, model), ("data", "model"))
