"""Multi-process fleet execution: sharded worker pool, zero-copy transport.

The whole platform so far runs in ONE Python process on one ``VirtualClock``
— the 1M-device columnar round (PR 6) saturates a single host and cohort
compute cannot overlap across fleet shards.  This module is the
coordinator/worker control plane that splits *cohort execution* across N
worker processes while the coordinator keeps everything stateful and
time-authoritative (``TaskEngine``, ``DeviceFlow``, ``AggregationService``,
fleet sampling, arrival stamping) in one place:

* **Workers compute, the coordinator decides.**  A round's cohort chunks —
  the exact ``(lo, hi)`` ranges + per-chunk rng subkeys the single-process
  engine would have run — are dispatched to workers (chunk ``i`` goes to
  worker ``i % N``, a stable fleet-shard assignment that keeps int8
  error-feedback residuals resident with "their" devices across rounds).
  Each worker owns its own jitted cohort loop (``run_cohort_zero_copy`` /
  ``run_cohort_quantized`` on tiers rebuilt from a picklable
  :class:`WorkerSpec` factory), so JAX compilation and dispatch parallelize
  across processes.

* **Zero-copy columnar transport.**  Results come back as the *existing*
  struct-of-arrays wire format: the chunk's ``UpdateBuffer`` leaves (int8 or
  f32, plus scale columns) are written into a ``multiprocessing
  .shared_memory`` segment in a canonical layout both sides compute from the
  update spec, and only a slim ``(call, chunk, shm_name, rows)`` header
  crosses the pipe — no pickling of model data.  The coordinator wraps the
  segment's numpy views in an ordinary ``UpdateBuffer``, so byte accounting
  (``row_nbytes`` → ``Shelf.total_bytes_*``) and the fused ``fed_reduce``
  aggregation path are untouched.

* **Recycled segment ring (the PR 3 donation discipline, across
  processes).**  Workers keep a free-list of segments and reuse one as soon
  as the coordinator releases it.  Release is GC-driven, mirroring how
  device buffers are freed: a ``weakref.finalize`` on each coordinator-side
  ``UpdateBuffer`` sends ``("free", name)`` back to the owning worker the
  moment the buffer is garbage-collected (i.e. when aggregation has consumed
  the round and dropped its handles).  Steady-state rounds therefore
  allocate no new segments.  Lifetime rule: anything read out of a buffer
  must be *copied* before the buffer is dropped — ``materialize`` /
  ``materialize_row`` already do this for shared-memory-backed leaves.

* **Graceful worker death.**  A worker dying mid-round (EOF on its pipe)
  does not hang the round barrier: its still-pending chunks are re-assigned
  to the survivors through ``runtime.fault_tolerance.redispatch_chunks`` and
  the failure is recorded on ``pool.failures``.  Re-dispatched int8 chunks
  restart their error-feedback residual from zero (the residual died with
  the worker) — the same semantics as a fresh device joining the fleet.

Determinism: because the coordinator precomputes the per-chunk subkeys by
walking the exact single-process rng split chain, and reassembles results in
chunk order before submission, a multi-process round is **bit-identical** to
the single-process columnar round — dispatch-group membership, ``created_t``
stamps, byte counters, and the reduced delta (property-tested in
``tests/test_workers.py``).  With ``stream_chunks=True`` results are instead
emitted in *completion* order so streaming partial reduction overlaps
still-running shards; global dispatch membership is then recovered by
arrival-time ordering exactly as in the single-process streaming trade-off.

``HybridSimulation(workers=N, worker_spec=WorkerSpec(factory, ...))``
selects this path; see ``examples/quickstart.py`` §11.
"""
from __future__ import annotations

import dataclasses
import os
import time
import traceback
import weakref
from multiprocessing import connection as mp_connection
from multiprocessing import shared_memory
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.analysis import sanitizers
from repro.runtime.fault_tolerance import redispatch_chunks

_ALIGN = 64  # segment field alignment (cache line; numpy view friendly)


class WorkerPoolError(RuntimeError):
    """Raised when the pool cannot make progress (all workers dead, a
    worker raised, or the round barrier timed out)."""


def _align(off: int) -> int:
    return (off + _ALIGN - 1) & ~(_ALIGN - 1)


def _np_dtype(name: Any) -> np.dtype:
    """``np.dtype`` lookup that also resolves ml_dtypes names (bfloat16)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, str(name)))


def _attach_shm(name: str) -> shared_memory.SharedMemory:
    """Attach to a segment another process created.

    The 3.10 resource tracker is one process shared by the whole tree and
    its cache is a *set*: the attach-side ``register`` is a no-op while the
    creator's entry exists, and the creator's eventual ``unlink`` clears it
    exactly once.  Unregistering here (the often-cited double-unlink
    workaround) would instead erase the creator's entry and make its unlink
    crash the tracker — so: attach, and leave the tracker alone.
    """
    return shared_memory.SharedMemory(name=name)


def segment_layout(shapes: Sequence[tuple], dtypes: Sequence[Any],
                   rows: int, wire: str) -> tuple[list, int]:
    """Canonical shared-memory layout of one chunk's ``UpdateBuffer``.

    Both sides compute this independently from the update spec — the pipe
    header never carries shapes or dtypes.  Layout: every leaf as its
    ``(rows, size)`` wire matrix (int8 for the quantized wire), then — int8
    only — one f32 ``(rows,)`` scale column per leaf, each field aligned to
    64 bytes.  Returns ``([(offset, shape, dtype), ...], total_bytes)`` with
    leaf fields first, scale fields after, in leaf order.
    """
    entries: list[tuple[int, tuple, np.dtype]] = []
    off = 0
    for shape, dt in zip(shapes, dtypes):
        size = int(np.prod(shape)) if shape else 1
        leaf_dt = np.dtype(np.int8) if wire == "int8" else _np_dtype(dt)
        off = _align(off)
        entries.append((off, (rows, size), leaf_dt))
        off += rows * size * leaf_dt.itemsize
    if wire == "int8":
        for _ in shapes:
            off = _align(off)
            entries.append((off, (rows,), np.dtype(np.float32)))
            off += rows * 4
    return entries, max(_align(off), _ALIGN)


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Picklable recipe for rebuilding the simulation tiers inside a worker.

    ``factory(**kwargs)`` must be a *module-level* callable (spawn pickles it
    by reference) returning ``(logical_tier, {grade: device_tier})`` built
    exactly like the coordinator's tiers — same local_train, dtypes, and
    cohort sizes — so worker-computed chunks are bit-identical to inline
    ones.  ``env`` entries are applied to ``os.environ`` before JAX
    initializes in the child (e.g. to pin XLA host threads per worker).
    """

    factory: Callable[..., tuple]
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    env: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def build(self) -> tuple:
        logical, tiers = self.factory(**dict(self.kwargs))
        return logical, dict(tiers)


@dataclasses.dataclass(frozen=True)
class ChunkSpec:
    """One cohort chunk of a round: the same (range, subkey) the inline
    engine would run.  ``kind`` selects the tier: ``"logical"`` or a grade
    name.  ``key`` is the chunk's rng subkey as a host uint32 array."""

    index: int
    kind: str
    lo: int
    hi: int
    key: np.ndarray
    id_offset: int = 0

    @property
    def rows(self) -> int:
        return self.hi - self.lo


def _tree_desc(tree: Any, offset: int) -> tuple[dict, int, list]:
    """Describe a pytree for shared-memory transport: a picklable skeleton
    (leaves replaced by indices) + per-leaf (offset, shape, dtype) entries.
    Returns (desc, next_offset, leaves)."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    skeleton = jax.tree_util.tree_unflatten(treedef, list(range(len(leaves))))
    entries = []
    for leaf in leaves:
        dt = _np_dtype(leaf.dtype)
        shape = tuple(int(s) for s in leaf.shape)
        offset = _align(offset)
        entries.append((offset, shape, str(dt)))
        offset += int(np.prod(shape)) * dt.itemsize if shape else dt.itemsize
    return {"skeleton": skeleton, "leaves": entries}, offset, leaves


def _tree_from_desc(desc: dict, buf) -> Any:
    """Rebuild a pytree of numpy views over a shared-memory buffer."""
    import jax

    leaves = [np.ndarray(shape, _np_dtype(dts), buffer=buf, offset=off)
              for off, shape, dts in desc["leaves"]]
    treedef = jax.tree_util.tree_structure(desc["skeleton"])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------
class _WorkerState:
    """Everything a worker process owns: lazily-built tiers, the jitted
    cohort loops' caches, error-feedback residuals for "its" device rows,
    and the recycled ring of result segments."""

    def __init__(self, worker_id: int, spec: WorkerSpec, delay_s: float):
        self.worker_id = worker_id
        self.spec = spec
        self.delay_s = delay_s  # test hook: interleaving jitter per chunk
        self.logical = None
        self.tiers: dict = {}
        self._ef: dict = {}
        self._free: list[shared_memory.SharedMemory] = []
        self._created: dict[str, shared_memory.SharedMemory] = {}
        self._park_close: list = []  # input segs with still-exported views
        self.fail_after: int | None = None  # test hook: die after N chunks
        self._sent = 0

    def _tier(self, kind: str):
        if self.logical is None:
            self.logical, self.tiers = self.spec.build()
        return self.logical if kind == "logical" else self.tiers[kind]

    def _acquire(self, nbytes: int) -> shared_memory.SharedMemory:
        for i, seg in enumerate(self._free):
            if seg.size >= nbytes:
                return self._free.pop(i)
        seg = shared_memory.SharedMemory(create=True, size=nbytes)
        self._created[seg.name] = seg
        return seg

    def release(self, name: str) -> None:
        seg = self._created.get(name)
        if seg is not None and all(s.name != name for s in self._free):
            self._free.append(seg)

    def _drain_parked(self) -> None:
        still = []
        for seg in self._park_close:
            try:
                seg.close()
            except BufferError:
                still.append(seg)
        self._park_close = still

    def run(self, conn, call_id: int, input_desc: dict,
            chunks: list[ChunkSpec], common: dict) -> None:
        import jax
        import jax.numpy as jnp

        self._drain_parked()
        seg = _attach_shm(input_desc["name"])
        try:
            params_np = _tree_from_desc(input_desc["params"], seg.buf)
            batches_np = _tree_from_desc(input_desc["batches"], seg.buf)
            # Params go on-device once per call; chunk slices are cheap
            # views copied at each cohort dispatch, like the inline path.
            params = jax.tree.map(jnp.asarray, params_np)
            del params_np
            wire = common["wire"]
            for c in chunks:
                if self.fail_after is not None and self._sent >= self.fail_after:
                    os._exit(1)  # test hook: simulated mid-round crash
                tier = self._tier(c.kind)
                chunk = jax.tree.map(lambda x: x[c.lo:c.hi], batches_np)
                rngs = jax.random.split(jnp.asarray(c.key), c.rows)
                if wire == "int8":
                    ef_key = (common["task_id"], c.kind,
                              c.id_offset + c.lo, c.id_offset + c.hi)
                    buf, metrics, res = tier.run_cohort_quantized(
                        params, chunk, rngs,
                        residual=self._ef.get(ef_key),
                        error_feedback=common["error_feedback"])
                    if common["error_feedback"]:
                        self._ef[ef_key] = res
                else:
                    buf, metrics = tier.run_cohort_zero_copy(
                        params, chunk, rngs)
                del chunk
                entries, total = segment_layout(
                    buf.shapes, buf.dtypes, buf.num_rows, wire)
                out = self._acquire(total)
                arrays = list(buf.leaves2d) + list(buf.scales or ())
                for (off, shape, dt), src in zip(entries, arrays):
                    dst = np.ndarray(shape, dt, buffer=out.buf, offset=off)
                    np.copyto(dst, np.asarray(src).astype(dt, copy=False))
                    del dst
                if self.delay_s:
                    time.sleep(self.delay_s)
                conn.send(("batch", call_id, c.index, out.name,
                           buf.num_rows, jax.device_get(metrics)))
                self._sent += 1
        finally:
            try:
                del batches_np
            except NameError:
                pass
            try:
                seg.close()
            except BufferError:  # a view outlived the call; retry later
                self._park_close.append(seg)

    def cleanup(self) -> None:
        for seg in self._created.values():
            try:
                seg.close()
            except BufferError:
                pass
            try:
                seg.unlink()
            except FileNotFoundError:
                pass


def _worker_main(worker_id: int, conn, spec: WorkerSpec,
                 delay_s: float) -> None:
    os.environ.update(dict(spec.env))
    state = _WorkerState(worker_id, spec, delay_s)
    try:
        conn.send(("ready", worker_id))
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                break  # coordinator gone
            tag = msg[0]
            if tag == "stop":
                break
            elif tag == "free":
                state.release(msg[1])
            elif tag == "poison":
                state.fail_after = msg[1]
            elif tag == "run":
                _, call_id, input_desc, chunks, common = msg
                try:
                    state.run(conn, call_id, input_desc, chunks, common)
                except Exception:
                    conn.send(("error", call_id, -1, traceback.format_exc()))
    finally:
        state.cleanup()


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _WorkerHandle:
    worker_id: int
    proc: Any
    conn: Any
    alive: bool = True
    announced: set = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class _Seg:
    shm: shared_memory.SharedMemory
    owner: int


class FleetWorkerPool:
    """Coordinator handle on N spawned cohort workers.

    Processes start lazily on the first :meth:`run_chunks` (spawn context —
    forking an initialized JAX runtime is unsafe) and are daemons, so a
    crashed coordinator never strands them.  See the module docstring for
    the transport/recycling/fault model.
    """

    def __init__(self, spec: WorkerSpec, num_workers: int, *,
                 chunk_timeout_s: float = 600.0,
                 start_timeout_s: float = 120.0,
                 debug_delay_s: Sequence[float] | None = None):
        if num_workers < 1:
            raise ValueError("need at least one worker")
        self.spec = spec
        self.num_workers = int(num_workers)
        self.chunk_timeout_s = float(chunk_timeout_s)
        self.start_timeout_s = float(start_timeout_s)
        self._debug_delay_s = tuple(debug_delay_s or ())
        self._workers: list[_WorkerHandle] = []
        self._segments: dict[str, _Seg] = {}  # held by a live UpdateBuffer
        self._to_close: list[shared_memory.SharedMemory] = []
        self._dead_owner_names: set[str] = set()
        self._call_counter = 0
        self._closed = False
        self.failures: list = []
        self.stats = {"calls": 0, "chunks": 0, "segments_created": 0,
                      "segment_reuses": 0, "redispatched_chunks": 0,
                      "bytes_shipped": 0, "input_bytes": 0}

    # -- lifecycle ---------------------------------------------------------
    @property
    def started(self) -> bool:
        return bool(self._workers)

    def start(self) -> None:
        if self._workers or self._closed:
            return
        import multiprocessing as mp

        ctx = mp.get_context("spawn")
        for wid in range(self.num_workers):
            parent, child = ctx.Pipe()
            delay = (self._debug_delay_s[wid % len(self._debug_delay_s)]
                     if self._debug_delay_s else 0.0)
            proc = ctx.Process(target=_worker_main,
                               args=(wid, child, self.spec, delay),
                               daemon=True, name=f"fleet-worker-{wid}")
            proc.start()
            child.close()
            self._workers.append(_WorkerHandle(wid, proc, parent))
        deadline = time.monotonic() + self.start_timeout_s
        for h in self._workers:
            remaining = max(0.1, deadline - time.monotonic())
            if not h.conn.poll(remaining):
                self.close()
                raise WorkerPoolError(
                    f"worker {h.worker_id} did not report ready within "
                    f"{self.start_timeout_s}s")
            tag = h.conn.recv()
            if tag[0] != "ready":  # pragma: no cover - defensive
                self.close()
                raise WorkerPoolError(f"bad handshake from {h.worker_id}")

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for h in self._workers:
            if h.alive:
                try:
                    h.conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        for h in self._workers:
            h.proc.join(timeout=5.0)
            if h.proc.is_alive():  # pragma: no cover - defensive
                h.proc.terminate()
                h.proc.join(timeout=5.0)
            try:
                h.conn.close()
            except OSError:
                pass
            h.alive = False
        # Names the (now exited) workers no longer own: make sure nothing
        # lingers in /dev/shm.  Held mappings stay valid for live buffers.
        for name, seg in self._segments.items():
            try:
                seg.shm.unlink()
            except FileNotFoundError:
                pass
        self._drain_closes()
        if sanitizers.enabled() and self._to_close:
            # A segment that cannot unmap at teardown means an exported
            # numpy view outlived its UpdateBuffer — the lifetime rule in
            # this module's docstring.  Unlinked above, so /dev/shm is
            # clean; the mapping itself leaks until the view dies.
            names = sorted(shm.name for shm in self._to_close)
            raise sanitizers.SegmentLeakError(
                f"{len(names)} shared-memory segment(s) still pinned at "
                f"pool teardown (views outlived their buffers): {names}")

    def __enter__(self) -> "FleetWorkerPool":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- segment bookkeeping ----------------------------------------------
    def _drain_closes(self) -> None:
        still = []
        for shm in self._to_close:
            try:
                shm.close()
            except BufferError:
                still.append(shm)
        self._to_close = still

    def _release_segment(self, name: str) -> None:
        """GC hook: the coordinator-side UpdateBuffer over segment ``name``
        was collected — hand the segment back to its worker's free ring."""
        entry = self._segments.pop(name, None)
        if entry is None:
            return
        if not self._closed and name not in self._dead_owner_names:
            h = self._workers[entry.owner]
            if h.alive:
                try:
                    h.conn.send(("free", name))
                except (BrokenPipeError, OSError):
                    pass
        # The buffer's views die right after this callback; close then.
        self._to_close.append(entry.shm)

    def _reap_worker_segments(self, h: _WorkerHandle) -> None:
        """A worker died: unlink every segment it ever announced.  Held
        mappings (live buffers) stay readable — unlink only drops the name."""
        for name in h.announced:
            self._dead_owner_names.add(name)
            entry = self._segments.get(name)
            try:
                shm = entry.shm if entry is not None else _attach_shm(name)
                shm.unlink()
                if entry is None:
                    shm.close()
            except (FileNotFoundError, OSError):
                pass

    # -- round execution ---------------------------------------------------
    def _write_input(self, params: Any, batches: Any) -> tuple:
        import jax

        off = 0
        p_desc, off, p_leaves = _tree_desc(params, off)
        b_desc, off, b_leaves = _tree_desc(batches, off)
        shm = shared_memory.SharedMemory(create=True, size=max(off, _ALIGN))
        for desc, leaves in ((p_desc, p_leaves), (b_desc, b_leaves)):
            for (o, shape, dts), leaf in zip(desc["leaves"], leaves):
                dst = np.ndarray(shape, _np_dtype(dts), buffer=shm.buf,
                                 offset=o)
                np.copyto(dst, np.asarray(leaf))
                del dst
        self.stats["input_bytes"] += int(off)
        return shm, {"name": shm.name, "params": p_desc, "batches": b_desc}

    def _wrap_result(self, h: _WorkerHandle, seg_name: str, rows: int,
                     chunk: ChunkSpec, spec: tuple, wire: str):
        """Wrap a worker's result segment in an ordinary ``UpdateBuffer``
        whose leaves are zero-copy numpy views; register a GC finalizer
        that recycles the segment back to the worker."""
        from repro.core.updates import UpdateBuffer

        treedef, shapes, dtypes = spec
        if rows != chunk.rows:  # pragma: no cover - defensive
            raise WorkerPoolError(
                f"worker {h.worker_id} returned {rows} rows for chunk "
                f"{chunk.index} ({chunk.rows} expected)")
        if seg_name in h.announced:
            self.stats["segment_reuses"] += 1
        else:
            h.announced.add(seg_name)
            self.stats["segments_created"] += 1
        entries, total = segment_layout(shapes, dtypes, rows, wire)
        shm = _attach_shm(seg_name)
        self._segments[seg_name] = _Seg(shm, h.worker_id)
        self.stats["bytes_shipped"] += int(total)
        fields = [np.ndarray(shape, dt, buffer=shm.buf, offset=off)
                  for off, shape, dt in entries]
        n_leaves = len(shapes)
        buf = UpdateBuffer(
            fields[:n_leaves], treedef, shapes, dtypes, wire=wire,
            scales=fields[n_leaves:] if wire == "int8" else None)
        weakref.finalize(buf, self._release_segment, seg_name)
        return buf

    def _on_worker_death(self, h: _WorkerHandle, call_id: int,
                         input_desc: dict, common: dict,
                         expected: dict, pending: dict) -> None:
        h.alive = False
        try:
            h.conn.close()
        except OSError:
            pass
        h.proc.join(timeout=1.0)
        self._reap_worker_segments(h)
        lost = sorted(pending.pop(h.worker_id, set()) & set(expected))
        survivors = [w.worker_id for w in self._workers if w.alive]
        assignment = redispatch_chunks(lost, survivors) if lost else {}
        for wid, idxs in assignment.items():
            self._workers[wid].conn.send(
                ("run", call_id, input_desc, [expected[i] for i in idxs],
                 common))
            pending.setdefault(wid, set()).update(idxs)
        self.stats["redispatched_chunks"] += len(lost)
        from repro.runtime.fault_tolerance import WorkerFailure

        self.failures.append(WorkerFailure(
            worker_id=h.worker_id, chunks=tuple(lost),
            survivors=tuple(survivors)))

    def run_chunks(self, *, task_id: int, round_idx: int, params: Any,
                   batches: Any, chunks: Sequence[ChunkSpec],
                   specs_by_kind: Mapping[str, tuple], wire: str = "f32",
                   error_feedback: bool = True,
                   on_result: Callable | None = None) -> list:
        """Execute one grade's cohort chunks across the pool.

        Ships ``params`` + the grade's stacked ``batches`` once through a
        per-call input segment, dispatches every chunk to its worker, and
        gathers ``(UpdateBuffer, metrics)`` per chunk — returned in CHUNK
        order (the bit-identical reassembly).  ``on_result(index, buf,
        metrics)`` additionally fires in COMPLETION order as shards finish,
        which is what overlaps streaming partial reduction with
        still-running workers.
        """
        if self._closed:
            raise WorkerPoolError("pool is closed")
        self.start()
        self._drain_closes()
        chunks = list(chunks)
        if not chunks:
            return []
        call_id = self._call_counter
        self._call_counter += 1
        self.stats["calls"] += 1
        alive = [h for h in self._workers if h.alive]
        if not alive:
            raise WorkerPoolError("no live workers")
        input_shm, input_desc = self._write_input(params, batches)
        common = {"task_id": int(task_id), "round_idx": int(round_idx),
                  "wire": wire, "error_feedback": bool(error_feedback)}
        try:
            # Stable fleet-shard assignment: chunk i -> worker i % N keeps
            # each row range (and its EF residual) with the same worker
            # across rounds; a dead worker's chunks fall to survivors.
            assign: dict[int, list[ChunkSpec]] = {}
            for c in chunks:
                h = self._workers[c.index % self.num_workers]
                if not h.alive:
                    h = alive[c.index % len(alive)]
                assign.setdefault(h.worker_id, []).append(c)
            pending: dict[int, set] = {}
            for wid, cs in assign.items():
                self._workers[wid].conn.send(
                    ("run", call_id, input_desc, cs, common))
                pending[wid] = {c.index for c in cs}
            expected = {c.index: c for c in chunks}
            results: dict[int, tuple] = {}
            deadline = time.monotonic() + self.chunk_timeout_s
            while expected:
                conns = {h.conn: h for h in self._workers if h.alive}
                if not conns:
                    raise WorkerPoolError(
                        f"all workers dead with {len(expected)} chunks "
                        f"outstanding")
                ready = mp_connection.wait(list(conns), timeout=1.0)
                if not ready:
                    for h in list(conns.values()):
                        if not h.proc.is_alive():
                            self._on_worker_death(h, call_id, input_desc,
                                                  common, expected, pending)
                    if time.monotonic() > deadline:
                        raise WorkerPoolError(
                            f"round barrier timed out with {len(expected)} "
                            f"chunks outstanding")
                    continue
                for conn in ready:
                    h = conns[conn]
                    try:
                        msg = conn.recv()
                    except (EOFError, OSError):
                        self._on_worker_death(h, call_id, input_desc,
                                              common, expected, pending)
                        continue
                    tag = msg[0]
                    if tag == "batch":
                        _, cid, index, seg_name, rows, metrics = msg
                        if cid != call_id or index not in expected:
                            continue  # stale duplicate (redispatch race)
                        c = expected.pop(index)
                        pending.get(h.worker_id, set()).discard(index)
                        buf = self._wrap_result(
                            h, seg_name, rows, c, specs_by_kind[c.kind],
                            wire)
                        results[index] = (buf, metrics)
                        self.stats["chunks"] += 1
                        if on_result is not None:
                            on_result(index, buf, metrics)
                    elif tag == "error":
                        raise WorkerPoolError(
                            f"worker {h.worker_id} raised:\n{msg[3]}")
            return [results[c.index] for c in chunks]
        finally:
            try:
                input_shm.close()
            except BufferError:  # pragma: no cover - defensive
                self._to_close.append(input_shm)
            try:
                input_shm.unlink()
            except FileNotFoundError:  # pragma: no cover - defensive
                pass

    # -- test / fault-injection hooks -------------------------------------
    def poison_worker(self, worker_id: int, fail_after_chunks: int) -> None:
        """Arrange for ``worker_id`` to crash (``os._exit``) after computing
        ``fail_after_chunks`` more chunks — the deterministic kill-a-worker
        fault injection used by the death-handling tests."""
        self.start()
        self._workers[worker_id].conn.send(("poison", fail_after_chunks))

    @property
    def alive_workers(self) -> list[int]:
        return [h.worker_id for h in self._workers if h.alive]
