"""Fault tolerance & elasticity for the SimDC platform at cluster scale.

Three layers, matching the failure domains of a 1000+-node deployment:

1. **Client/device failures** are *first-class inputs* in SimDC (dropout
   strategies, DeviceFlow §V) — aggregation triggers never block on absent
   clients, and over-selection + deadlines bound round time.

2. **Server/trainer failures** — checkpoint/restart (``checkpoint``), retry
   wrappers with bounded backoff, and a restart protocol that resumes
   mid-federated-round from the persisted DeviceFlow shelves.

3. **Resource-pool changes** — elastic rescale: when phones or bundles join
   or leave, the allocation ILP is re-solved for the surviving pool and the
   task continues with the new split (the makespan argument of §IV.B holds
   per-round, so re-solving between rounds is optimal-per-round).

4. **Simulation-worker failures** (``runtime.workers``) — a cohort worker
   process dying mid-round must not hang the coordinator's round barrier:
   :func:`redispatch_chunks` re-assigns the dead shard's still-pending
   cohort chunks round-robin over the survivors, and the pool records a
   :class:`WorkerFailure` per event.  The re-dispatched chunks rerun with
   their original rng subkeys, so the round's *result* is unchanged — only
   its wall-clock and (for int8) the dead shard's error-feedback residual
   (restarted at zero, like a fresh device) pay for the failure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.core import allocation as alloc
from repro.core.scheduler import ResourceManager
from repro.core.task import GradeSpec


@dataclasses.dataclass
class RetryPolicy:
    max_attempts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0
    retryable: tuple[type[BaseException], ...] = (RuntimeError, OSError)


def with_retries(fn: Callable, policy: RetryPolicy = RetryPolicy(),
                 *, on_retry: Callable[[int, BaseException], None] | None = None):
    """Wrap a step/IO function with bounded-backoff retries."""

    def wrapped(*args, **kwargs):
        delay = policy.backoff_s
        for attempt in range(1, policy.max_attempts + 1):
            try:
                return fn(*args, **kwargs)
            except policy.retryable as e:
                if attempt == policy.max_attempts:
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                time.sleep(delay)
                delay *= policy.backoff_mult
        raise AssertionError("unreachable")

    return wrapped


@dataclasses.dataclass(frozen=True)
class WorkerFailure:
    """One worker-process death observed by a ``FleetWorkerPool`` round
    barrier: which worker died, which chunk indices were re-dispatched, and
    who survived to absorb them."""

    worker_id: int
    chunks: tuple[int, ...]
    survivors: tuple[int, ...]


def redispatch_chunks(chunk_ids, survivors) -> dict[int, list]:
    """Re-assign a dead worker's pending cohort chunks to the survivors.

    Round-robin over ``survivors`` (stable order) so a burst of failures
    spreads evenly instead of piling onto one shard.  Raises when nobody is
    left — the coordinator turns that into a round failure rather than a
    hang.  Returns ``{survivor_worker_id: [chunk_id, ...]}``.
    """
    survivors = list(survivors)
    if not survivors:
        raise RuntimeError(
            "no surviving workers to absorb re-dispatched chunks")
    assignment: dict[int, list] = {}
    for i, c in enumerate(sorted(chunk_ids)):
        assignment.setdefault(survivors[i % len(survivors)], []).append(c)
    return assignment


@dataclasses.dataclass
class StragglerPolicy:
    """Over-selection + deadline: select (1+over_select)*K clients, close the
    round at ``deadline_s`` or when ``target`` results arrived (whichever
    first) — the standard federated straggler mitigation, realized through
    DeviceFlow triggers."""

    target: int
    over_select: float = 0.3
    deadline_s: float = 600.0

    @property
    def num_selected(self) -> int:
        return int(self.target * (1.0 + self.over_select))

    def round_complete(self, arrived: int, elapsed_s: float) -> bool:
        return arrived >= self.target or elapsed_s >= self.deadline_s


class ElasticController:
    """Re-solves the hybrid allocation when the resource pool changes."""

    def __init__(self, resources: ResourceManager):
        self.resources = resources
        self.events: list[dict] = []

    def node_failure(self, grade: str, *, bundles: int = 0, phones: int = 0,
                     task_specs: list[GradeSpec] | None = None,
                     runtimes: list[alloc.GradeRuntime] | None = None):
        """Remove failed capacity and return a fresh allocation if specs given."""
        self.resources.scale(grade, bundles_delta=-bundles, phones_delta=-phones)
        self.events.append({
            "type": "failure", "grade": grade, "bundles": bundles,
            "phones": phones, "t": time.time(),
        })
        return self._resolve(task_specs, runtimes)

    def scale_up(self, grade: str, *, bundles: int = 0, phones: int = 0,
                 task_specs: list[GradeSpec] | None = None,
                 runtimes: list[alloc.GradeRuntime] | None = None):
        self.resources.scale(grade, bundles_delta=bundles, phones_delta=phones)
        self.events.append({
            "type": "scale_up", "grade": grade, "bundles": bundles,
            "phones": phones, "t": time.time(),
        })
        return self._resolve(task_specs, runtimes)

    def _resolve(self, task_specs, runtimes):
        if task_specs is None or runtimes is None:
            return None
        free = self.resources.free()
        # Clamp each grade's requested resources to the surviving pool.
        clamped = [
            dataclasses.replace(
                s,
                logical_bundles=min(
                    s.logical_bundles, free.logical_bundles.get(s.grade, 0)),
                physical_devices=min(
                    s.physical_devices, free.physical_devices.get(s.grade, 0)),
            )
            for s in task_specs
        ]
        return alloc.solve_allocation(clamped, runtimes)


@dataclasses.dataclass
class TrainingSupervisor:
    """Checkpoint/restart loop for the cloud-side trainer.

    ``run`` executes ``num_steps`` of ``step_fn`` with periodic async
    checkpoints; on a retryable failure it restores the last committed
    checkpoint and continues — the standard production restart loop.
    """

    checkpointer: Any  # checkpoint.Checkpointer
    checkpoint_every: int = 100
    policy: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)

    def run(self, state, step_fn: Callable, num_steps: int, *,
            state_like=None, extra_fn: Callable[[], dict] | None = None,
            on_restore: Callable[[dict], None] | None = None):
        start = 0
        latest = self.checkpointer.latest_step()
        if latest is not None:
            state, extra = self.checkpointer.restore(
                state_like if state_like is not None else state)
            start = latest
            if on_restore is not None:
                on_restore(extra)
        step = start
        attempts = 0
        while step < num_steps:
            try:
                state = step_fn(state, step)
                step += 1
                attempts = 0
                if step % self.checkpoint_every == 0 or step == num_steps:
                    self.checkpointer.save_async(
                        step, state,
                        extra=(extra_fn() if extra_fn else {}))
            except self.policy.retryable:
                attempts += 1
                if attempts >= self.policy.max_attempts:
                    raise
                latest = self.checkpointer.latest_step()
                if latest is not None:
                    state, extra = self.checkpointer.restore(
                        state_like if state_like is not None else state)
                    step = latest
                    if on_restore is not None:
                        on_restore(extra)
                time.sleep(self.policy.backoff_s * attempts)
        self.checkpointer.wait()
        return state, step
