from repro.data.synthetic_ctr import CTRDataset, make_federated_ctr
from repro.data.partition import dirichlet_partition, iid_partition, label_skew_partition
from repro.data.tokens import TokenPipeline, synthetic_token_batches
