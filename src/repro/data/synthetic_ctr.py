"""Synthetic Avazu-like CTR dataset (paper §VI.A.1).

The paper trains logistic regression for click-through-rate prediction on a
2 M-record subset of Avazu covering 100 000 unique ``device_id``s.  Avazu
cannot be shipped offline, so we generate a statistically analogous dataset:

* hashed categorical features (site/app category, banner position, device
  attributes, anonymized C14–C21) one-hot folded into a fixed-width hashed
  feature space — the standard LR-on-Avazu treatment;
* a ground-truth sparse logit vector generates labels, so the Bayes-optimal
  accuracy is controlled and learnable by LR;
* per-device preference offsets create natural non-IID-ness, with an explicit
  ``positive_rate`` knob per device for the paper's Fig. 11 "70 % of devices
  high-positive / 30 % high-negative" split.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CTRDataset:
    """Federated CTR data: features hashed to ``dim`` dims, one shard per device."""

    features: np.ndarray  # (num_records, dim) float32 (multi-hot hashed)
    labels: np.ndarray  # (num_records,) float32 in {0, 1}
    device_ids: np.ndarray  # (num_records,) int32
    num_devices: int
    dim: int

    def device_shard(self, device_id: int) -> tuple[np.ndarray, np.ndarray]:
        m = self.device_ids == device_id
        return self.features[m], self.labels[m]

    def stacked_shards(
        self, device_ids: np.ndarray, records_per_device: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fixed-size per-device batches (pad/trim) for vectorized simulation.

        Returns (features (D, R, dim), labels (D, R), num_samples (D,)).
        """
        n = len(device_ids)
        X = np.zeros((n, records_per_device, self.dim), np.float32)
        Y = np.zeros((n, records_per_device), np.float32)
        counts = np.zeros((n,), np.int32)
        for i, d in enumerate(device_ids):
            x, y = self.device_shard(int(d))
            k = min(len(x), records_per_device)
            if k == 0:
                continue
            X[i, :k] = x[:k]
            Y[i, :k] = y[:k]
            counts[i] = k
        return X, Y, counts


_N_RAW_FIELDS = 14  # site/app/banner/device fields + C14..C21 analogues


def make_federated_ctr(
    *,
    num_devices: int = 1000,
    records_per_device: int = 20,
    dim: int = 256,
    seed: int = 0,
    noniid_alpha: float | None = None,
    positive_rate_split: tuple[float, float, float] | None = None,
) -> CTRDataset:
    """Generate the synthetic federated CTR dataset.

    ``noniid_alpha``: if set, per-device feature distributions are skewed by a
    Dirichlet(alpha) mixture over latent user segments (smaller = more skew).

    ``positive_rate_split``: ``(frac_high, rate_high, rate_low)`` reproduces
    Fig. 11(b): ``frac_high`` of devices get positive-label rate
    ``rate_high``, the rest ``rate_low``.
    """
    rng = np.random.default_rng(seed)
    n = num_devices * records_per_device

    # Latent segments drive both feature values and CTR propensity.
    n_segments = 8
    seg_field_prefs = rng.integers(0, 1000, size=(n_segments, _N_RAW_FIELDS))
    if noniid_alpha is not None:
        dev_seg_probs = rng.dirichlet([noniid_alpha] * n_segments, size=num_devices)
    else:
        dev_seg_probs = np.full((num_devices, n_segments), 1.0 / n_segments)

    device_ids = np.repeat(np.arange(num_devices, dtype=np.int32), records_per_device)
    seg = np.array(
        [rng.choice(n_segments, p=dev_seg_probs[d]) for d in device_ids],
        dtype=np.int32,
    )

    # Raw categorical values: segment preference + noise, then feature-hashed.
    raw = seg_field_prefs[seg] + rng.integers(0, 50, size=(n, _N_RAW_FIELDS))
    feats = np.zeros((n, dim), np.float32)
    for f in range(_N_RAW_FIELDS):
        h = (raw[:, f] * 2654435761 + f * 97) % dim
        feats[np.arange(n), h] += 1.0
    feats /= np.sqrt(_N_RAW_FIELDS)

    # Ground-truth sparse logit vector => learnable-by-LR labels.
    w_true = rng.normal(0.0, 1.5, size=dim) * (rng.random(dim) < 0.3)
    logits = feats @ w_true - 1.0
    if positive_rate_split is not None:
        frac_high, rate_high, rate_low = positive_rate_split
        is_high = (device_ids % num_devices) < int(frac_high * num_devices)
        target = np.where(is_high, rate_high, rate_low)
        # Shift each device's logits to hit its target positive rate.
        logits = logits + np.log(target / (1.0 - target))
    probs = 1.0 / (1.0 + np.exp(-logits))
    labels = (rng.random(n) < probs).astype(np.float32)

    return CTRDataset(
        features=feats,
        labels=labels,
        device_ids=device_ids,
        num_devices=num_devices,
        dim=dim,
    )
