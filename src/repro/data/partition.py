"""Federated data partitioners: IID, label-skew, Dirichlet (non-IID)."""
from __future__ import annotations

import numpy as np


def iid_partition(n_records: int, n_clients: int, *, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_records)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def label_skew_partition(
    labels: np.ndarray, n_clients: int, *, frac_positive_heavy: float = 0.7,
    heavy_pos_share: float = 0.8, seed: int = 0,
) -> list[np.ndarray]:
    """Paper Fig. 11(b): a fraction of clients get mostly-positive samples."""
    rng = np.random.default_rng(seed)
    pos = rng.permutation(np.flatnonzero(labels > 0.5))
    neg = rng.permutation(np.flatnonzero(labels <= 0.5))
    n_heavy = int(frac_positive_heavy * n_clients)
    per_client = len(labels) // n_clients
    out, pi, ni = [], 0, 0
    for c in range(n_clients):
        share = heavy_pos_share if c < n_heavy else 1.0 - heavy_pos_share
        n_pos = min(int(per_client * share), len(pos) - pi)
        n_neg = min(per_client - n_pos, len(neg) - ni)
        idx = np.concatenate([pos[pi : pi + n_pos], neg[ni : ni + n_neg]])
        pi += n_pos
        ni += n_neg
        rng.shuffle(idx)
        out.append(idx)
    return out


def dirichlet_partition(
    labels: np.ndarray, n_clients: int, *, alpha: float = 0.5, seed: int = 0,
) -> list[np.ndarray]:
    """Classic Dirichlet(alpha) label partition (Hsu et al.)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = rng.permutation(np.flatnonzero(labels == c))
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, cuts)):
            client_idx[cl].extend(part.tolist())
    return [np.array(sorted(ix), dtype=np.int64) for ix in client_idx]
