"""Token data pipeline for LM training/serving.

Production shape: a sharded, host-local, deterministic pipeline that yields
``(tokens, targets, mask)`` batches.  Offline here, the source is a synthetic
corpus (mixture of Zipf-distributed token streams with per-shard seeds so
every data-parallel host draws disjoint streams — the property that matters
for multi-host correctness).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenBatch:
    tokens: np.ndarray  # (batch, seq) int32 inputs
    targets: np.ndarray  # (batch, seq) int32 next-token targets
    mask: np.ndarray  # (batch, seq) float32 loss mask

    @property
    def num_tokens(self) -> int:
        return int(self.mask.sum())


class TokenPipeline:
    """Deterministic per-host shard of a synthetic Zipf corpus."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch_size: int,
        *,
        host_id: int = 0,
        num_hosts: int = 1,
        seed: int = 0,
        zipf_a: float = 1.2,
    ):
        if not 0 <= host_id < num_hosts:
            raise ValueError("host_id out of range")
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng((seed * num_hosts + host_id) ^ 0xA5A5)
        self.zipf_a = zipf_a
        self._step = 0

    def _draw(self, n: int) -> np.ndarray:
        # Zipf with rejection to the vocab range; vectorized.
        out = np.empty(n, dtype=np.int64)
        filled = 0
        while filled < n:
            cand = self.rng.zipf(self.zipf_a, size=2 * (n - filled))
            cand = cand[cand < self.vocab_size][: n - filled]
            out[filled : filled + len(cand)] = cand
            filled += len(cand)
        return out

    def __iter__(self) -> Iterator[TokenBatch]:
        return self

    def __next__(self) -> TokenBatch:
        n = self.batch_size * (self.seq_len + 1)
        stream = self._draw(n).reshape(self.batch_size, self.seq_len + 1)
        self._step += 1
        return TokenBatch(
            tokens=stream[:, :-1].astype(np.int32),
            targets=stream[:, 1:].astype(np.int32),
            mask=np.ones((self.batch_size, self.seq_len), np.float32),
        )

    # -- deterministic restart (checkpoint integration) ----------------------
    def state_dict(self) -> dict:
        return {"step": self._step, "rng": self.rng.bit_generator.state}

    def load_state_dict(self, d: dict) -> None:
        self._step = d["step"]
        self.rng.bit_generator.state = d["rng"]


def synthetic_token_batches(
    vocab_size: int, seq_len: int, batch_size: int, n_batches: int, *, seed: int = 0
) -> list[TokenBatch]:
    pipe = TokenPipeline(vocab_size, seq_len, batch_size, seed=seed)
    return [next(pipe) for _ in range(n_batches)]
