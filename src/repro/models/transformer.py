"""Decoder-only transformer LM (dense / MoE / VLM-backbone families).

init/apply with optional ``lax.scan`` over homogeneous layers (compact HLO,
production compile times); the roofline analyzer multiplies scan-body costs by
the trip count.  The same layer code serves train, prefill, and cached decode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.distribution import ctx as shard_ctx
from repro.distribution.ctx import constrain
from repro.models import moe as moe_lib
from repro.models.layers import (
    attention_apply,
    attention_decode,
    attention_init,
    cross_entropy,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    unembed_apply,
)

Params = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def layer_init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": attention_init(k1, cfg, dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.num_experts:
        p["moe"] = moe_lib.moe_init(k2, cfg, dt)
    else:
        p["mlp"] = mlp_init(k2, cfg, dt)
    return p


def layer_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    h = attention_apply(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                        positions, causal=True)
    x = x + h
    hn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        impl = shard_ctx.moe_impl() or moe_lib.moe_apply
        h, aux = impl(p["moe"], hn, cfg)
    else:
        h, aux = mlp_apply(p["mlp"], hn, cfg), jnp.zeros((), jnp.float32)
    return constrain(x + h, "act_btd"), aux


def layer_decode(p: Params, x: jax.Array, cfg: ModelConfig,
                 cache: dict) -> tuple[jax.Array, dict]:
    h, cache = attention_decode(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                                cfg, cache)
    x = x + h
    hn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        impl = shard_ctx.moe_impl() or moe_lib.moe_apply
        h, _ = impl(p["moe"], hn, cfg)
    else:
        h = mlp_apply(p["mlp"], hn, cfg)
    return x + h, cache


def init(key, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    ke, kl = jax.random.split(key)
    vp = padded_vocab(cfg.vocab_size)
    params = {
        "embed": embed_init(ke, cfg, dt, vp),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    layer_keys = jax.random.split(kl, cfg.num_layers)
    if cfg.scan_layers:
        params["layers"] = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    else:
        params["layers"] = [layer_init(k, cfg) for k in layer_keys]
    return params


def _run_stack(params: Params, x: jax.Array, cfg: ModelConfig,
               positions: jax.Array, remat: bool) -> tuple[jax.Array, jax.Array]:
    f = layer_apply
    if remat:
        f = jax.checkpoint(f, static_argnums=(2,))
    if cfg.scan_layers:
        def body(carry, lp):
            h, aux = f(lp, carry[0], cfg, positions)
            return (h, carry[1] + aux), None
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return x, aux
    aux = jnp.zeros((), jnp.float32)
    for lp in params["layers"]:
        x, a = f(lp, x, cfg, positions)
        aux = aux + a
    return x, aux


def apply(
    params: Params,
    tokens: jax.Array,  # (b, s) int32
    cfg: ModelConfig,
    *,
    prefix_embeds: jax.Array | None = None,  # (b, n, d) VLM patch embeddings
    remat: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (b, s_total, padded_vocab) f32, aux_loss)."""
    x = embed_apply(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "act_btd")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux = _run_stack(params, x, cfg, positions, remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return constrain(unembed_apply(params["embed"], x), "logits"), aux


def loss_fn(params: Params, batch: dict, cfg: ModelConfig, *,
            remat: bool = True, aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    logits, aux = apply(
        params, batch["tokens"], cfg,
        prefix_embeds=batch.get("prefix_embeds"), remat=remat,
    )
    if batch.get("prefix_embeds") is not None:
        logits = logits[:, batch["prefix_embeds"].shape[1]:]
    ce = cross_entropy(logits, batch["targets"], batch["mask"], cfg.vocab_size)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list | dict:
    dt = _dtype(cfg)
    def one():
        return {
            "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.scan_layers:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one()
        )
    return [one() for _ in range(cfg.num_layers)]


def prefill(
    params: Params,
    tokens: jax.Array,  # (b, s)
    cfg: ModelConfig,
    max_len: int,
    *,
    prefix_embeds: jax.Array | None = None,
) -> tuple[jax.Array, Any]:
    """Full-sequence forward that also populates the KV cache.

    Returns (last-position logits (b, padded_vocab), caches).
    """
    dt = _dtype(cfg)
    x = embed_apply(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pad = max_len - s

    def run_layer(lp, h):
        from repro.models.layers import _project_qkv, rope  # local reuse
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        from repro.models.layers import _attend
        o = _attend(q, k, v, cfg, causal=True)
        h = h + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if cfg.num_experts:
            impl = shard_ctx.moe_impl() or moe_lib.moe_apply
            m, _ = impl(lp["moe"], hn, cfg)
        else:
            m = mlp_apply(lp["mlp"], hn, cfg)
        cache = {
            "k": jnp.pad(k.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
            "pos": jnp.asarray(s, jnp.int32),
        }
        return h + m, cache

    if cfg.scan_layers:
        def body(h, lp):
            h, cache = run_layer(lp, h)
            return h, cache
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        caches = []
        for lp in params["layers"]:
            x, c = run_layer(lp, x)
            caches.append(c)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed_apply(params["embed"], x[:, -1])
    return logits, caches


def decode_step(
    params: Params,
    token: jax.Array,  # (b,) int32 — last sampled token
    cfg: ModelConfig,
    caches: Any,
) -> tuple[jax.Array, Any]:
    """One-token decode: returns (logits (b, padded_vocab), caches)."""
    x = embed_apply(params["embed"], token[:, None])
    if cfg.scan_layers:
        def body(h, xs):
            lp, cache = xs
            h, cache = layer_decode(lp, h, cfg, cache)
            return h, cache
        x, caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        new = []
        for lp, cache in zip(params["layers"], caches):
            x, c = layer_decode(lp, x, cfg, cache)
            new.append(c)
        caches = new
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, 0]), caches
