"""Family → (init, loss_fn, serving fns) dispatch."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, mamba2, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init: Callable
    loss_fn: Callable
    apply: Callable | None = None
    init_cache: Callable | None = None
    prefill: Callable | None = None
    decode_step: Callable | None = None


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        return ModelApi(
            init=transformer.init,
            loss_fn=transformer.loss_fn,
            apply=transformer.apply,
            init_cache=transformer.init_cache,
            prefill=transformer.prefill,
            decode_step=transformer.decode_step,
        )
    if cfg.family == "ssm":
        return ModelApi(
            init=mamba2.init,
            loss_fn=mamba2.loss_fn,
            apply=mamba2.apply,
            init_cache=mamba2.init_cache,
            prefill=mamba2.prefill,
            decode_step=mamba2.decode_step,
        )
    if cfg.family == "hybrid":
        return ModelApi(
            init=hybrid.init,
            loss_fn=hybrid.loss_fn,
            apply=hybrid.apply,
            init_cache=hybrid.init_cache,
            prefill=hybrid.prefill,
            decode_step=hybrid.decode_step,
        )
    if cfg.family == "audio":
        return ModelApi(
            init=encdec.init,
            loss_fn=encdec.loss_fn,
            apply=None,
            init_cache=encdec.init_cache,
            prefill=encdec.prefill,
            decode_step=encdec.decode_step,
        )
    raise ValueError(f"unknown family {cfg.family}")
