"""Shared neural building blocks (pure JAX, init/apply style).

Conventions:
* params are nested dicts of jnp arrays; init fns take a PRNG key + config;
* compute dtype follows the input (bf16 end-to-end), with f32 accumulation
  inside softmax/normalization/logits (mixed-precision production recipe);
* every block is shape-polymorphic over batch/sequence so the same code path
  serves train, prefill and decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.ctx import constrain
from repro.kernels.decode_attention.ops import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

Params = Any


def truncated_normal_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# Normalization
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rmsnorm_gated(x: jax.Array, z: jax.Array, w: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Mamba2 gated RMSNorm: norm(x * silu(z)) * w."""
    xf = (x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention block (GQA + RoPE), shared by all attention-bearing families
# --------------------------------------------------------------------------- #
def attention_init(key, cfg: ModelConfig, dtype) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {}
    if cfg.fuse_qkv:
        # Beyond-paper: one fused projection (D, (H + 2KV) * hd) — fewer HLO
        # ops / fewer weight all-gathers under FSDP (see §Perf).
        p["wqkv"] = truncated_normal_init(ks[0], (D, (H + 2 * KV) * hd), dtype)
    else:
        p["wq"] = truncated_normal_init(ks[0], (D, H * hd), dtype)
        p["wk"] = truncated_normal_init(ks[1], (D, KV * hd), dtype)
        p["wv"] = truncated_normal_init(ks[2], (D, KV * hd), dtype)
    p["wo"] = truncated_normal_init(ks[3], (H * hd, D), dtype,
                                    scale=0.02 / (2 * cfg.num_layers) ** 0.5)
    if cfg.qkv_bias:
        zeros = lambda n: jnp.zeros((n * hd,), dtype)
        if cfg.fuse_qkv:
            p["bqkv"] = zeros(H + 2 * KV)
        else:
            p["bq"], p["bk"], p["bv"] = zeros(H), zeros(KV), zeros(KV)
    return p


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.fuse_qkv:
        qkv = x @ p["wqkv"]
        if cfg.qkv_bias:
            qkv = qkv + p["bqkv"]
        q, k, v = jnp.split(qkv, [H * hd, (H + KV) * hd], axis=-1)
    else:
        q, k, v = x @ p["wq"], x @ p["wk"], x @ p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, s, H, hd), k.reshape(b, s, KV, hd),
            v.reshape(b, s, KV, hd))


def _attend(q, k, v, cfg: ModelConfig, *, causal: bool, q_offset: int = 0):
    impl = cfg.attention_impl
    if impl == "auto":
        impl = "chunked" if q.shape[1] * k.shape[1] > 2048 * 2048 else "einsum"
    if impl == "einsum":
        return attention_ref(q, k, v, causal=causal, q_offset=q_offset)
    return flash_attention(
        q, k, v, causal=causal, q_offset=q_offset, impl=impl,
        block_k=min(cfg.attention_kv_chunk, k.shape[1]),
    )


def attention_apply(
    p: Params,
    x: jax.Array,  # (b, s, d)
    cfg: ModelConfig,
    positions: jax.Array,  # (b, s)
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attention
) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if kv_override is not None:
        k, v = kv_override
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = rope(k, positions, cfg.rope_theta)
    # Context parallelism: q stays sequence-sharded; k/v are constrained to
    # sequence-replicated, which GSPMD realizes as the per-layer KV all-gather
    # over the sp axis.
    q = constrain(q, "act_q")
    k = constrain(k, "act_kv")
    v = constrain(v, "act_kv")
    o = _attend(q, k, v, cfg, causal=causal)
    return o.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"]


def attention_decode(
    p: Params,
    x: jax.Array,  # (b, 1, d) — one new token
    cfg: ModelConfig,
    cache: dict,  # {"k": (b, S, KV, hd), "v": ..., "pos": scalar int32}
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(p, x, cfg)
    pos = cache["pos"]
    if use_rope:
        pos2d = jnp.full((b, 1), pos, jnp.int32)
        q = rope(q, pos2d, cfg.rope_theta)
        k = rope(k, pos2d, cfg.rope_theta)
    # One-hot masked cache write: elementwise, so GSPMD keeps the cache
    # sequence-sharded (a dynamic-update-slice on a sharded dim would
    # replicate the whole cache).
    seq_iota = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)
    write = (seq_iota == pos)[None, :, None, None]
    k_cache = jnp.where(write, k.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(write, v.astype(cache["v"].dtype), cache["v"])
    k_cache = constrain(k_cache, "cache_kv")
    v_cache = constrain(v_cache, "cache_kv")
    lengths = jnp.full((b,), pos + 1, jnp.int32)
    # Plain masked softmax over the (sequence-sharded) cache: GSPMD lowers the
    # softmax reductions over the sharded axis into the flash-decoding
    # max/sum combine (psum over sp); the Pallas kernel is the on-chip analogue.
    o = decode_attention_ref(q[:, 0], k_cache, v_cache, lengths)
    out = o.reshape(b, 1, H * hd) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache, "pos": pos + 1}


# --------------------------------------------------------------------------- #
# MLP block (dense)
# --------------------------------------------------------------------------- #
def mlp_init(key, cfg: ModelConfig, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    down_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    if cfg.mlp_activation == "swiglu":
        if cfg.fuse_qkv:
            return {
                "w_gate_up": truncated_normal_init(ks[0], (D, 2 * F), dtype),
                "w_down": truncated_normal_init(ks[2], (F, D), dtype, down_scale),
            }
        return {
            "w_gate": truncated_normal_init(ks[0], (D, F), dtype),
            "w_up": truncated_normal_init(ks[1], (D, F), dtype),
            "w_down": truncated_normal_init(ks[2], (F, D), dtype, down_scale),
        }
    return {
        "w_up": truncated_normal_init(ks[0], (D, F), dtype),
        "w_down": truncated_normal_init(ks[2], (F, D), dtype, down_scale),
    }


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_activation == "swiglu":
        if "w_gate_up" in p:
            gu = x @ p["w_gate_up"]
            gate, up = jnp.split(gu, 2, axis=-1)
        else:
            gate, up = x @ p["w_gate"], x @ p["w_up"]
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp_activation == "sq_relu":
        h = x @ p["w_up"]
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_activation == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(f"unknown activation {cfg.mlp_activation}")
    return h @ p["w_down"]


# --------------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------------- #
def embed_init(key, cfg: ModelConfig, dtype, padded_vocab_size: int) -> Params:
    ks = jax.random.split(key, 2)
    p = {"embedding": truncated_normal_init(
        ks[0], (padded_vocab_size, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = truncated_normal_init(
            ks[1], (cfg.d_model, padded_vocab_size), dtype)
    return p


def embed_apply(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed_apply(p: Params, x: jax.Array) -> jax.Array:
    w = p["lm_head"] if "lm_head" in p else p["embedding"].T
    return (x @ w).astype(jnp.float32)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array, vocab_size: int) -> jax.Array:
    """Token-mean CE in f32; padded vocab tail columns are masked out."""
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < vocab_size, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
