"""Zamba2-style hybrid model: Mamba2 backbone + one *shared* attention block.

Zamba2's signature trick is parameter sharing: a single global
attention+MLP transformer block is applied every ``hybrid_attn_every`` Mamba2
layers, reusing the same weights at each application (activations — and hence
KV caches — differ per application).  We implement the shared-block pattern
faithfully; the concatenation-with-embedding input of the original is
simplified to a residual application (noted in DESIGN.md §2).

Sub-quadratic long-context story: the SSM layers carry O(1) state and only
the handful of shared-attention applications keep KV caches, so ``long_500k``
decode is memory-feasible with the cache sequence-sharded over the mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.models import mamba2 as m2
from repro.models import transformer as tfm
from repro.models.layers import (
    cross_entropy,
    embed_apply,
    embed_init,
    rmsnorm,
    unembed_apply,
)

Params = Any


def _attn_positions(cfg: ModelConfig) -> list[int]:
    k = cfg.hybrid_attn_every
    return [i for i in range(cfg.num_layers) if i % k == 0] if k else []


def init(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ke, kl, ka = jax.random.split(key, 3)
    vp = padded_vocab(cfg.vocab_size)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    return {
        "embed": embed_init(ke, cfg, dt, vp),
        "mamba_layers": [m2.block_init(k, cfg) for k in layer_keys],
        "shared_attn": tfm.layer_init(ka, cfg),  # ONE block, reused
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }


def apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
          *, remat: bool = False) -> tuple[jax.Array, jax.Array]:
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    attn_at = set(_attn_positions(cfg))
    mb = jax.checkpoint(m2.block_apply, static_argnums=(2,)) if remat else m2.block_apply
    ab = jax.checkpoint(tfm.layer_apply, static_argnums=(2,)) if remat else tfm.layer_apply
    for i, lp in enumerate(params["mamba_layers"]):
        if i in attn_at:
            x, _ = ab(params["shared_attn"], x, cfg, positions)
        x = mb(lp, x, cfg)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig,
            *, remat: bool = True) -> tuple[jax.Array, dict]:
    logits, _ = apply(params, batch["tokens"], cfg, remat=remat)
    ce = cross_entropy(logits, batch["targets"], batch["mask"], cfg.vocab_size)
    return ce, {"ce": ce}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    n_apps = len(_attn_positions(cfg))
    return {
        "mamba": m2.init_cache(dataclass_replace_scan(cfg), batch),
        "attn": [
            {
                "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt),
                "pos": jnp.zeros((), jnp.int32),
            }
            for _ in range(n_apps)
        ],
    }


def dataclass_replace_scan(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, scan_layers=False)


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int) -> tuple[jax.Array, dict]:
    dt = jnp.dtype(cfg.dtype)
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    attn_at = set(_attn_positions(cfg))
    caches = {"mamba": [], "attn": []}
    for i, lp in enumerate(params["mamba_layers"]):
        if i in attn_at:
            sp = params["shared_attn"]
            from repro.models.layers import _attend, _project_qkv, rope
            hn = rmsnorm(x, sp["ln1"], cfg.norm_eps)
            q, k, v = _project_qkv(sp["attn"], hn, cfg)
            q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
            o = _attend(q, k, v, cfg, causal=True)
            x = x + o.reshape(b, s, -1) @ sp["attn"]["wo"]
            hn = rmsnorm(x, sp["ln2"], cfg.norm_eps)
            from repro.models.layers import mlp_apply
            x = x + mlp_apply(sp["mlp"], hn, cfg)
            pad = max_len - s
            caches["attn"].append({
                "k": jnp.pad(k.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
                "pos": jnp.asarray(s, jnp.int32),
            })
        x, mc = m2.block_prefill(lp, x, cfg)
        caches["mamba"].append(mc)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, -1]), caches


def decode_step(params: Params, token: jax.Array, cfg: ModelConfig,
                caches: dict) -> tuple[jax.Array, dict]:
    x = embed_apply(params["embed"], token[:, None])
    attn_at = _attn_positions(cfg)
    new = {"mamba": [], "attn": []}
    ai = 0
    for i, lp in enumerate(params["mamba_layers"]):
        if i in attn_at:
            x, c = tfm.layer_decode(params["shared_attn"], x, cfg,
                                    caches["attn"][ai])
            new["attn"].append(c)
            ai += 1
        x, mc = m2.block_decode(lp, x, cfg, caches["mamba"][i])
        new["mamba"].append(mc)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, 0]), new
