"""CTR models for the paper's federated experiments (§VI.A.1).

Logistic regression on hashed features — the paper's benchmark model for
device-cloud CTR prediction — plus the client-local SGD step used by both
simulation tiers.  A tiny MLP variant is included for heavier-client
ablations.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def lr_init(key, dim: int, dtype=jnp.float32) -> Params:
    return {
        "w": jnp.zeros((dim,), dtype),
        "b": jnp.zeros((), dtype),
    }


def lr_logits(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def bce_loss(params: Params, x: jax.Array, y: jax.Array,
             mask: jax.Array | None = None) -> jax.Array:
    logits = lr_logits(params, x).astype(jnp.float32)
    per = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    if mask is None:
        return per.mean()
    return (per * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def accuracy(params: Params, x: jax.Array, y: jax.Array,
             mask: jax.Array | None = None) -> jax.Array:
    pred = (lr_logits(params, x) > 0).astype(jnp.float32)
    correct = (pred == y).astype(jnp.float32)
    if mask is None:
        return correct.mean()
    return (correct * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_local_train_fn(*, lr: float = 1e-3, epochs: int = 10):
    """Client-local SGD: the paper's per-device training operator.

    Returns ``f(params, batch, rng) -> (params, metrics)``; ``batch`` is
    ``{"x": (n, dim), "y": (n,), "mask": (n,)}`` (mask handles per-device
    padding in the vectorized cohort layout).
    """

    def local_train(params: Params, batch: dict, rng: jax.Array):
        def epoch_step(p, _):
            g = jax.grad(bce_loss)(p, batch["x"], batch["y"], batch.get("mask"))
            p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        params, _ = jax.lax.scan(epoch_step, params, None, length=epochs)
        metrics = {
            "loss": bce_loss(params, batch["x"], batch["y"], batch.get("mask")),
            "acc": accuracy(params, batch["x"], batch["y"], batch.get("mask")),
        }
        return params, metrics

    return local_train


def mlp_init(key, dim: int, hidden: int = 64, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden), dtype) * (2.0 / dim) ** 0.5,
        "b1": jnp.zeros((hidden,), dtype),
        "w2": jax.random.normal(k2, (hidden,), dtype) * (2.0 / hidden) ** 0.5,
        "b2": jnp.zeros((), dtype),
    }


def mlp_logits(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]
