"""Mamba2 (SSD — state-space duality) language model.

Block layout follows the Mamba2 reference: fused ``in_proj`` producing
``[z, x, B, C, dt]``, short causal depthwise conv over ``[x, B, C]``, SSD scan
(chunked; Pallas kernel on TPU), gated RMSNorm, ``out_proj``.  Decode carries
an O(1) recurrent state per layer — this is what makes the ``long_500k``
cell feasible.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.distribution.ctx import constrain
from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan
from repro.models.layers import (
    cross_entropy,
    embed_apply,
    embed_init,
    rmsnorm,
    rmsnorm_gated,
    truncated_normal_init,
    unembed_apply,
)

Params = Any


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * g * n
    return di, g, n, h, conv_dim


def block_init(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    di, g, n, h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default).
    u = jax.random.uniform(ks[2], (h,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    kz, kx, kbc, kdt = jax.random.split(ks[0], 4)
    kcx, kcbc = jax.random.split(ks[1])
    # Projections are stored separately so each can carry its own sharding:
    # z/x/dt outputs are head-sharded over tp; B/C are per-group (replicated
    # when groups < tp).  Functionally identical to the fused in_proj.
    return {
        "ln": jnp.ones((D,), dt),
        "in_z": truncated_normal_init(kz, (D, di), dt),
        "in_x": truncated_normal_init(kx, (D, di), dt),
        "in_BC": truncated_normal_init(kbc, (D, 2 * g * n), dt),
        "in_dt": truncated_normal_init(kdt, (D, h), dt),
        "conv_x_w": truncated_normal_init(kcx, (cfg.ssm_conv_width, di), dt,
                                          scale=0.5 / cfg.ssm_conv_width),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_BC_w": truncated_normal_init(kcbc, (cfg.ssm_conv_width, 2 * g * n), dt,
                                           scale=0.5 / cfg.ssm_conv_width),
        "conv_BC_b": jnp.zeros((2 * g * n,), dt),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "D_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": truncated_normal_init(
            ks[3], (di, D), dt, scale=0.02 / (2 * cfg.num_layers) ** 0.5),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 *, tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv along seq.  xbc (b, l, c); w (width, c).

    ``tail`` is the (b, width-1, c) left-context carried by the decode cache.
    """
    width = w.shape[0]
    if tail is None:
        xbc_p = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xbc_p = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(width):  # width is 4: unrolled elementwise adds
        out = out + xbc_p[:, i : i + xbc.shape[1]] * w[i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _project(p: Params, hn: jax.Array):
    return hn @ p["in_z"], hn @ p["in_x"], hn @ p["in_BC"], hn @ p["in_dt"]


def block_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                *, impl: str = "auto") -> jax.Array:
    b, l, D = x.shape
    di, g, n, h, conv_dim = _dims(cfg)
    hn = rmsnorm(x, p["ln"], cfg.norm_eps)
    z, xp, BC_raw, dt_raw = _project(p, hn)
    z, xp = constrain(z, "ssm_inner"), constrain(xp, "ssm_inner")
    BC_raw = constrain(BC_raw, "ssm_bc")
    xs = _causal_conv(xp, p["conv_x_w"], p["conv_x_b"])
    BC = _causal_conv(BC_raw, p["conv_BC_w"], p["conv_BC_b"])
    B, C = jnp.split(BC, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_scan(
        xs.reshape(b, l, h, cfg.ssm_head_dim),
        dt, A,
        B.reshape(b, l, g, n), C.reshape(b, l, g, n),
        chunk=min(cfg.ssm_chunk, l), impl=impl,
    )
    y = y + p["D_skip"][None, None, :, None] * xs.reshape(b, l, h, cfg.ssm_head_dim).astype(jnp.float32)
    y = constrain(y.reshape(b, l, di).astype(x.dtype), "ssm_inner")
    y = rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    return constrain(x + y @ p["out_proj"], "act_btd")


def block_prefill(p: Params, x: jax.Array, cfg: ModelConfig,
                  *, impl: str = "auto") -> tuple[jax.Array, dict]:
    """Like block_apply but returns the decode cache (conv tail + ssm state)."""
    b, l, D = x.shape
    di, g, n, h, conv_dim = _dims(cfg)
    width = cfg.ssm_conv_width
    hn = rmsnorm(x, p["ln"], cfg.norm_eps)
    z, xp, BC_raw, dt_raw = _project(p, hn)
    xs = _causal_conv(xp, p["conv_x_w"], p["conv_x_b"])
    BC = _causal_conv(BC_raw, p["conv_BC_w"], p["conv_BC_b"])
    B, C = jnp.split(BC, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_scan(
        xs.reshape(b, l, h, cfg.ssm_head_dim),
        dt, A,
        B.reshape(b, l, g, n), C.reshape(b, l, g, n),
        chunk=min(cfg.ssm_chunk, l), impl=impl,
    )
    y = y + p["D_skip"][None, None, :, None] * xs.reshape(b, l, h, cfg.ssm_head_dim).astype(jnp.float32)
    y = y.reshape(b, l, di).astype(x.dtype)
    y = rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    cache = {
        "conv_x": xp[:, l - (width - 1):].astype(x.dtype),
        "conv_BC": BC_raw[:, l - (width - 1):].astype(x.dtype),
        "ssm": state,
    }
    return x + y @ p["out_proj"], cache


def block_decode(p: Params, x: jax.Array, cfg: ModelConfig,
                 cache: dict) -> tuple[jax.Array, dict]:
    """One-token recurrent update: x (b, 1, d)."""
    b = x.shape[0]
    di, g, n, h, conv_dim = _dims(cfg)
    width = cfg.ssm_conv_width
    hn = rmsnorm(x, p["ln"], cfg.norm_eps)
    z, xp, BC_raw, dt_raw = _project(p, hn)
    conv_x_in = jnp.concatenate([cache["conv_x"], xp], axis=1)  # (b, width, di)
    conv_BC_in = jnp.concatenate([cache["conv_BC"], BC_raw], axis=1)
    cx = (conv_x_in * p["conv_x_w"]).sum(axis=1, keepdims=True) + p["conv_x_b"]
    cbc = (conv_BC_in * p["conv_BC_w"]).sum(axis=1, keepdims=True) + p["conv_BC_b"]
    xs = jax.nn.silu(cx.astype(jnp.float32)).astype(x.dtype)[:, 0]
    BC = jax.nn.silu(cbc.astype(jnp.float32)).astype(x.dtype)[:, 0]
    B, C = jnp.split(BC, 2, axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_decode_step(
        xs.reshape(b, h, cfg.ssm_head_dim), dt, A,
        B.reshape(b, g, n), C.reshape(b, g, n), cache["ssm"],
    )
    y = y + p["D_skip"][None, :, None] * xs.reshape(b, h, cfg.ssm_head_dim).astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm_gated(y, z, p["norm_w"], cfg.norm_eps)
    new_cache = {"conv_x": conv_x_in[:, 1:], "conv_BC": conv_BC_in[:, 1:],
                 "ssm": state}
    return x + y @ p["out_proj"], new_cache


# --------------------------------------------------------------------------- #
# Full model
# --------------------------------------------------------------------------- #
def init(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ke, kl = jax.random.split(key)
    vp = padded_vocab(cfg.vocab_size)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    params = {
        "embed": embed_init(ke, cfg, dt, vp),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.scan_layers:
        params["layers"] = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    else:
        params["layers"] = [block_init(k, cfg) for k in layer_keys]
    return params


def apply(params: Params, tokens: jax.Array, cfg: ModelConfig,
          *, remat: bool = False) -> tuple[jax.Array, jax.Array]:
    x = constrain(embed_apply(params["embed"], tokens), "act_btd")
    f = block_apply
    if remat:
        f = jax.checkpoint(f, static_argnums=(2,))
    if cfg.scan_layers:
        def body(h, lp):
            return f(lp, h, cfg), None
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for lp in params["layers"]:
            x = f(lp, x, cfg)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return constrain(unembed_apply(params["embed"], x), "logits"), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig,
            *, remat: bool = True) -> tuple[jax.Array, dict]:
    logits, _ = apply(params, batch["tokens"], cfg, remat=remat)
    ce = cross_entropy(logits, batch["targets"], batch["mask"], cfg.vocab_size)
    return ce, {"ce": ce}


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> Any:
    """max_len unused: SSM decode state is O(1)."""
    dt = jnp.dtype(cfg.dtype)
    di, g, n, h, conv_dim = _dims(cfg)
    def one():
        return {
            "conv_x": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dt),
            "conv_BC": jnp.zeros((batch, cfg.ssm_conv_width - 1, 2 * g * n), dt),
            "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
        }
    if cfg.scan_layers:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one()
        )
    return [one() for _ in range(cfg.num_layers)]


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int = 0) -> tuple[jax.Array, Any]:
    x = embed_apply(params["embed"], tokens)
    if cfg.scan_layers:
        def body(h, lp):
            h, cache = block_prefill(lp, h, cfg)
            return h, cache
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        caches = []
        for lp in params["layers"]:
            x, c = block_prefill(lp, x, cfg)
            caches.append(c)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, -1]), caches


def decode_step(params: Params, token: jax.Array, cfg: ModelConfig,
                caches: Any) -> tuple[jax.Array, Any]:
    x = embed_apply(params["embed"], token[:, None])
    if cfg.scan_layers:
        def body(h, xs):
            lp, cache = xs
            h, cache = block_decode(lp, h, cfg, cache)
            return h, cache
        x, caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        new = []
        for lp, cache in zip(params["layers"], caches):
            x, c = block_decode(lp, x, cfg, cache)
            new.append(c)
        caches = new
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, 0]), caches
