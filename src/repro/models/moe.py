"""Mixture-of-Experts MLP block (GShard/Switch-style capacity dispatch).

TPU-native formulation: routing is expressed as dense one-hot
dispatch/combine einsums over an ``(experts, capacity)`` buffer, so under
GSPMD the token→expert shuffle lowers to a single pair of all-to-alls on the
``ep``-sharded expert axis (no scatter/gather emulation, no dynamic shapes).
Dropped tokens (over capacity) fall through the residual connection, standard
for capacity-factor routing.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import truncated_normal_init

Params = Any


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    down_scale = 0.02 / (2 * cfg.num_layers) ** 0.5
    p = {
        "router": truncated_normal_init(ks[0], (D, E), jnp.float32),
        "w_down": truncated_normal_init(ks[3], (E, F, D), dtype, down_scale),
    }
    if cfg.mlp_activation == "swiglu":
        p["w_gate"] = truncated_normal_init(ks[1], (E, D, F), dtype)
        p["w_up"] = truncated_normal_init(ks[2], (E, D, F), dtype)
    else:
        p["w_up"] = truncated_normal_init(ks[2], (E, D, F), dtype)
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    cap = int(num_tokens * cfg.experts_per_token * cfg.capacity_factor
              / cfg.num_experts)
    return max(cap, cfg.experts_per_token)


def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    """Returns (output (b, s, d), aux_loss scalar)."""
    b, s, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = b * s
    C = _capacity(T, cfg)
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e.
    me = probs.mean(axis=0)
    one_hot_all = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (T, K, E)
    fe = one_hot_all.sum(axis=(0, 1)) / (T * K)
    aux_loss = E * jnp.sum(fe * me)

    # Capacity-based positions: rank of each (token, slot) within its expert.
    flat_expert = expert_idx.reshape(-1)  # (T*K,) in token-major order
    oh = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_expert = (jnp.cumsum(oh, axis=0) - 1) * oh  # (T*K, E)
    pos = pos_in_expert.max(axis=-1)  # (T*K,)
    keep = pos < C
    gates_flat = gate_vals.reshape(-1) * keep.astype(jnp.float32)

    # Dispatch/combine one-hots: (T, K, E, C) contracted immediately.
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)  # (T*K, C)
    disp = (oh.astype(x.dtype)[..., None] * pos_oh[:, None, :])  # (T*K, E, C)
    disp = disp.reshape(T, K, E, C)
    comb = disp.astype(jnp.float32) * gates_flat.reshape(T, K, 1, 1)

    # Expert inputs: (E, C, D) — the all-to-all boundary under GSPMD.
    ein = jnp.einsum("tkec,td->ecd", disp, xt)
    if cfg.mlp_activation == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", ein, p["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", ein, p["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp_activation == "sq_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", ein, p["w_up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ein, p["w_up"]))
    eout = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), p["w_down"])

    out = jnp.einsum("tkec,ecd->td", comb.astype(x.dtype), eout)
    return out.reshape(b, s, D), aux_loss
