"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech/text frontend is a stub per the assignment: ``input_specs()``
supplies precomputed frame embeddings ``(b, s_src, d)`` for the encoder.
Decoder layers add cross-attention against the encoder memory; serving
precomputes the cross KV once at prefill (standard enc-dec serving layout).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.models.layers import (
    _attend,
    _project_qkv,
    attention_init,
    cross_entropy,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rope,
    truncated_normal_init,
    unembed_apply,
)
from repro.models.transformer import layer_apply, layer_decode, layer_init

Params = Any


def _cross_attn_init(key, cfg: ModelConfig, dt) -> Params:
    # Same projection structure as self-attention (never fused: KV comes from
    # the encoder memory at a different time).
    import dataclasses
    return attention_init(key, dataclasses.replace(cfg, fuse_qkv=False, qkv_bias=False), dt)


def init(key, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ke, kenc, kdec, kx = jax.random.split(key, 4)
    vp = padded_vocab(cfg.vocab_size)
    enc_keys = jax.random.split(kenc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    x_keys = jax.random.split(kx, cfg.num_layers)

    def enc_layer(k):
        return layer_init(k, cfg)

    def dec_layer(k, kx_):
        p = layer_init(k, cfg)
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = _cross_attn_init(kx_, cfg, dt)
        return p

    params = {
        "embed": embed_init(ke, cfg, dt, vp),
        "ln_enc": jnp.ones((cfg.d_model,), dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.scan_layers:
        params["encoder"] = jax.vmap(enc_layer)(enc_keys)
        params["decoder"] = jax.vmap(dec_layer)(dec_keys, x_keys)
    else:
        params["encoder"] = [enc_layer(k) for k in enc_keys]
        params["decoder"] = [dec_layer(k, kk) for k, kk in zip(dec_keys, x_keys)]
    return params


def encode(params: Params, src_embeds: jax.Array, cfg: ModelConfig,
           *, remat: bool = False) -> jax.Array:
    """src_embeds: (b, s_src, d) precomputed frontend embeddings."""
    x = src_embeds.astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def enc_apply(lp, h):
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg)
        q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
        o = _attend(q, k, v, cfg, causal=False)  # bidirectional
        h = h + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        return h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)

    f = jax.checkpoint(enc_apply) if remat else enc_apply
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda h, lp: (f(lp, h), None), x, params["encoder"])
    else:
        for lp in params["encoder"]:
            x = f(lp, x)
    return rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _dec_layer_full(lp, x, memory, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    # Self-attention (causal).
    hn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(lp["attn"], hn, cfg)
    q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
    o = _attend(q, k, v, cfg, causal=True)
    x = x + o.reshape(b, s, -1) @ lp["attn"]["wo"]
    # Cross-attention (no RoPE, full memory).
    hn = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
    qc = (hn @ lp["cross"]["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    mk = (memory @ lp["cross"]["wk"]).reshape(
        b, memory.shape[1], cfg.num_kv_heads, cfg.head_dim)
    mv = (memory @ lp["cross"]["wv"]).reshape(
        b, memory.shape[1], cfg.num_kv_heads, cfg.head_dim)
    oc = _attend(qc, mk, mv, cfg, causal=False)
    x = x + oc.reshape(b, s, -1) @ lp["cross"]["wo"]
    # MLP.
    return x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)


def decode_train(params: Params, tokens: jax.Array, memory: jax.Array,
                 cfg: ModelConfig, *, remat: bool = False) -> jax.Array:
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    f = jax.checkpoint(_dec_layer_full, static_argnums=(3,)) if remat else _dec_layer_full
    if cfg.scan_layers:
        x, _ = jax.lax.scan(
            lambda h, lp: (f(lp, h, memory, cfg, positions), None),
            x, params["decoder"])
    else:
        for lp in params["decoder"]:
            x = f(lp, x, memory, cfg, positions)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig,
            *, remat: bool = True) -> tuple[jax.Array, dict]:
    memory = encode(params, batch["src_embeds"], cfg, remat=remat)
    logits = decode_train(params, batch["tokens"], memory, cfg, remat=remat)
    ce = cross_entropy(logits, batch["targets"], batch["mask"], cfg.vocab_size)
    return ce, {"ce": ce}


# --------------------------------------------------------------------------- #
# Serving: cross-KV precomputed at prefill, self-KV cached per decoder layer
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int) -> Any:
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def one():
        return {
            "k": jnp.zeros((batch, max_len, KV, hd), dt),
            "v": jnp.zeros((batch, max_len, KV, hd), dt),
            "pos": jnp.zeros((), jnp.int32),
            "xk": jnp.zeros((batch, src_len, KV, hd), dt),
            "xv": jnp.zeros((batch, src_len, KV, hd), dt),
        }

    if cfg.scan_layers:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one()
        )
    return [one() for _ in range(cfg.num_layers)]


def prefill(params: Params, src_embeds: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, max_len: int) -> tuple[jax.Array, Any]:
    """Encode the source, run the decoder prompt, build all caches."""
    dt = jnp.dtype(cfg.dtype)
    memory = encode(params, src_embeds, cfg)
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    s_src = memory.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pad = max_len - s

    def run_layer(lp, h):
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg)
        q, k = rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)
        o = _attend(q, k, v, cfg, causal=True)
        h = h + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        hn = rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        qc = (hn @ lp["cross"]["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
        mk = (memory @ lp["cross"]["wk"]).reshape(b, s_src, cfg.num_kv_heads, cfg.head_dim)
        mv = (memory @ lp["cross"]["wv"]).reshape(b, s_src, cfg.num_kv_heads, cfg.head_dim)
        oc = _attend(qc, mk, mv, cfg, causal=False)
        h = h + oc.reshape(b, s, -1) @ lp["cross"]["wo"]
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
        cache = {
            "k": jnp.pad(k.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v.astype(dt), ((0, 0), (0, pad), (0, 0), (0, 0))),
            "pos": jnp.asarray(s, jnp.int32),
            "xk": mk.astype(dt),
            "xv": mv.astype(dt),
        }
        return h, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(lambda h, lp: run_layer(lp, h), x,
                                 params["decoder"])
    else:
        caches = []
        for lp in params["decoder"]:
            x, c = run_layer(lp, x)
            caches.append(c)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, -1]), caches


def decode_step(params: Params, token: jax.Array, cfg: ModelConfig,
                caches: Any) -> tuple[jax.Array, Any]:
    from repro.models.layers import attention_decode

    x = embed_apply(params["embed"], token[:, None])
    b = x.shape[0]

    def run_layer(lp, h, cache):
        h_attn, sa = attention_decode(
            lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg,
            {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]},
        )
        h = h + h_attn
        hn = rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        qc = (hn @ lp["cross"]["wq"]).reshape(b, 1, cfg.num_heads, cfg.head_dim)
        from repro.kernels.decode_attention.ops import decode_attention_ref
        s_src = cache["xk"].shape[1]
        lengths = jnp.full((b,), s_src, jnp.int32)
        oc = decode_attention_ref(qc[:, 0], cache["xk"], cache["xv"], lengths)
        h = h + oc.reshape(b, 1, -1) @ lp["cross"]["wo"]
        h = h + mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
        new_cache = dict(sa, xk=cache["xk"], xv=cache["xv"])
        return h, new_cache

    if cfg.scan_layers:
        def body(h, xs):
            lp, cache = xs
            h, c = run_layer(lp, h, cache)
            return h, c
        x, caches = jax.lax.scan(body, x, (params["decoder"], caches))
    else:
        new = []
        for lp, cache in zip(params["decoder"], caches):
            x, c = run_layer(lp, x, cache)
            new.append(c)
        caches = new
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return unembed_apply(params["embed"], x[:, 0]), caches
