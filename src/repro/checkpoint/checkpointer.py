"""Sharded, atomic, async-capable checkpointing with restart protocol.

Production layout: one directory per step; each host writes its local shards
(``shard-<host>.npz``); a ``manifest.json`` committed by atomic rename is the
durability barrier (a step without a manifest is garbage-collected on
restart).  In this single-host container host-count is 1, but the layout,
commit protocol, and restore path are the multi-host ones.

Federated-platform integration: the DeviceFlow shelf state and data-pipeline
RNG state ride in the manifest's ``extra`` field, so a restart resumes
mid-round without message loss or duplication (exactly-once per message).
JSON can't carry live runtime objects, though — mid-round engine snapshots
(``TaskEngine.state_dict(deviceflow=...)``) hold shelved ``Message``s and
columnar ``ArrivalBatch`` segments.  Those ride in the step directory's
``runtime.pkl`` instead (``save(..., runtime_state=...)`` /
``restore_runtime_state``), with every device reference — handle payloads,
batch update buffers — materialized to host arrays first, so the pickle
never contains live device memory.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import pickle
import shutil
import tempfile
import threading
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.core.updates import UpdateBuffer, UpdateHandle, materialize_handles


def _jsonify(obj: Any) -> Any:
    """JSON-safe view of manifest ``extra`` state.

    Engine/service state_dicts carry numpy scalars (virtual-time stamps),
    small arrays, tuples (resource grants), and rng bit-generator states
    (arbitrary-precision ints — JSON-safe in Python); ``json.dumps``
    rejects the numpy types outright, so normalize here instead of pushing
    the conversion burden onto every caller.  Anything else fails *here*,
    named, rather than as an opaque ``json.dumps`` error after the
    checkpoint tempdir was already built.
    """
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(
        f"manifest extra contains a non-JSON-serializable "
        f"{type(obj).__name__}; encode it in the state_dict (live objects "
        f"— Tasks, device buffers — are re-supplied on restore, not saved)")


def _host_runtime_view(obj: Any) -> Any:
    """Recursively replace device references in a runtime-state snapshot with
    host data, so ``runtime.pkl`` pickles cleanly and holds no live buffers.

    Handles the shapes engine state_dicts actually produce: nested
    dicts/lists/tuples, shelved ``Message``s with handle payloads, bare
    handles/buffers, and stray ``jax.Array`` leaves.  (Columnar
    ``ArrivalBatch`` state is already host-safe — ``Shelf.state_dict``
    materializes its buffers via ``UpdateBuffer.state_dict``.)
    """
    from repro.core.deviceflow import Message  # late: avoid import cycle
    if isinstance(obj, dict):
        return {k: _host_runtime_view(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(_host_runtime_view(v) for v in obj)
    if isinstance(obj, Message):
        if isinstance(obj.payload, (UpdateHandle, UpdateBuffer)):
            return dataclasses.replace(obj, payload=obj.payload.materialize())
        return obj
    if isinstance(obj, (UpdateHandle, UpdateBuffer)):
        return obj.materialize()
    if isinstance(obj, jax.Array):
        return np.asarray(obj)
    return obj


def _flatten(tree: Any) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, np.asarray(leaf)))
    return out, jax.tree_util.tree_structure(tree)


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._async_thread: threading.Thread | None = None
        self._async_err: list[BaseException] = []

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             runtime_state: Any = None) -> None:
        """Synchronous save with atomic manifest commit.

        Zero-copy handle payloads (``core.updates.UpdateHandle`` /
        ``UpdateBuffer``) anywhere in ``tree`` are materialized to host
        pytrees here — saved state must never contain live device references.

        ``runtime_state`` (optional) is an arbitrary engine snapshot — the
        one-manifest shape is ``TaskEngine.state_dict(deviceflow=flow,
        fleets=sim.fleets, services={tid: svc})``, which carries scheduled
        events, in-flight scalar/columnar arrivals, fleet RNG counters and
        streaming-aggregation partials as ONE atomic unit — pickled to
        ``runtime.pkl`` inside the step directory after device references
        are materialized to host arrays.  Restore it with
        :meth:`restore_runtime_state`; the manifest records which runtime
        sections the snapshot carries (``runtime_sections``) so tooling can
        tell a full simulation snapshot from a bare engine one without
        unpickling.
        """
        leaves, _ = _flatten(materialize_handles(tree))
        tmp = pathlib.Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        try:
            np.savez(tmp / f"shard-{self.host_id}.npz",
                     **{k: v for k, v in leaves})
            if runtime_state is not None:
                with open(tmp / "runtime.pkl", "wb") as f:
                    pickle.dump(_host_runtime_view(runtime_state), f,
                                protocol=pickle.HIGHEST_PROTOCOL)
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "keys": [k for k, _ in leaves],
                "time": time.time(),
                "extra": _jsonify(extra or {}),
                "has_runtime_state": runtime_state is not None,
                "runtime_sections": (sorted(map(str, runtime_state))
                                     if isinstance(runtime_state, dict)
                                     else []),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            target = self._step_dir(step)
            if target.exists():
                shutil.rmtree(target)
            os.replace(tmp, target)  # atomic commit
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def save_async(self, step: int, tree: Any, *,
                   extra: dict | None = None,
                   runtime_state: Any = None) -> None:
        """Overlap checkpoint I/O with the next training steps.

        Device→host transfer happens synchronously (cheap, and guarantees a
        consistent snapshot); serialization+fsync run on a worker thread.
        """
        self.wait()
        host_tree = jax.tree.map(np.asarray, materialize_handles(tree))
        host_runtime = (None if runtime_state is None
                        else _host_runtime_view(runtime_state))

        def work():
            try:
                self.save(step, host_tree, extra=extra,
                          runtime_state=host_runtime)
            except BaseException as e:  # surfaced on next wait()
                self._async_err.append(e)

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err:
            raise self._async_err.pop()

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        steps = []
        for d in self.dir.glob("step_*"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
            else:  # uncommitted garbage from a crashed save
                shutil.rmtree(d, ignore_errors=True)
        return max(steps) if steps else None

    def restore(self, like: Any, step: int | None = None,
                *, shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure (and shardings) of ``like``.

        Returns (tree, extra).  ``shardings``: optional matching pytree of
        NamedShardings to place restored arrays directly onto the mesh.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / f"shard-{self.host_id}.npz")
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(leaves)
        )
        out = []
        for (path, leaf), sh in zip(leaves, shard_leaves):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            )
            arr = data[key]
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)
        return tree, manifest.get("extra", {})

    def restore_runtime_state(self, step: int | None = None) -> Any:
        """The ``runtime.pkl`` engine snapshot saved alongside ``step`` (the
        latest step when ``None``), or ``None`` if that save carried no
        runtime state.  Feed it to ``TaskEngine.load_state_dict`` /
        ``DeviceFlow.load_state_dict`` — in-flight columnar batches restore
        with their buffers rebuilt as device arrays and shared-buffer
        identity preserved."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._step_dir(step) / "runtime.pkl"
        if not path.exists():
            return None
        with open(path, "rb") as f:
            return pickle.load(f)

    def _gc(self) -> None:
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.dir.glob("step_*")
            if (d / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
