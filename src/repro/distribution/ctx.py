"""Sharding context: models call ``constrain(x, role)``; the distribution
layer installs a role→PartitionSpec map.  Outside a context every call is a
no-op, so model code runs unmodified on a single device.

Roles:
  act_btd    — residual-stream activations (batch, seq, d_model)
  act_q      — query tensor (batch, seq, heads, head_dim)
  act_kv     — key/value tensors (batch, seq, kv_heads, head_dim)
  logits     — (batch, seq, padded_vocab)
  ssm_inner  — mamba inner activations (batch, seq, d_inner)
  ssm_bc     — mamba B/C projections (batch, seq, 2*g*n)
  moe_impl   — callable override for the MoE block (expert-parallel shard_map)
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable

import jax

_CTX: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_context(rules: dict[str, Any]):
    token = _CTX.set(rules)
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, role: str) -> jax.Array:
    rules = _CTX.get()
    if not rules:
        return x
    spec = rules.get(role)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_impl() -> Callable | None:
    rules = _CTX.get()
    return rules.get("moe_impl") if rules else None


def active() -> bool:
    return _CTX.get() is not None
