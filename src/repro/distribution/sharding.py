"""Sharding rules: params / activations / caches → PartitionSpec trees.

The physical production mesh is ``(pod?, data=16, model=16)``.  Per
architecture we *derive* a logical mesh by reshaping the same device array to
``(pod?, data, tp, sp)`` with ``tp*sp = model`` (DESIGN.md §4) — the hardware
topology is untouched; only the axis naming is refined.

Placement summary (train):
  weights      — ``tp`` on heads/d_ff/experts/vocab + FSDP (``data``) on the
                 other matrix dim; biases/norms replicated.
  activations  — batch on ``(pod, data)``, sequence on ``sp``.
  KV caches    — batch on ``data``, sequence on ``sp``, kv-heads on ``tp``
                 (replicated over ``tp`` when kv_dup > 1).
  optimizer    — same specs as the (FSDP-sharded) parameters.
Serving drops FSDP (params replicated over ``data``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshPlan, ModelConfig

DP_AXES = ("pod", "data")  # batch axes when present in the mesh


@dataclasses.dataclass(frozen=True)
class LogicalMesh:
    mesh: Mesh
    plan: MeshPlan
    has_pod: bool

    @property
    def dp(self):  # batch axes
        return ("pod", "data") if self.has_pod else ("data",)

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def derive_logical_mesh(production_mesh: Mesh, plan: MeshPlan) -> LogicalMesh:
    """Reshape (pod?, data, model) devices into (pod?, data, tp, sp)."""
    devs = production_mesh.devices
    has_pod = "pod" in production_mesh.axis_names
    model = devs.shape[-1]
    if plan.tp * plan.sp != model:
        raise ValueError(f"tp*sp={plan.tp * plan.sp} != model axis {model}")
    new_shape = devs.shape[:-1] + (plan.tp, plan.sp)
    names = (("pod",) if has_pod else ()) + ("data", "tp", "sp")
    mesh = Mesh(devs.reshape(new_shape), names)
    return LogicalMesh(mesh=mesh, plan=plan, has_pod=has_pod)


# --------------------------------------------------------------------------- #
# Parameter specs by path rules
# --------------------------------------------------------------------------- #
def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec_for(
    path: str, shape: tuple[int, ...], cfg: ModelConfig, plan: MeshPlan,
    *, train: bool,
) -> P:
    """Rule table mapping a parameter path+shape to a PartitionSpec.

    Leading stacked-layer dims (from scan-layers) are detected by rank vs the
    rule's expected rank and left unsharded.
    """
    fsdp = "data" if (train and plan.fsdp) else None
    tp = "tp" if plan.tp > 1 else None
    sp = "sp" if plan.sp > 1 else None
    kv_shardable = (cfg.num_kv_heads % plan.tp == 0) if plan.tp > 1 else False

    def with_stack(rule: tuple, base_rank: int) -> P:
        extra = len(shape) - base_rank
        return P(*(([None] * extra) + list(rule)))

    leaf = path.rsplit("/", 1)[-1]
    # ---- embedding / unembedding ----
    # NOTE §Perf iteration 4a tried FSDP-sharding the vocab rows during
    # training (to reduce-scatter the embedding gradient); GSPMD answered
    # with full-table gathers instead — REFUTED, reverted (see EXPERIMENTS).
    if leaf == "embedding":
        return P(None, tp)  # rows local-gather, features tp-sharded
    if leaf == "lm_head":
        return P(fsdp, tp)  # vocab tp-sharded => logits stay vocab-sharded
    # ---- attention ----
    if leaf in ("wq", "wqkv"):
        return with_stack((fsdp, tp), 2)
    if leaf in ("wk", "wv"):
        return with_stack((fsdp, tp if kv_shardable else None), 2)
    if leaf == "wo":
        return with_stack((tp, fsdp), 2)
    if leaf in ("bq", "bqkv"):
        return with_stack((tp,), 1)
    if leaf in ("bk", "bv"):
        return with_stack((tp if kv_shardable else None,), 1)
    # ---- dense MLP ----
    if leaf in ("w_gate", "w_up", "w_gate_up") and "moe" not in path:
        return with_stack((fsdp, tp), 2)
    if leaf == "w_down" and "moe" not in path:
        return with_stack((tp, fsdp), 2)
    # ---- MoE (experts on tp = ep axis; FSDP on d_model; router replicated
    #      so every (data, sp) cell routes its own tokens without a gather;
    #      F is NOT sp-sharded — sp ranks hold disjoint tokens, so an sp psum
    #      of F-partial outputs would mix different tokens' results) ----
    if "moe" in path:
        if leaf == "router":
            return with_stack((None, None), 2)
        if leaf in ("w_gate", "w_up"):
            return with_stack((tp, fsdp, None), 3)
        if leaf == "w_down":
            return with_stack((tp, None, fsdp), 3)
    # ---- Mamba2 ----
    if leaf in ("in_z", "in_x"):
        return with_stack((fsdp, tp), 2)
    if leaf == "in_BC":
        bc_shardable = (cfg.ssm_groups % plan.tp == 0) if plan.tp > 1 else False
        return with_stack((fsdp, tp if bc_shardable else None), 2)
    if leaf == "in_dt":
        return with_stack((fsdp, tp), 2)
    if leaf == "conv_x_w":
        return with_stack((None, tp), 2)
    if leaf in ("conv_x_b", "norm_w"):
        return with_stack((tp,), 1)
    if leaf in ("conv_BC_w",):
        return with_stack((None, None), 2)
    if leaf in ("conv_BC_b",):
        return with_stack((None,), 1)
    if leaf in ("A_log", "dt_bias", "D_skip"):
        return with_stack(("tp" if (plan.tp > 1 and shape[-1] % plan.tp == 0) else None,), 1)
    if leaf == "out_proj":
        return with_stack((tp, fsdp), 2)
    # ---- norms, biases, scalars ----
    return P(*([None] * len(shape)))


def param_shardings(
    params_shape: Any, cfg: ModelConfig, lmesh: LogicalMesh, *, train: bool
) -> Any:
    """Pytree of NamedShardings matching a params (shape) tree."""

    def rule(path, leaf):
        spec = param_spec_for(
            _path_str(path), leaf.shape, cfg, lmesh.plan, train=train
        )
        return NamedSharding(lmesh.mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# --------------------------------------------------------------------------- #
# Activation / cache / batch specs
# --------------------------------------------------------------------------- #
def activation_rules(cfg: ModelConfig, lmesh: LogicalMesh,
                     *, kind: str, batch_shardable: bool = True
                     ) -> dict[str, NamedSharding]:
    """Role → sharding map consumed by the models' ``constrain`` hooks.

    ``batch_shardable=False`` (e.g. long_500k's global_batch=1): the batch
    stays unsharded and decode caches shard their *sequence* over the
    otherwise-idle ``data`` axis.
    """
    dp = lmesh.dp if batch_shardable else None
    plan = lmesh.plan
    tp = "tp" if plan.tp > 1 else None
    sp = "sp" if plan.sp > 1 else None
    kv_tp = tp if (plan.tp > 1 and cfg.num_kv_heads % plan.tp == 0) else None
    sh = lmesh.sharding
    # NOTE §Perf iteration 4b tried Megatron-style sequence-parallel norms
    # (residual seq sharded over (sp, tp)); under scan+remat GSPMD added
    # reshard collectives instead of folding the TP psum — all-reduce bytes
    # DOUBLED.  REFUTED, reverted (see EXPERIMENTS §Perf).
    rules = {
        "act_btd": sh(dp, sp, None),
        "act_q": sh(dp, sp, tp, None),
        # KV sequence-replicated: GSPMD inserts the sp all-gather (context
        # parallelism); kv-heads tp-sharded when divisible, else replicated.
        "act_kv": sh(dp, None, kv_tp, None),
        "logits": sh(dp, sp, tp),
        "ssm_inner": sh(dp, None, tp),
        "ssm_bc": sh(dp, None, None),
    }
    if kind == "decode":
        # Cache layout: (batch, seq, kv, hd).  The sequence takes every axis
        # the other dims cannot use: sp always; tp when kv-heads are not
        # tp-shardable (kv-dup archs — otherwise the cache would be
        # *replicated* 16x over tp: 88 GB/dev on nemotron, §Perf); data when
        # the batch cannot shard (long_500k b=1).
        seq_axes = []
        if not batch_shardable:
            seq_axes += list(lmesh.dp)
        if kv_tp is None and tp:
            seq_axes.append(tp)
        if sp:
            seq_axes.append(sp)
        cache_seq = tuple(seq_axes) if seq_axes else None
        rules["cache_kv"] = sh(dp, cache_seq, kv_tp, None)
        rules["act_btd"] = sh(dp, None, None)
        rules["act_q"] = sh(dp, None, tp, None)
        rules["logits"] = sh(dp, None, tp)
    return rules


def batch_shardings(cfg: ModelConfig, lmesh: LogicalMesh, *, kind: str,
                    batch_shardable: bool = True) -> dict:
    dp = lmesh.dp if batch_shardable else None
    sp = "sp" if lmesh.plan.sp > 1 else None
    sh = lmesh.sharding
    if kind == "train":
        # leaves carry a leading microbatch dim: (n_micro, mb, seq)
        out = {
            "tokens": sh(None, dp, sp),
            "targets": sh(None, dp, sp),
            "mask": sh(None, dp, sp),
        }
        if cfg.family == "vlm":
            out["prefix_embeds"] = sh(None, dp, None, None)
        if cfg.family == "audio":
            out["src_embeds"] = sh(None, dp, sp, None)
        return out
    if kind == "prefill":
        out = {"tokens": sh(dp, sp)}
        if cfg.family == "vlm":
            out["prefix_embeds"] = sh(dp, None, None)
        if cfg.family == "audio":
            out["src_embeds"] = sh(dp, sp, None)
        return out
    if kind == "decode":
        return {"token": sh(dp)}
    raise ValueError(kind)


def cache_shardings(cfg: ModelConfig, lmesh: LogicalMesh, cache_shape: Any,
                    *, batch_shardable: bool = True) -> Any:
    """Shardings for a KV/SSM cache (shape) tree."""
    plan = lmesh.plan
    dp = lmesh.dp if batch_shardable else None
    tp = "tp" if plan.tp > 1 else None
    sp = "sp" if plan.sp > 1 else None
    kv_tp_c = tp if (plan.tp > 1 and cfg.num_kv_heads % plan.tp == 0) else None
    seq_axes = []
    if not batch_shardable:
        seq_axes += list(lmesh.dp)
    if kv_tp_c is None and tp:
        seq_axes.append(tp)
    if sp:
        seq_axes.append(sp)
    cache_seq = tuple(seq_axes) if seq_axes else None
    kv_tp = tp if (plan.tp > 1 and cfg.num_kv_heads % plan.tp == 0) else None
    ssm_h_tp = tp if (plan.tp > 1 and cfg.family in ("ssm", "hybrid")
                      and cfg.ssm_heads % plan.tp == 0) else None
    sh = lmesh.sharding

    def rule(path, leaf):
        ps = _path_str(path)
        leaf_name = ps.rsplit("/", 1)[-1]
        rank = len(leaf.shape)
        base = {
            "k": (dp, cache_seq, kv_tp, None),
            "v": (dp, cache_seq, kv_tp, None),
            "xk": (dp, None, kv_tp, None),
            "xv": (dp, None, kv_tp, None),
            "pos": (),
            "conv_x": (dp, None, tp),
            "conv_BC": (dp, None, None),
            "ssm": (dp, ssm_h_tp, None, None),
        }.get(leaf_name)
        if base is None:
            return sh(*([None] * rank))
        extra = rank - len(base)  # stacked-layer leading dims
        return sh(*(([None] * extra) + list(base)))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def make_fleet_mesh(num_fleet_shards: int | None = None,
                    model_shards: int = 1) -> Mesh:
    """``(dp, mp)`` mesh for fleet-sharded federated rounds.

    The redco ``mesh_utils`` idiom: reshape the flat local device array to
    ``(devices // model_shards, model_shards)`` and name the axes ``dp``
    (fleet shards — cohort rows and ``fed_reduce`` rows split here) and
    ``mp`` (intra-model shards).  ``num_fleet_shards=None`` uses every
    device; CPU CI exercises the same code path at ``dp=1``.
    """
    devices = jax.devices()
    if num_fleet_shards is None:
        if len(devices) % model_shards:
            raise ValueError(
                f"{len(devices)} devices not divisible by "
                f"model_shards={model_shards}")
        num_fleet_shards = len(devices) // model_shards
    need = num_fleet_shards * model_shards
    if need > len(devices):
        raise ValueError(
            f"fleet mesh needs {need} devices, have {len(devices)}")
    mesh_devices = np.array(devices[:need]).reshape(
        num_fleet_shards, model_shards)
    return Mesh(mesh_devices, ("dp", "mp"))
