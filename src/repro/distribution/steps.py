"""Distributed train/serve step builders.

``build_train_step`` — gradient-accumulation scan over microbatches, per-layer
remat, AdamW with FSDP-sharded f32 state, donated buffers.

``build_serve_step``  — one-token batched decode against sharded caches
(sequence over ``sp``, kv-heads over ``tp``, batch over ``data``); the
softmax-over-sharded-cache lowers to the flash-decoding psum combine.

``build_prefill_step`` — full-sequence forward populating the caches.

All builders return ``(fn, in_shardings, out_shardings, input_specs)`` so the
dry-run can ``jax.jit(fn, ...).lower(*input_specs).compile()`` without ever
materializing full-scale arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, padded_vocab
from repro.distribution import sharding as shlib
from repro.distribution.ctx import sharding_context
from repro.distribution.moe_parallel import make_moe_sharded
from repro.distribution.sharding import LogicalMesh
from repro.models.registry import get_model
from repro.optim.optimizers import AdamWConfig, adamw_init, adamw_update

SDS = jax.ShapeDtypeStruct


def _eval_params_shape(cfg: ModelConfig) -> Any:
    api = get_model(cfg)
    return jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))


def _dp_size(lmesh: LogicalMesh) -> int:
    sizes = dict(zip(lmesh.mesh.axis_names, lmesh.mesh.devices.shape))
    out = sizes.get("data", 1)
    if lmesh.has_pod:
        out *= sizes.get("pod", 1)
    return out


def _rules(cfg: ModelConfig, lmesh: LogicalMesh, kind: str, train: bool,
           batch_shardable: bool = True) -> dict:
    rules = shlib.activation_rules(cfg, lmesh, kind=kind,
                                   batch_shardable=batch_shardable)
    if cfg.num_experts:
        rules["moe_impl"] = make_moe_sharded(
            cfg, lmesh, train=train, seq_sharded=(kind != "decode"),
            batch_shardable=batch_shardable)
    return rules


# --------------------------------------------------------------------------- #
# Training
# --------------------------------------------------------------------------- #
def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    n, mb = shape.microbatches, shape.global_batch // shape.microbatches
    s = shape.seq_len
    batch = {
        "tokens": SDS((n, mb, s), jnp.int32),
        "targets": SDS((n, mb, s), jnp.int32),
        "mask": SDS((n, mb, s), jnp.float32),
    }
    if cfg.family == "vlm":
        # Patch embeddings replace the first frontend_tokens text positions.
        batch["tokens"] = SDS((n, mb, s - cfg.frontend_tokens), jnp.int32)
        batch["targets"] = SDS((n, mb, s - cfg.frontend_tokens), jnp.int32)
        batch["mask"] = SDS((n, mb, s - cfg.frontend_tokens), jnp.float32)
        batch["prefix_embeds"] = SDS(
            (n, mb, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["src_embeds"] = SDS((n, mb, s, cfg.d_model), jnp.bfloat16)
    return batch


def build_train_step(
    cfg: ModelConfig,
    lmesh: LogicalMesh,
    shape: ShapeConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
):
    api = get_model(cfg)
    rules = _rules(cfg, lmesh, "train", True)
    pshape_early = _eval_params_shape(cfg)
    grad_shardings = shlib.param_shardings(pshape_early, cfg, lmesh, train=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]

        with sharding_context(rules):
            def micro(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    api.loss_fn, has_aux=True)(params, mb, cfg)
                # Keep per-microbatch grads on the FSDP/TP param shards: the
                # backward then emits per-layer reduce-scatters instead of a
                # full-gradient all-reduce (§Perf iteration 1: measured
                # 122 GB/dev of all-reduce on llama3.2-3b train_4k baseline).
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, grad_shardings)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), s),
                params, grad_shardings)
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
            n = shape.microbatches
            grads = jax.tree.map(lambda g: g / n, grads)
            new_params, opt_state, om = adamw_update(
                opt_cfg, grads, state["opt"], params)
        new_state = {"params": new_params, "opt": opt_state}
        return new_state, {"loss": loss_sum / n, **om}

    # Shardings.
    pshape = _eval_params_shape(cfg)
    pshard = shlib.param_shardings(pshape, cfg, lmesh, train=True)
    oshape = jax.eval_shape(adamw_init, pshape)
    oshard = {
        "master": pshard, "m": pshard, "v": pshard,
        "step": NamedSharding(lmesh.mesh, P()),
    }
    state_shard = {"params": pshard, "opt": oshard}
    bspec = train_batch_specs(cfg, shape)
    bshard = {k: v for k, v in shlib.batch_shardings(
        cfg, lmesh, kind="train").items() if k in bspec}

    state_shape = {"params": pshape, "opt": oshape}
    metrics_shard = None  # let jit choose (scalars)
    return train_step, (state_shard, bshard), (state_shard, metrics_shard), (
        state_shape, bspec)


# --------------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------------- #
def serve_cache_shape(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    api = get_model(cfg)
    b = shape.global_batch
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: api.init_cache(cfg, b, shape.seq_len, shape.seq_len))
    return jax.eval_shape(lambda: api.init_cache(cfg, b, shape.seq_len))


def _serve_weight_fsdp(cfg: ModelConfig, lmesh: LogicalMesh) -> bool:
    """ZeRO-inference: when tp-only weights exceed the HBM budget (16 GB
    v5e minus cache/temp headroom), shard serve weights over ``data`` too —
    GSPMD inserts per-layer weight all-gathers (phi3-medium at tp=2: 14.7 GB
    replicated -> 0.9 GB sharded)."""
    per_dev = 2.0 * cfg.num_params() / max(lmesh.plan.tp, 1)
    return per_dev > 12e9


def build_serve_step(cfg: ModelConfig, lmesh: LogicalMesh, shape: ShapeConfig):
    """One-token decode step: (params, caches, token) -> (logits, caches)."""
    api = get_model(cfg)
    bs = shape.global_batch % _dp_size(lmesh) == 0
    rules = _rules(cfg, lmesh, "decode", False, batch_shardable=bs)

    def serve_step(params, caches, token):
        with sharding_context(rules):
            logits, caches = api.decode_step(params, token, cfg, caches)
        return logits, caches

    pshape = _eval_params_shape(cfg)
    pshard = shlib.param_shardings(
        pshape, cfg, lmesh, train=_serve_weight_fsdp(cfg, lmesh))
    cshape = serve_cache_shape(cfg, shape)
    cshard = shlib.cache_shardings(cfg, lmesh, cshape, batch_shardable=bs)
    tshard = shlib.batch_shardings(cfg, lmesh, kind="decode",
                                   batch_shardable=bs)["token"]
    logit_shard = NamedSharding(
        lmesh.mesh, P(lmesh.dp if bs else None,
                      "tp" if lmesh.plan.tp > 1 else None))
    token_spec = SDS((shape.global_batch,), jnp.int32)
    return serve_step, (pshard, cshard, tshard), (logit_shard, cshard), (
        pshape, cshape, token_spec)


def build_prefill_step(cfg: ModelConfig, lmesh: LogicalMesh, shape: ShapeConfig):
    """Full-sequence prefill: (params, inputs...) -> (last logits, caches)."""
    api = get_model(cfg)
    rules = _rules(cfg, lmesh, "prefill", False)
    b, s = shape.global_batch, shape.seq_len

    if cfg.family == "audio":
        def prefill_step(params, src_embeds, tokens):
            with sharding_context(rules):
                return api.prefill(params, src_embeds, tokens, cfg, s)
        inputs = (SDS((b, s, cfg.d_model), jnp.bfloat16),
                  SDS((b, s), jnp.int32))
        bsh = shlib.batch_shardings(cfg, lmesh, kind="prefill")
        in_batch_shard = (bsh["src_embeds"], bsh["tokens"])
    elif cfg.family == "vlm":
        def prefill_step(params, tokens, prefix_embeds):
            with sharding_context(rules):
                return api.prefill(params, tokens, cfg, s,
                                   prefix_embeds=prefix_embeds)
        inputs = (SDS((b, s - cfg.frontend_tokens), jnp.int32),
                  SDS((b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16))
        bsh = shlib.batch_shardings(cfg, lmesh, kind="prefill")
        in_batch_shard = (bsh["tokens"], bsh["prefix_embeds"])
    else:
        def prefill_step(params, tokens):
            with sharding_context(rules):
                return api.prefill(params, tokens, cfg, s)
        inputs = (SDS((b, s), jnp.int32),)
        in_batch_shard = (shlib.batch_shardings(cfg, lmesh, kind="prefill")["tokens"],)

    pshape = _eval_params_shape(cfg)
    pshard = shlib.param_shardings(
        pshape, cfg, lmesh, train=_serve_weight_fsdp(cfg, lmesh))
    logit_shard = NamedSharding(
        lmesh.mesh, P(lmesh.dp, "tp" if lmesh.plan.tp > 1 else None))
    # Output caches: shard like serve caches.
    out_shape = jax.eval_shape(prefill_step, pshape, *inputs)
    cshard = shlib.cache_shardings(cfg, lmesh, out_shape[1])
    return prefill_step, (pshard,) + in_batch_shard, (logit_shard, cshard), (
        pshape,) + inputs


def init_train_state(cfg: ModelConfig, seed: int = 0) -> dict:
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return {"params": params, "opt": adamw_init(params)}
