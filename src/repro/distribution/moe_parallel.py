"""Expert-parallel MoE via shard_map (explicit all-to-all).

Under GSPMD alone, GShard-style dispatch one-hots would be built at *global*
token count — ``(T_global, E, C_global)`` is astronomically large as an HLO
value.  The production formulation dispatches **per data-shard**: each
``(data, sp)`` cell routes its local tokens with a local capacity, and the
token↔expert shuffle is an explicit ``all_to_all`` over the ``tp`` (= expert
parallel) axis.  FSDP weight shards are all-gathered over ``data`` inside the
region (explicit ZeRO-3 gather).

Autodiff flows through shard_map/all_to_all, so the same code path serves
training.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distribution.sharding import LogicalMesh


def _local_moe(xl, router, wg, wu, wd, cfg: ModelConfig, ep_axis: str | None,
               fsdp_axis: str | None, avg_axes: tuple = ()):
    b, s, D = xl.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = b * s
    C = max(int(T * K * cfg.capacity_factor / E), K)
    xt = xl.reshape(T, D)

    if fsdp_axis is not None:
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)

    logits = xt.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    oh_all = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    fe = oh_all.sum(axis=(0, 1)) / (T * K)
    aux = E * jnp.sum(fe * me)
    if avg_axes:
        # Each (data, sp) cell routed different tokens: average the balance
        # loss across them so the out_spec's "replicated" claim holds.
        aux = jax.lax.pmean(aux, avg_axes)

    flat_e = expert_idx.reshape(-1)  # (T*K,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = ((jnp.cumsum(oh, axis=0) - 1) * oh).max(axis=-1)
    keep = pos < C
    gates_flat = gate_vals.reshape(-1) * keep.astype(jnp.float32)

    # Scatter-based dispatch: O(T*K*D) work and O(E*C*D) memory — the GShard
    # dispatch-einsum (kept as the reference formulation in models/moe.py)
    # materializes an O(T*E*C) one-hot, which explodes at prefill token
    # counts (measured: 89 GB/dev on granite prefill_32k — §Perf).
    pos_c = jnp.where(keep, pos, C)  # row C = overflow slot, dropped below
    ein = jnp.zeros((E, C + 1, D), xl.dtype)
    ein = ein.at[flat_e, pos_c].add(
        jnp.repeat(xt, K, axis=0), mode="drop")
    ein = ein[:, :C]  # (E, C, D) local tokens
    if ep_axis is not None:
        # (E, C, D) -> (E/ep, C*ep, D): experts scatter, token-slots gather.
        ein = jax.lax.all_to_all(ein, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
    if cfg.mlp_activation == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", ein, wg)
        up = jnp.einsum("ecd,edf->ecf", ein, wu)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xl.dtype) * up
    elif cfg.mlp_activation == "sq_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", ein, wu)))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ein, wu))
    eout = jnp.einsum("ecf,efd->ecd", h.astype(xl.dtype), wd)
    if ep_axis is not None:
        eout = jax.lax.all_to_all(eout, ep_axis, split_axis=1, concat_axis=0,
                                  tiled=True)
    # Combine: gather each (token, slot)'s expert output, weight, sum over K.
    gathered = eout[flat_e, jnp.minimum(pos_c, C - 1)]  # overflow rows read
    gathered = gathered * gates_flat[:, None].astype(xl.dtype)  # junk, but are zero-gated
    y = gathered.reshape(T, K, D).sum(axis=1)
    return y.reshape(b, s, D), aux


def make_moe_sharded(cfg: ModelConfig, lmesh: LogicalMesh, *, train: bool,
                     seq_sharded: bool = True, batch_shardable: bool = True):
    """Returns a drop-in replacement for ``models.moe.moe_apply``.

    ``seq_sharded=False`` for decode (seq=1 cannot shard over sp);
    ``batch_shardable=False`` when global_batch < the dp axis size.
    """
    plan = lmesh.plan
    mesh = lmesh.mesh
    dp = lmesh.dp if batch_shardable else None
    ep_axis = "tp" if plan.tp > 1 else None
    fsdp_axis = "data" if (train and plan.fsdp) else None
    if ep_axis is not None and cfg.num_experts % plan.tp != 0:
        raise ValueError(
            f"{cfg.name}: experts {cfg.num_experts} not divisible by tp={plan.tp}"
        )

    # Tokens must be sharded over EVERY axis participating in expert
    # parallelism: with x replicated over tp, all tp ranks route identical
    # tokens and the all-to-all ships tp duplicate slot sets — measured 8x
    # (granite) / 16x (phi3.5) expert-FLOP waste (§Perf iteration 2).  The
    # sequence therefore shards over (sp, tp) for dispatch; decode (seq=1)
    # keeps tp replication (its MoE compute is negligible).
    seq_axes = []
    if plan.sp > 1 and seq_sharded:
        seq_axes.append("sp")
    if plan.tp > 1 and seq_sharded:
        seq_axes.append("tp")
    sp = tuple(seq_axes) if seq_axes else None

    x_spec = P(dp, sp, None)
    router_spec = P(None, None)
    wgu_spec = P(ep_axis, fsdp_axis, None)
    wd_spec = P(ep_axis, None, fsdp_axis)

    avg_axes = tuple(a for a in (dp if isinstance(dp, tuple) else (dp,))
                     if a) + (sp if isinstance(sp, tuple) else
                              ((sp,) if sp else ()))
    fn = functools.partial(_local_moe, cfg=cfg, ep_axis=ep_axis,
                           fsdp_axis=fsdp_axis, avg_axes=avg_axes)
    smapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(x_spec, router_spec, wgu_spec, wgu_spec, wd_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )

    def moe_apply_sharded(p: Any, x: jax.Array, cfg_: ModelConfig):
        wg = p.get("w_gate", p["w_up"])
        y, aux = smapped(x, p["router"], wg, p["w_up"], p["w_down"])
        return y, aux

    return moe_apply_sharded
