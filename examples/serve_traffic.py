"""Device-cloud serving under a traffic curve: DeviceFlow replays request
arrivals against a batched prefill+decode server (paper §I system-level
concern, LM edition).

Run:  PYTHONPATH=src python examples/serve_traffic.py
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "llama3_2_3b", "--requests", "32",
               "--batch-size", "4", "--sigma", "1.0"]))
