"""Quickstart: the SimDC platform in ~60 lines.

Simulates a small federated CTR task end-to-end: hybrid allocation decides
the logical/physical split, both tiers run client-local training in batched
(vmapped) cohorts, the device fleet's sampled Table-I round durations become
per-message arrival times through DeviceFlow, and the cloud aggregates with
FedAvg while tracking real queuing latency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AccumulatedStrategy, AggregationService, DeviceFlow, GradeRuntime,
    GradeSpec, SampleThresholdTrigger, solve_allocation,
)
from repro.core.devicemodel import GRADES
from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier
from repro.data.synthetic_ctr import make_federated_ctr
from repro.models import ctr

N_DEVICES, RECORDS, DIM, ROUNDS = 24, 16, 64, 4

# 1. Hybrid allocation (paper Eq. 1): how many devices run on each tier?
spec = GradeSpec("High", N_DEVICES, logical_bundles=64,
                 bundles_per_device=4, physical_devices=4)
rt = GradeRuntime(alpha=16.2, beta=21.6, lam=15.0)  # Table-I calibrated
alloc = solve_allocation([spec], [rt])
print(f"allocation: {alloc.per_grade[0].logical_devices} logical / "
      f"{alloc.per_grade[0].physical_devices} physical, "
      f"makespan {alloc.makespan:.1f}s")

# 2. Data + client-local training operator.
data = make_federated_ctr(num_devices=N_DEVICES, records_per_device=RECORDS,
                          dim=DIM, seed=0)
local_train = ctr.make_local_train_fn(lr=1e-3, epochs=10)
params = ctr.lr_init(jax.random.PRNGKey(0), DIM)

# 3. Cloud service behind DeviceFlow (real-time dispatch here).
svc = AggregationService(params,
                         trigger=SampleThresholdTrigger(N_DEVICES * RECORDS))
flow = DeviceFlow(svc)
flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))

# 4. Hybrid simulation rounds.
sim = HybridSimulation(LogicalTier(local_train, cohort_size=16),
                       DeviceTier(local_train, GRADES["High"]),
                       deviceflow=flow)
X, Y, counts = data.stacked_shards(np.arange(N_DEVICES), RECORDS)
mask = (np.arange(RECORDS)[None] < counts[:, None]).astype(np.float32)
test = make_federated_ctr(num_devices=64, dim=DIM, seed=1)

for rnd in range(ROUNDS):
    outcome = sim.run_round(
        task_id=0, round_idx=rnd, global_params=svc.global_params,
        client_batches={"x": jnp.asarray(X), "y": jnp.asarray(Y),
                        "mask": jnp.asarray(mask)},
        num_samples=counts,
        num_logical=alloc.per_grade[0].logical_devices,
        rng=jax.random.PRNGKey(rnd), benchmark_devices=1,
    )
    acc = float(ctr.accuracy(svc.global_params,
                             jnp.asarray(test.features),
                             jnp.asarray(test.labels)))
    last_arrival = float(np.max(outcome.arrival_times))
    print(f"round {rnd}: aggregations={len(svc.history)} test_acc={acc:.4f} "
          f"round_end_t={last_arrival:.1f}s")

if sim.device.reports:
    print("benchmark-device report:",
          f"{sim.device.reports[0].total_power_mah:.2f} mAh,"
          f" {sim.device.reports[0].total_duration_min:.2f} min")
else:
    print("(allocation placed every device on the logical tier; "
          "no physical benchmarking ran)")
