"""Quickstart: the SimDC platform in ~70 lines.

Simulates a small two-grade federated CTR task end-to-end: fleet-calibrated
runtimes (no hand-coded constants) drive the hybrid allocator, a ``RoundPlan``
maps each grade's split onto its own logical/device cohorts, the per-grade
fleet-sampled Table-I round durations become per-message arrival times through
DeviceFlow, and the cloud aggregates with FedAvg while tracking real queuing
latency.

**Zero-copy rounds (default).**  Model updates are device-resident end to
end: each cohort chunk's stacked output stays on device as an
``UpdateBuffer`` and every ``Message.payload`` is a lightweight
``UpdateHandle`` (buffer ref + row).  The aggregation below never builds a
per-device host pytree — ``AggregationService`` detects handle payloads and
runs one fused ``fed_reduce`` weighted reduction per buffer (a Pallas kernel
on TPU).  Host materialization still happens in exactly three places: the
q_i benchmarking devices (their updates accompany the ``RoundReport``
telemetry printed at the end), checkpoint saves (``Checkpointer``
materializes handles), and host-side payload transforms like top-k
compression.  Pass ``HybridSimulation(..., zero_copy=False)`` to get the old
host-materializing path, and ``AggregationService(...,
donate_params=True)`` to recycle the global-params buffer between rounds
(skip it if you read ``history[i].global_params`` later — donation
invalidates the previous round's copy).

**Multi-task scheduling (PR 4).**  Section 6 shows the event-driven
``TaskEngine``: two contending tasks time-share one resource pool, their
round events interleaving on the shared ``VirtualClock`` with elastic
re-allocation when resources free up — instead of the serial
run-to-completion drain.

**Preemptive priority scheduling (PR 5).**  Section 7 adds reclamation: a
high-priority arrival refreezes lower-priority grants *down* at their next
round-event boundary (pausing a victim to the queue when clamped to zero),
and ``monte_carlo_schedules`` replays the contention over sampled timelines
to compare preemptive vs non-preemptive queueing-delay and makespan
distributions.

**Columnar message plane (PR 6).**  Section 8 shows the struct-of-arrays
arrival path: a cohort chunk travels as ONE ``ArrivalBatch`` (int32 row
indices + created_t/nbytes columns + one shared ``UpdateBuffer`` ref)
instead of per-device ``Message`` objects, so per-arrival Python cost is
O(1/chunk).  The scalar ``Message`` API stays available as a thin
compatibility adapter — ``batch.message(i)`` / ``batch.messages()``
materialize per-row views on demand, and ``submit_arrivals`` accepts both
planes mixed with identical dispatch semantics.

**Quantized wire format (PR 7).**  Section 9 shows ``wire="int8"``: each
cohort chunk quantizes inside the cohort jit (symmetric per-row int8 + one
f32 scale column per leaf), the ``UpdateBuffer`` stores the int8 leaves so
every byte counter reports the real ~4x-smaller wire footprint, and
aggregation folds the scales into the fed_reduce weight vector
(dequantize-and-reduce — no dense f32 stack is ever built).  Device-resident
error-feedback residuals (``error_feedback=True``, the default) carry the
quantization error into the next round, keeping the trajectory glued to the
f32 run.  The same knobs ride the training driver:
``python -m repro.launch.train --mode federated --wire-format int8
[--error-feedback off]``.

**Invariants & sanitizers (PR 10).**  Section 12 catalogs the platform's
load-bearing footguns (2-D buffer leaves, ``keep_unused`` donation, shm
segment lifetime, virtual-clock-only timing) with their simcheck rule IDs,
and shows the two enforcement layers: the AST linter
(``python -m repro.analysis.lint src tests``) and the ``SIMDC_SANITIZE=1``
runtime sanitizers (transfer-guarded hot paths, use-after-donate poisoning,
segment-leak audit, clock monotonicity).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AccumulatedStrategy, AggregationService, DeviceFlow, GradeSpec,
    OperatorFlow, ResourceManager, ResourcePool, RoundPlan,
    RuntimeCalibrator, SampleThresholdTrigger, Task, TaskEngine,
    solve_allocation,
)
from repro.core.devicemodel import GRADES, DeviceFleet
from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier
from repro.data.synthetic_ctr import make_federated_ctr
from repro.models import ctr

N_HIGH, N_LOW, RECORDS, DIM, ROUNDS = 16, 8, 16, 64, 4
specs = [
    GradeSpec("High", N_HIGH, benchmarking_devices=1, logical_bundles=8,
              bundles_per_device=4, physical_devices=8),
    GradeSpec("Low", N_LOW, benchmarking_devices=1, logical_bundles=16,
              bundles_per_device=2, physical_devices=2),
]

# 1. Calibrate per-grade runtimes from measured fleet rounds (paper §IV.C):
#    no hand-coded GradeRuntime constants — the allocator runs on data.
cal = RuntimeCalibrator()
for g in ("High", "Low"):
    probe = DeviceFleet(GRADES[g], 64, seed=7)  # pre-measurement fleet
    for r in range(3):
        cal.observe_fleet(probe.run_round(r))

# 2. Hybrid allocation (paper Eq. 1): per-grade logical/physical split.
alloc = solve_allocation(specs, cal.runtimes_for(specs))
plan = RoundPlan.from_allocation(alloc, specs)
for e in plan.entries:
    print(f"allocation[{e.grade}]: {e.num_logical} logical / "
          f"{e.num_physical} physical / {e.num_benchmarking} benchmarking")
print(f"estimated makespan {alloc.makespan:.1f}s")

# 3. Data + client-local training operator (shared across grades).
local_train = ctr.make_local_train_fn(lr=1e-3, epochs=10)
params = ctr.lr_init(jax.random.PRNGKey(0), DIM)
grade_batches, grade_counts = {}, {}
for i, spec in enumerate(specs):
    data = make_federated_ctr(num_devices=spec.num_devices,
                              records_per_device=RECORDS, dim=DIM, seed=i)
    X, Y, counts = data.stacked_shards(np.arange(spec.num_devices), RECORDS)
    mask = (np.arange(RECORDS)[None] < counts[:, None]).astype(np.float32)
    grade_batches[spec.grade] = {"x": jnp.asarray(X), "y": jnp.asarray(Y),
                                 "mask": jnp.asarray(mask)}
    grade_counts[spec.grade] = counts

# 4. Cloud service behind DeviceFlow (real-time dispatch here).
svc = AggregationService(
    params, trigger=SampleThresholdTrigger((N_HIGH + N_LOW) * RECORDS // 2))
flow = DeviceFlow(svc)
flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))

# 5. Grade-partitioned hybrid rounds: one DeviceTier+fleet per grade; every
#    round's fleet samples feed the calibrator, re-measuring the runtimes.
sim = HybridSimulation(
    LogicalTier(local_train, cohort_size=16),
    tiers={g: DeviceTier(local_train, GRADES[g]) for g in ("High", "Low")},
    deviceflow=flow)
test = make_federated_ctr(num_devices=64, dim=DIM, seed=9)

for rnd in range(ROUNDS):
    outcome = sim.run_plan_round(
        task_id=0, round_idx=rnd, global_params=svc.global_params,
        plan=plan, grade_batches=grade_batches,
        grade_num_samples=grade_counts, rng=jax.random.PRNGKey(rnd),
        calibrator=cal)
    acc = float(ctr.accuracy(svc.global_params,
                             jnp.asarray(test.features),
                             jnp.asarray(test.labels)))
    per_grade = " ".join(f"{g}={b.makespan_s:.0f}s"
                         for g, b in outcome.per_grade.items())
    print(f"round {rnd}: aggregations={len(svc.history)} test_acc={acc:.4f} "
          f"makespan[{per_grade}] round_end_t={outcome.makespan_s:.1f}s")

# Handle payloads report real model-update sizes, so DeviceFlow traffic
# accounting reflects the bytes physical devices would have uploaded.
shelf = flow.shelf(0)
print(f"deviceflow traffic: {shelf.total_bytes_dispatched / 1024:.1f} KiB "
      f"dispatched across {shelf.total_dispatched} update messages")

rts = cal.runtimes_for(specs)
print("re-measured runtimes:",
      "; ".join(f"{s.grade}: alpha={r.alpha:.1f} beta={r.beta:.1f} "
                f"lam={r.lam:.1f}" for s, r in zip(specs, rts)))
for rep in sim.tiers["High"].reports[:1]:
    print(f"benchmark-device report ({rep.grade}): "
          f"{rep.total_power_mah:.2f} mAh, {rep.total_duration_min:.2f} min")

# 6. Event-driven multi-task scheduling: two contending tasks time-share ONE
#    pool.  Task A freezes its full demand; task B is admitted *elastically*
#    on what is left, and when A finishes the engine re-solves B's
#    allocation with the freed resources (elastic re-allocation).  Rounds
#    interleave as events on the shared VirtualClock — the makespan is far
#    below the serial back-to-back drain.
rm = ResourceManager(ResourcePool({"High": 12}, {"High": 4}))
make_task = lambda prio: Task(
    OperatorFlow(("train",)),
    (GradeSpec("High", 24, logical_bundles=8, bundles_per_device=1,
               physical_devices=3),),
    rounds=3, priority=prio)
task_a, task_b = make_task(1), make_task(0)
engine = TaskEngine(rm, cal, elastic=True)  # calibrated runtimes time events
engine.submit(task_a)
engine.submit(task_b)
engine.run_until()
serial_s = sum(ex.task.rounds * ex.allocation.makespan
               for ex in engine.completed)
for ex in engine.completed:
    print(f"task {ex.task.task_id}: start={ex.started_t:.0f}s "
          f"finish={ex.finished_t:.0f}s rounds={ex.rounds_done} "
          f"elastic-reallocations={ex.reallocations}")
print(f"interleaved makespan {engine.makespan:.0f}s "
      f"(serial drain would take ~{serial_s:.0f}s)")

# 7. Preemptive priority scheduling (PR 5): two low-priority tasks freeze
#    the WHOLE pool; a high-priority task arrives mid-round-0.  Without
#    preemption it waits for a full task completion.  With
#    ``preemptive=True`` the engine refreezes a victim's grant down at its
#    next round-event boundary (here: to zero — the victim is PAUSED back
#    to the queue with its round progress kept, and resumes later), so the
#    urgent task starts a whole task-duration earlier.  Queueing delay and
#    grant utilization quantify what each side pays.
def contended(preemptive):
    rm = ResourceManager(ResourcePool({"High": 16}, {"High": 6}))
    eng = TaskEngine(rm, cal, elastic=True, preemptive=preemptive)
    low = [make_task(0), make_task(0)]  # together they fill the pool
    urgent = make_task(9)
    for t in low:
        eng.submit(t)
    eng.submit(urgent, at=60.0)  # arrives while both run their round 0
    eng.run_until()
    return eng, urgent

for preemptive in (False, True):
    eng7, urgent = contended(preemptive)
    ex = eng7.executions[urgent.task_id]
    mode = "preemptive" if preemptive else "non-preemptive"
    victims = [e for e in eng7.completed if e.task.task_id != urgent.task_id]
    print(f"{mode}: urgent task queued {ex.queueing_delay_s:.0f}s, "
          f"victim preemptions={sum(e.preemptions for e in victims)}, "
          f"victim grant-utilization="
          f"{min(e.grant_utilization for e in victims):.2f}")

# Monte-Carlo makespan estimation: the same contention replayed over N
# sampled timelines (round durations drawn from the calibrator's measured
# observations, not their mean) — the distributional case for preemption.
from repro.core import monte_carlo_schedules
low_mc = [make_task(0), make_task(0)]
urgent_mc = make_task(9)
mc = monte_carlo_schedules(
    low_mc + [urgent_mc], ResourcePool({"High": 16}, {"High": 6}), cal,
    arrivals={urgent_mc.task_id: 60.0}, n_samples=24, seed=0)
for preemptive, est in mc.items():
    mode = "preemptive" if preemptive else "non-preemptive"
    print(f"monte-carlo {mode}: mean makespan {est.mean_makespan_s:.0f}s "
          f"(p95 {est.p95_makespan_s:.0f}s), urgent mean queue-delay "
          f"{est.mean_queueing_delay_s(urgent_mc.task_id):.0f}s")

# 8. Columnar message plane (PR 6): a whole cohort chunk is ONE
#    struct-of-arrays ``ArrivalBatch`` — row indices into a shared
#    device-resident ``UpdateBuffer`` plus created_t/nbytes columns — so
#    the Sorter/Shelf/Dispatcher path does O(chunks) Python work instead of
#    O(devices).  ``HybridSimulation`` emits batches by default
#    (``columnar=True``); below we drive the plane directly.  The scalar
#    ``Message`` API remains the compatibility adapter: ``batch.message(i)``
#    materializes a per-row view, and both planes mix freely in
#    ``submit_arrivals`` with identical dispatch timestamps.
from repro.core.deviceflow import ArrivalBatch
from repro.core.federation import ClientCountTrigger
from repro.core.updates import UpdateBuffer

CHUNK, N_DEV = 256, 1024
svc8 = AggregationService({"w": jnp.zeros(DIM)},
                          trigger=ClientCountTrigger(N_DEV))
flow8 = DeviceFlow(svc8)
flow8.register_task(0, AccumulatedStrategy(thresholds=(N_DEV,)))
for lo in range(0, N_DEV, CHUNK):
    stacked = {"w": 1e-3 * jnp.arange(CHUNK * DIM, dtype=jnp.float32
                                      ).reshape(CHUNK, DIM)}
    chunk_buf = UpdateBuffer.from_stacked(stacked)
    flow8.submit_batch(
        ArrivalBatch.from_buffer(0, 0, chunk_buf,
                                 device_ids=np.arange(lo, lo + CHUNK)),
        ts=np.linspace(lo / N_DEV, (lo + CHUNK) / N_DEV, CHUNK))
shelf8 = flow8.shelf(0)
print(f"columnar plane: {N_DEV} device-messages in {N_DEV // CHUNK} batches "
      f"-> aggregations={len(svc8.history)} "
      f"bytes={shelf8.total_bytes_dispatched // 1024} KiB "
      f"conservation_ok={flow8.conservation_ok(0)}; "
      f"scalar adapter view: "
      f"{ArrivalBatch.from_buffer(0, 0, chunk_buf).message(0).device_id=}")

# 9. Quantized wire format (PR 7): the SAME federated rounds as section 5,
#    but every cohort chunk ships int8.  ``HybridSimulation(wire="int8")``
#    fuses symmetric per-row quantization into the cohort jit, the chunk's
#    ``UpdateBuffer`` stores int8 leaves + one f32 scale column per leaf
#    (``row_nbytes`` reports the true quantized footprint), and the fused
#    aggregation folds the scales into the fed_reduce weight vector —
#    ``weights[i]*scale[i]`` — so the int8 stack is reduced directly
#    without ever materializing a dense f32 copy.  Error feedback (on by
#    default) carries each device's quantization residual into its next
#    round, which is why the loss below tracks the f32 run of section 5.
#    Compare the byte counters: ~4x fewer wire bytes per round.
svc9 = AggregationService(
    ctr.lr_init(jax.random.PRNGKey(0), DIM),
    trigger=SampleThresholdTrigger((N_HIGH + N_LOW) * RECORDS // 2))
flow9 = DeviceFlow(svc9)
flow9.register_task(0, AccumulatedStrategy(thresholds=(1,)))
sim9 = HybridSimulation(
    LogicalTier(local_train, cohort_size=16),
    tiers={g: DeviceTier(local_train, GRADES[g]) for g in ("High", "Low")},
    deviceflow=flow9, wire="int8", error_feedback=True)
for rnd in range(ROUNDS):
    sim9.run_plan_round(
        task_id=0, round_idx=rnd, global_params=svc9.global_params,
        plan=plan, grade_batches=grade_batches,
        grade_num_samples=grade_counts, rng=jax.random.PRNGKey(rnd),
        calibrator=cal)
    flow9.run(1e12)
    svc9.tick(flow9.clock.now)
acc9 = float(ctr.accuracy(svc9.global_params,
                          jnp.asarray(test.features),
                          jnp.asarray(test.labels)))
shelf9 = flow9.shelf(0)
print(f"quantized wire: test_acc={acc9:.4f} (f32 run above: {acc:.4f}) "
      f"bytes={shelf9.total_bytes_dispatched / 1024:.1f} KiB vs "
      f"{shelf.total_bytes_dispatched / 1024:.1f} KiB f32 "
      f"({shelf.total_bytes_dispatched / shelf9.total_bytes_dispatched:.1f}x "
      f"cut, {len(svc9.history)} aggregations)")

# 10. Continuous-batching serving under diurnal traffic (PR 8): the same
#     DeviceFlow clock now drives LM *inference*.  A diurnal arrival curve
#     shapes when requests reach the cloud; the fixed-batch baseline makes
#     every request wait for batch-mates (and for the whole batch to decode),
#     while ``ContinuousBatchingEngine`` keeps a fixed KV-cache arena —
#     requests prefill into free slots at iteration boundaries, every active
#     slot decodes one token per fused jitted step at its own ragged cache
#     length, and finished slots retire immediately.  Both modes charge
#     virtual service time from one ``ServeCostModel`` and decode
#     token-identical outputs, so the p50/p99/TTFT gap below is purely the
#     batching policy.
from repro.configs.registry import get_config
from repro.core import (
    ContinuousBatchingEngine, ContinuousServer, ServeCostModel, VirtualClock,
    diurnal,
)
from repro.launch.serve import BatchedServer, run_trace

cfg10 = get_config("llama3_2_3b", smoke=True)
serve_kw = dict(prompt_len=8, decode_tokens=4, max_len=13, seed=0,
                cost_model=ServeCostModel())
trace10 = dict(requests=24, prompt_len=8, vocab_size=cfg10.vocab_size,
               curve=diurnal(), interval=60.0, seed=0)
fixed10 = BatchedServer(cfg10, batch_size=4, **serve_kw)
run_trace(fixed10, **trace10)
rep_fixed = fixed10.report()
eng10 = ContinuousBatchingEngine(cfg10, slots=4, **serve_kw)
clock10 = VirtualClock()
run_trace(ContinuousServer(eng10, clock10), clock=clock10, **trace10)
rep_cont = eng10.report()
occ10 = max(it.n_active for it in eng10.iterations)
same_tokens = ({r.request_id: r.tokens for r in rep_fixed.records}
               == {r.request_id: r.tokens for r in rep_cont.records})
print(f"serving: fixed p50={rep_fixed.p50_latency_s * 1e3:.1f}ms "
      f"p99={rep_fixed.p99_latency_s * 1e3:.1f}ms | continuous "
      f"p50={rep_cont.p50_latency_s * 1e3:.1f}ms "
      f"p99={rep_cont.p99_latency_s * 1e3:.1f}ms "
      f"(p99 cut {rep_fixed.p99_latency_s / rep_cont.p99_latency_s:.0f}x, "
      f"peak occupancy {occ10}/{eng10.slots}, "
      f"token_identical={same_tokens})")

# 11. Multi-process fleet execution (PR 9): ``launch/train.py --workers N``
#     shards cohort execution across N spawned worker processes, each running
#     its own jitted cohort loop, while the coordinator keeps the TaskEngine,
#     DeviceFlow, and AggregationService on the authoritative VirtualClock:
#
#         python -m repro.launch.train --mode federated --workers 4
#         python -m repro.launch.train --mode federated --workers 4 \
#             --wire-format int8        # quantized transport, still bit-exact
#
#     Chunk results ship back as the SAME struct-of-arrays ArrivalBatch
#     records as in-process rounds — the UpdateBuffer leaves travel through
#     multiprocessing.shared_memory segments (a recycled ring, mirroring the
#     zero-copy donation discipline) with only a slim (rows, created_t,
#     nbytes, shm_name) header on the pipe, so rounds are bit-identical to
#     single-process execution and Shelf byte accounting stays exact.
#
#     Shared-memory lifetime rules:
#       * A pooled round's UpdateBuffer leaves are *views* into a worker's
#         segment.  They stay valid while the buffer object is alive; when
#         the coordinator drops its last reference (post-aggregation), GC
#         returns the segment to the worker's free ring for the next round.
#       * Copy before caching: anything that outlives the round (checkpoint
#         snapshots, history) must own its arrays — ``materialize()`` /
#         ``state_dict()`` already copy, so the standard paths are safe.
#       * ``HybridSimulation.close()`` (or the context-manager form) stops
#         the pool and unlinks every segment; workers are daemonic, so a
#         crashed coordinator never leaks processes.
#
#     When workers beat threads: client training is jit-compiled Python —
#     threads serialize on the GIL between dispatches and share one compile
#     cache lock, while processes give each shard its own interpreter AND
#     its own XLA thread pool.  Expect ~linear scale-up in device-messages/s
#     up to the physical core count (see ``benchmarks.run workers_round``);
#     on a 1-2 core host the spawn+compile overhead dominates and in-process
#     rounds win.  Worker death mid-round is survivable: the coordinator
#     re-dispatches the lost chunks to survivors (runtime.fault_tolerance).
#
#     Both sides compute the segment layout independently from the update
#     spec — headers never carry shapes/dtypes.  The layout below is one
#     8-row int8-wire chunk of a {w: (16,), b: ()} model: two int8 wire
#     matrices, then one f32 scale column per leaf, each 64-byte aligned.
from repro.runtime.workers import segment_layout

layout11, nbytes11 = segment_layout([(16,), ()], ["float32", "float32"],
                                    rows=8, wire="int8")
print("worker transport segment:", nbytes11, "bytes:",
      [(off, shape, str(dt)) for off, shape, dt in layout11])

# 12. Invariants & sanitizers (PR 10): the platform's performance story
#     rests on a handful of easy-to-break invariants.  ``repro.analysis``
#     enforces them twice — statically (an AST linter, rules R001-R006) and
#     dynamically (opt-in runtime sanitizers):
#
#         PYTHONPATH=src python -m repro.analysis.lint src tests   # static
#         SIMDC_SANITIZE=1 python -m pytest -q                     # runtime
#         python -m pytest -q --sanitize                           # same
#
#     The catalog of footguns, each with its rule ID:
#       * R001 — ``jax.jit(..., donate_argnums=...)`` WITHOUT
#         ``keep_unused=True``: if the traced fn never reads a donated arg,
#         XLA drops it from the signature and the donation silently no-ops —
#         the zero-copy recycle path degrades to a fresh allocation per
#         round with no error anywhere.
#       * R002 — wall-clock reads (``time.time`` etc.) in simulation-domain
#         (``core/``) modules: simulated components must stamp time from the
#         ``VirtualClock`` (``MetricsBus.on_virtual_clock``) or replays stop
#         being deterministic.
#       * R003 — host syncs (``int()``/``.item()``/``np.asarray``) inside
#         ``@hot_path`` functions: one stray sync in the decode loop
#         serializes the whole dispatch stream.
#       * R004 — ``state_dict``/``load_state_dict`` key asymmetry: a written
#         key the reader ignores is state that silently fails to restore.
#       * R005 — shared-memory segments without a close/unlink/finalize
#         path (or ``resource_tracker.unregister`` calls): segments outlive
#         their creators and leak in /dev/shm.
#       * R006 — 3-D+ reshapes on reduction operands inside cohort jits:
#         aggregation operands must stay (rows, size) 2-D to lower to one
#         BLAS/MXU matmul (~40x on CPU XLA).
#
#     With ``SIMDC_SANITIZE=1`` the runtime half arms itself: the decode
#     loop, zero-copy round pipeline and fused aggregation dispatch run
#     under ``jax.transfer_guard("disallow")`` (implicit host<->device
#     transfers raise at the offending op), donated ``UpdateBuffer``s are
#     poisoned so use-after-donate raises ``UseAfterDonateError`` instead of
#     failing deep in XLA, ``FleetWorkerPool.close()`` audits for pinned
#     segments, and ``VirtualClock.schedule`` rejects events in the virtual
#     past.  All of it is a single truthiness check per call when disabled.
import pathlib

from repro.analysis import lint, sanitizers

findings12 = lint.lint_paths(
    [pathlib.Path(__file__).resolve().parents[1] / "src"])
print(f"simcheck lint over src/: {len(findings12)} finding(s)")
buf12 = UpdateBuffer.from_stacked({"w": jnp.ones((4, DIM))})
with sanitizers.override(True):
    sanitizers.poison_donated(buf12)
    try:
        buf12.leaves2d
    except sanitizers.UseAfterDonateError:
        print("use-after-donate fenced: donated buffer access raises "
              "UseAfterDonateError instead of a deep XLA error")
