"""Federated LM training: the cloud model is one of the assigned
architectures (reduced config); client updates flow through DeviceFlow with
top-k+error-feedback compression — the LM-scale SimDC loop.

Run:  PYTHONPATH=src python examples/lm_federation.py [--arch llama3_2_3b]
"""
import sys

from repro.launch.train import main

sys.exit(main([
    "--mode", "federated",
    "--arch", sys.argv[sys.argv.index("--arch") + 1]
    if "--arch" in sys.argv else "llama3_2_3b",
    "--rounds", "5", "--clients-per-round", "8",
    "--traffic", "curve", "--sigma", "1.0",
    "--compress", "--compress-fraction", "0.05",
]))
