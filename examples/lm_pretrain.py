"""Cloud-side LM pretraining driver (smoke scale): a ~10M-param llama-family
model trained for a few hundred steps with checkpoint/restart — the
datacenter end of the device-cloud platform.

Run:  PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]
"""
import sys

from repro.launch.train import main

steps = sys.argv[sys.argv.index("--steps") + 1] if "--steps" in sys.argv else "200"
sys.exit(main([
    "--mode", "cloud", "--arch", "llama3_2_3b", "--smoke",
    "--steps", steps, "--checkpoint-every", "50",
    "--checkpoint-dir", "artifacts/ckpt_example", "--log-every", "10",
]))
