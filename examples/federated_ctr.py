"""The paper's own experiment (§VI): federated LR-on-CTR with DeviceFlow
traffic curves, aggregation triggers, and dropout — at up to 100k devices.

Run:  PYTHONPATH=src python examples/federated_ctr.py [--devices 2000]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AggregationService, DeviceFlow, Message,
                        SampleThresholdTrigger, TimeIntervalStrategy)
from repro.core.traffic_curves import right_tailed_normal
from repro.data.synthetic_ctr import make_federated_ctr
from repro.models import ctr

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=2000)
ap.add_argument("--rounds", type=int, default=4)
ap.add_argument("--sigma", type=float, default=1.0)
ap.add_argument("--dropout", type=float, default=0.0)
args = ap.parse_args()

DIM, RECORDS = 64, 16
data = make_federated_ctr(num_devices=args.devices, records_per_device=RECORDS,
                          dim=DIM, seed=0, noniid_alpha=0.5)
test = make_federated_ctr(num_devices=200, dim=DIM, seed=1)
local = jax.jit(jax.vmap(ctr.make_local_train_fn(lr=1e-3, epochs=10)))

params = ctr.lr_init(jax.random.PRNGKey(0), DIM)
svc = AggregationService(
    params, trigger=SampleThresholdTrigger(args.devices * RECORDS // 2))
flow = DeviceFlow(svc, seed=0)
flow.register_task(0, TimeIntervalStrategy(
    curve=right_tailed_normal(args.sigma), interval=1200.0,
    failure_prob=args.dropout))

X, Y, counts = data.stacked_shards(np.arange(args.devices), RECORDS)
mask = (np.arange(RECORDS)[None] < counts[:, None]).astype(np.float32)

for rnd in range(args.rounds):
    stacked = jax.tree.map(
        lambda p: jnp.broadcast_to(p, (args.devices,) + p.shape),
        svc.global_params)
    keys = jax.random.split(jax.random.PRNGKey(rnd), args.devices)
    new_params, metrics = local(
        stacked, {"x": jnp.asarray(X), "y": jnp.asarray(Y),
                  "mask": jnp.asarray(mask)}, keys)
    host = jax.device_get(new_params)
    for c in range(args.devices):
        flow.submit(Message(0, c, rnd, jax.tree.map(lambda x: x[c], host),
                            num_samples=int(counts[c])))
    flow.round_complete(0)
    flow.run(flow.clock.now + 1200.0)
    acc = float(ctr.accuracy(svc.global_params, jnp.asarray(test.features),
                             jnp.asarray(test.labels)))
    print(f"round {rnd}: virtual_t={flow.clock.now:8.1f}s "
          f"aggregations={len(svc.history)} dropped="
          f"{flow.shelf(0).total_dropped} test_acc={acc:.4f}", flush=True)
