import os
import sys

# Tests run on the real single CPU device (the dry-run sets its own 512-device
# flag in a separate process; never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

try:  # real hypothesis when installed (CI: pip install -e ".[test]")
    import hypothesis  # noqa: F401
except ImportError:  # hermetic containers: seeded-random fallback
    import _hypothesis_stub

    _hypothesis_stub.install()
