import os
import sys

# Tests run on the real single CPU device (the dry-run sets its own 512-device
# flag in a separate process; never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="enable the simcheck runtime sanitizers (sets SIMDC_SANITIZE=1 "
             "before any repro module imports jax)")


def pytest_configure(config):
    if config.getoption("--sanitize"):
        os.environ["SIMDC_SANITIZE"] = "1"


try:  # real hypothesis when installed (CI: pip install -e ".[test]")
    import hypothesis  # noqa: F401
except ImportError:  # hermetic containers: seeded-random fallback
    import _hypothesis_stub

    _hypothesis_stub.install()
