"""R003 fixture: host syncs inside a @hot_path function."""
import numpy as np

from repro.analysis.sanitizers import hot_path


@hot_path
def decode_loop(tok):
    val = int(tok[0])
    host = np.asarray(tok)
    return val, host
