"""R001 fixture: donated jit without keep_unused — donation can no-op."""
import jax

step = jax.jit(lambda state, batch: state, donate_argnums=(0,))
