"""R004 fixture: state_dict writes a key restore never consumes."""


class Engine:
    def state_dict(self):
        return {"step": self.step, "rng": self.rng}

    def load_state_dict(self, d):
        self.step = d["step"]
