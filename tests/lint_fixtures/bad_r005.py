"""R005 fixture: leaked shared-memory segment + resource_tracker bypass."""
from multiprocessing import resource_tracker, shared_memory


def leak_segment(nbytes):
    shm = shared_memory.SharedMemory(create=True, size=nbytes)
    resource_tracker.unregister(shm._name, "shared_memory")
    return shm.buf
