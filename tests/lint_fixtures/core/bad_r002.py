"""R002 fixture: wall-clock in a simulation-domain (core/) module."""
import time


def emit_now():
    return time.time()
