"""R006 fixture: 3-D reshape on a reduction operand inside a cohort jit."""
import jax
import jax.numpy as jnp


def cohort_reduce(stack, weights):
    operands = stack.reshape(4, 8, -1)
    return jnp.tensordot(weights, operands, axes=1)


cohort_reduce_jit = jax.jit(cohort_reduce)
