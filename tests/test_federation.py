"""Federation service, triggers, scheduler, device models, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.deviceflow import Delivery, Message
from repro.core.devicemodel import GRADES, DeviceModel, Stage
from repro.core.federation import (
    AggregationService,
    ClientCountTrigger,
    SampleThresholdTrigger,
    ScheduledTrigger,
    polynomial_staleness,
    weighted_average,
)
from repro.core.scheduler import (
    ResourceManager,
    ResourcePool,
    TaskManager,
    TaskRunner,
)
from repro.core.task import GradeSpec, OperatorFlow, Task, TaskQueue
from repro.core.allocation import GradeRuntime
from repro.optim.compression import (
    int8_dequantize,
    int8_quantize,
    payload_bytes,
    topk_compress,
    topk_init,
)


def test_weighted_average_exact():
    a = {"w": jnp.array([1.0, 2.0])}
    b = {"w": jnp.array([3.0, 6.0])}
    avg = weighted_average([a, b], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(avg["w"]), [2.5, 5.0])


def test_sample_threshold_trigger_fires():
    svc = AggregationService({"w": jnp.zeros(2)},
                             trigger=SampleThresholdTrigger(10))
    for i in range(4):
        svc(Delivery(t=float(i), message=Message(
            0, i, 0, {"w": jnp.ones(2) * i}, num_samples=3)))
    assert len(svc.history) == 1  # fired at 12 >= 10
    assert svc.pending_clients == 0


def test_scheduled_trigger_fires_on_tick():
    svc = AggregationService({"w": jnp.zeros(2)},
                             trigger=ScheduledTrigger(period=10.0))
    svc(Delivery(t=1.0, message=Message(0, 0, 0, {"w": jnp.ones(2)},
                                        num_samples=1)))
    svc.tick(5.0)
    assert len(svc.history) == 0
    svc.tick(10.5)
    assert len(svc.history) == 1


def test_scheduled_trigger_stays_on_grid():
    """Satellite fix: firing re-anchors to the grid point, not the tick's
    arrival time — late ticks must not drift the whole schedule."""
    trig = ScheduledTrigger(period=10.0)
    svc = AggregationService({"w": jnp.zeros(2)}, trigger=trig)
    msg = Message(0, 0, 0, {"w": jnp.ones(2)}, num_samples=1)
    svc(Delivery(t=1.0, message=msg))
    svc.tick(10.5)  # late tick: fires, but the grid stays at 10.0
    assert len(svc.history) == 1
    assert trig._last == pytest.approx(10.0)
    svc(Delivery(t=12.0, message=msg))
    # Old behavior re-anchored to 10.5 and needed t >= 20.5; the fixed grid
    # fires at the scheduled time 20.0.
    svc.tick(20.0)
    assert len(svc.history) == 2
    assert trig._last == pytest.approx(20.0)
    svc(Delivery(t=21.0, message=msg))
    svc.tick(57.3)  # several periods skipped: snap forward on the grid
    assert len(svc.history) == 3
    assert trig._last == pytest.approx(50.0)


def test_aggregate_survives_all_zero_weights():
    """Satellite fix: an aggressive staleness discount zeroing every pending
    weight falls back to uniform weights instead of raising mid-delivery."""
    svc = AggregationService(
        {"w": jnp.zeros(1)},
        trigger=ClientCountTrigger(2),
        staleness_discount=lambda s: 0.0,
    )
    svc(Delivery(t=0, message=Message(0, 0, 0, {"w": jnp.array([2.0])},
                                      num_samples=1)))
    svc(Delivery(t=0, message=Message(0, 1, 0, {"w": jnp.array([4.0])},
                                      num_samples=3)))
    assert len(svc.history) == 1  # did not crash the delivery callback
    np.testing.assert_allclose(np.asarray(svc.global_params["w"]), [3.0])


def test_staleness_discount_downweights():
    svc = AggregationService(
        {"w": jnp.zeros(1)},
        trigger=ClientCountTrigger(2),
        staleness_discount=polynomial_staleness(1.0),
    )
    svc.round_idx = 2
    svc(Delivery(t=0, message=Message(0, 0, round_idx=2,
                                      payload={"w": jnp.array([10.0])},
                                      num_samples=1)))
    svc(Delivery(t=0, message=Message(0, 1, round_idx=0,
                                      payload={"w": jnp.array([20.0])},
                                      num_samples=1)))
    # weights: fresh 1.0, stale (1+2)^-1 = 1/3 -> avg = (10 + 20/3)/(4/3) = 12.5
    np.testing.assert_allclose(np.asarray(svc.global_params["w"]), [12.5])


def test_scheduler_admits_by_priority_and_resources():
    pool = ResourcePool({"High": 100}, {"High": 10})
    rm = ResourceManager(pool)
    runner = TaskRunner(
        rm,
        runtimes=lambda t: [GradeRuntime(1.0, 1.0, 0.1)] * len(t.grades),
        tier_runners={"logical": lambda *a: [], "device": lambda *a: []},
    )
    tm = TaskManager(rm, runner)
    flow = OperatorFlow(("train",))
    big = Task(flow, (GradeSpec("High", 10, logical_bundles=80,
                                physical_devices=8),), priority=1)
    small = Task(flow, (GradeSpec("High", 5, logical_bundles=30,
                                  physical_devices=3),), priority=5)
    tm.submit(big)
    tm.submit(small)
    done = tm.drain()
    # Priority 5 task runs first; both eventually complete (release frees pool).
    assert [d.task.priority for d in done] == [5, 1]
    assert all(d.state.value == "completed" for d in done)
    assert rm.free().logical_bundles["High"] == 100


def test_resource_manager_freeze_release_and_elastic():
    rm = ResourceManager(ResourcePool({"High": 10}, {"High": 2}))
    rm.freeze(1, {"High": (4, 1)})
    assert not rm.fits({"High": (7, 0)})
    rm.release(1)
    assert rm.fits({"High": (10, 2)})
    rm.scale("High", bundles_delta=-5)
    assert not rm.fits({"High": (6, 0)})
    with pytest.raises(ValueError):
        rm.scale("High", phones_delta=-3)


def test_device_model_table1_ordering():
    hi = DeviceModel(0, GRADES["High"], seed=0).run_round(0)
    lo = DeviceModel(0, GRADES["Low"], seed=0).run_round(0)
    assert hi.total_power_mah < lo.total_power_mah
    assert (hi.stage_duration_min[Stage.TRAINING]
            < lo.stage_duration_min[Stage.TRAINING])
    assert hi.comm_kb > 0


def test_device_model_telemetry_stream():
    model = DeviceModel(3, GRADES["High"], seed=1)
    rep = model.run_round(0)
    samples = list(model.telemetry(rep, hz=1.0))
    assert len(samples) > 10
    assert all(s.voltage_mv > 3000 for s in samples)
    stages = {s.stage for s in samples}
    assert stages == set(Stage)


@settings(max_examples=25, deadline=None)
@given(frac=st.floats(0.01, 0.5), seed=st.integers(0, 1000))
def test_topk_compression_error_feedback_roundtrip(frac, seed):
    rng = np.random.default_rng(seed)
    update = {"a": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    state = topk_init(update)
    kept, state, stats = topk_compress(update, state, fraction=frac)
    # Error feedback invariant: kept + residual == update (exactly).
    for k in update:
        np.testing.assert_allclose(
            np.asarray(kept[k]) + np.asarray(state.residual[k]),
            np.asarray(update[k]), atol=1e-6)
    assert stats["compression_ratio"] >= 1.0


def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    u = {"w": jnp.asarray(rng.standard_normal((128,)) * 3, jnp.float32)}
    q, s = int8_quantize(u)
    back = int8_dequantize(q, s, u)
    scale = float(np.abs(np.asarray(u["w"])).max()) / 127
    assert float(jnp.abs(back["w"] - u["w"]).max()) <= scale * 0.5 + 1e-6
    assert payload_bytes(q) == 128  # int8


# --------------------------------------------------------------------------- #
# Columnar batch intake: ArrivalBatch deliveries into the fused aggregation
# --------------------------------------------------------------------------- #
from repro.core.deviceflow import ArrivalBatch  # noqa: E402
from repro.core.updates import UpdateBuffer  # noqa: E402


def _update_buffer(n, dim=4, seed=0):
    rng = np.random.default_rng(seed)
    leaf = jnp.asarray(rng.standard_normal((n, dim)) * 0.1, jnp.float32)
    return UpdateBuffer([leaf], jax.tree.structure({"w": 0}), [(dim,)],
                        [np.dtype(np.float32)])


def test_batched_aggregation_matches_scalar_plane():
    """One columnar delivery must aggregate to the same global params as the
    per-row Message adapter — the fused batch intake is an encoding change
    on top of the identical weighted reduction."""
    n, dim = 13, 4
    buf = _update_buffer(n, dim)
    samples = np.random.default_rng(1).integers(1, 9, n)
    b = ArrivalBatch.from_buffer(0, 0, buf, num_samples=samples)

    svc_b = AggregationService({"w": jnp.zeros(dim)},
                               trigger=ClientCountTrigger(n))
    svc_b(Delivery(t=1.0, batch=b))
    svc_s = AggregationService({"w": jnp.zeros(dim)},
                               trigger=ClientCountTrigger(n))
    for m in b.messages():
        svc_s(Delivery(t=1.0, message=m))
    assert len(svc_b.history) == len(svc_s.history) == 1
    diff = np.abs(np.asarray(svc_b.global_params["w"])
                  - np.asarray(svc_s.global_params["w"])).max()
    assert diff <= 1e-6


def test_batch_with_host_payloads_demotes_whole_aggregation():
    """A host-pytree payload anywhere demotes the aggregation to the host
    reference path (the scalar-plane contract) — batches spill through the
    Message adapter and the result still matches an all-scalar run."""
    n, dim = 6, 4
    buf = _update_buffer(n - 2, dim, seed=3)
    b = ArrivalBatch.from_buffer(0, 0, buf)
    host_msgs = [
        Message(0, 100 + i, 0, {"w": jnp.full((dim,), 0.5 + i)},
                num_samples=2) for i in range(2)]

    svc_m = AggregationService({"w": jnp.zeros(dim)},
                               trigger=ClientCountTrigger(n))
    svc_m(Delivery(t=0.0, batch=b))
    for m in host_msgs[:-1]:
        svc_m(Delivery(t=0.0, message=m))
    svc_m(Delivery(t=0.0, message=host_msgs[-1]))

    svc_ref = AggregationService({"w": jnp.zeros(dim)},
                                 trigger=ClientCountTrigger(n))
    for m in b.messages():
        svc_ref(Delivery(
            t=0.0, message=type(m)(
                m.task_id, m.device_id, m.round_idx,
                m.payload.materialize(), num_samples=m.num_samples)))
    for m in host_msgs:
        svc_ref(Delivery(t=0.0, message=m))
    assert len(svc_m.history) == len(svc_ref.history) == 1
    np.testing.assert_allclose(
        np.asarray(svc_m.global_params["w"]),
        np.asarray(svc_ref.global_params["w"]), atol=1e-6)


def test_pending_batch_state_dict_roundtrip_identical_timeline():
    """A snapshot taken with pending columnar batches restores to the exact
    same aggregation outcome as the uninterrupted service."""
    dim = 4
    buf_a, buf_b = _update_buffer(5, dim, seed=7), _update_buffer(3, dim,
                                                                  seed=8)
    ba = ArrivalBatch.from_buffer(
        0, 0, buf_a, num_samples=np.arange(1, 6))
    bb = ArrivalBatch.from_buffer(
        0, 0, buf_b, num_samples=np.array([2, 2, 2]))

    svc = AggregationService({"w": jnp.zeros(dim)},
                             trigger=ClientCountTrigger(8))
    svc(Delivery(t=1.0, batch=ba))
    assert svc.pending_clients == 5
    state = svc.state_dict()

    svc2 = AggregationService({"w": jnp.zeros(dim)},
                              trigger=ClientCountTrigger(8))
    svc2.load_state_dict(state)
    assert svc2.pending_clients == 5
    for s in (svc, svc2):
        s(Delivery(t=2.0, batch=bb))
        assert len(s.history) == 1
    np.testing.assert_array_equal(
        np.asarray(svc.global_params["w"]),
        np.asarray(svc2.global_params["w"]))


def test_streaming_batch_slices_match_nonstreaming():
    """Batch slices sharing one buffer stream into per-chunk partials; the
    final aggregate matches the one-shot non-streaming reduction."""
    n, dim = 12, 4
    buf = _update_buffer(n, dim, seed=9)
    samples = np.random.default_rng(2).integers(1, 5, n)
    b = ArrivalBatch.from_buffer(0, 0, buf, num_samples=samples)

    svc_st = AggregationService({"w": jnp.zeros(dim)},
                                trigger=ClientCountTrigger(n),
                                streaming=True)
    svc_st(Delivery(t=0.5, batch=b.islice(0, 7)))
    svc_st(Delivery(t=0.7, batch=b.islice(7, n)))
    svc_ns = AggregationService({"w": jnp.zeros(dim)},
                                trigger=ClientCountTrigger(n))
    svc_ns(Delivery(t=0.5, batch=b))
    assert len(svc_st.history) == len(svc_ns.history) == 1
    np.testing.assert_allclose(
        np.asarray(svc_st.global_params["w"]),
        np.asarray(svc_ns.global_params["w"]), atol=1e-6)
