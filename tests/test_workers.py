"""Multi-process fleet execution: pool/inline equivalence (the PR's core
property — a sharded round is bit-identical to the single-process columnar
round), worker-death re-dispatch, shared-memory segment recycling, the
fed_reduce block autotune table, and the one-manifest runtime checkpoint."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.deviceflow import DeviceFlow
from repro.core.devicemodel import GRADES, DeviceFleet
from repro.core.federation import AggregationService, SampleThresholdTrigger
from repro.core.scheduler import ResourceManager, ResourcePool, TaskEngine
from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier
from repro.core.strategies import AccumulatedStrategy
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.synthetic_ctr import make_federated_ctr
from repro.kernels.fed_reduce.ops import fed_reduce, tuned_blocks
from repro.models import ctr as ctr_lib
from repro.runtime.fault_tolerance import WorkerFailure, redispatch_chunks
from repro.runtime.workers import ChunkSpec, WorkerSpec, segment_layout

N, RPD, DIM = 24, 8, 16


def make_tiers(cohort=4, seed=7):
    """Module-level so spawn'ed workers can unpickle it by reference."""
    local = ctr_lib.make_local_train_fn(lr=1e-2, epochs=2)
    return (LogicalTier(local, cohort_size=cohort),
            {"High": DeviceTier(local, GRADES["High"], seed=seed,
                                cohort_size=cohort)})


class RecordingSink:
    """Forwarding sink that records dispatch-group membership + stamps."""

    def __init__(self, svc):
        self.svc = svc
        self.groups = []

    def __call__(self, d):
        if d.batch is not None:
            self.groups.append((d.t, tuple(d.batch.device_ids.tolist()),
                                tuple(d.batch.created_t.tolist())))
        else:
            m = d.message
            self.groups.append((d.t, (m.device_id,), (m.created_t,)))
        self.svc(d)


def _run_world(wire, workers, *, rounds=2, delay=None, poison=None):
    """Run ``rounds`` full rounds; return the observable world state."""
    data = make_federated_ctr(num_devices=N, records_per_device=RPD,
                              dim=DIM, seed=0)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), DIM)
    X, Y, counts = data.stacked_shards(np.arange(N), RPD)
    mask = (np.arange(RPD)[None] < counts[:, None]).astype(np.float32)
    batches = {"x": jnp.asarray(X), "y": jnp.asarray(Y),
               "mask": jnp.asarray(mask)}
    svc = AggregationService(
        params, trigger=SampleThresholdTrigger(int(counts.sum())))
    sink = RecordingSink(svc)
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    logical, tiers = make_tiers()
    kw = {}
    if workers and delay is not None:
        from repro.runtime.workers import FleetWorkerPool
        kw = dict(worker_pool=FleetWorkerPool(
            WorkerSpec(make_tiers), workers, debug_delay_s=delay))
    elif workers:
        kw = dict(workers=workers, worker_spec=WorkerSpec(make_tiers))
    sim = HybridSimulation(logical, tiers=tiers, deviceflow=flow,
                           wire=wire, **kw)
    stats = failures = None
    try:
        for rnd in range(rounds):
            if poison is not None and rnd == poison[0]:
                sim.pool.poison_worker(poison[1],
                                       fail_after_chunks=poison[2])
            sim.run_round(task_id=0, round_idx=rnd,
                          global_params=svc.global_params,
                          client_batches=batches, num_samples=counts,
                          num_logical=10, rng=jax.random.PRNGKey(rnd))
            flow.run(1e12)
            svc.tick(flow.clock.now)
        if sim.pool is not None:
            stats = dict(sim.pool.stats)
            failures = list(sim.pool.failures)
            alive = list(sim.pool.alive_workers)
        else:
            alive = None
    finally:
        sim.close()
    shelf = flow.shelf(0)
    return {
        "params": jax.device_get(svc.global_params),
        "bytes_received": shelf.total_bytes_received,
        "bytes_dispatched": shelf.total_bytes_dispatched,
        "aggregations": len(svc.history),
        "groups": sink.groups,
        "stats": stats,
        "failures": failures,
        "alive": alive,
    }


_REF_CACHE = {}


def _inline_ref(wire):
    if wire not in _REF_CACHE:
        _REF_CACHE[wire] = _run_world(wire, 0)
    return _REF_CACHE[wire]


def _assert_equivalent(ref, got):
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(got["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert got["bytes_received"] == ref["bytes_received"]
    assert got["bytes_dispatched"] == ref["bytes_dispatched"]
    assert got["aggregations"] == ref["aggregations"]
    # Dispatch-group membership and created_t stamps match group-for-group.
    assert len(got["groups"]) == len(ref["groups"])
    for (t0, ids0, ct0), (t1, ids1, ct1) in zip(ref["groups"],
                                                got["groups"]):
        assert t0 == t1 and ids0 == ids1
        np.testing.assert_array_equal(np.asarray(ct0), np.asarray(ct1))


@pytest.mark.parametrize("wire,workers,delay", [
    ("f32", 2, None),       # even shard count
    ("int8", 3, (0.0, 0.03, 0.01)),  # odd shards + jittered interleaving
    ("int8", 1, None),      # degenerate pool: every chunk on one worker
])
def test_pool_round_bit_identical(wire, workers, delay):
    """The property at the heart of the PR: a multi-process round — any
    shard count, any worker completion interleaving, quantized wire
    included — is bit-identical to the single-process columnar round:
    same params, same exact byte counters, same dispatch groups, same
    created_t stamps (the int8 case also proves error-feedback residuals
    stay with their shard across rounds)."""
    ref = _inline_ref(wire)
    got = _run_world(wire, workers, delay=delay)
    _assert_equivalent(ref, got)
    # Transport accounting: segments were created, then recycled in round 2.
    st = got["stats"]
    assert st["chunks"] == 2 * 7  # 3 logical + 4 device chunks per round
    assert st["segments_created"] >= 1 and st["bytes_shipped"] > 0
    assert st["redispatched_chunks"] == 0 and got["failures"] == []


def test_pool_segment_ring_recycles():
    """Round 2 reuses round 1's shared-memory segments (the donation-style
    ring): segment creations stay bounded while reuses accrue."""
    got = _run_world("f32", 2, rounds=3)
    st = got["stats"]
    assert st["segment_reuses"] > 0
    assert st["segments_created"] <= st["chunks"]


def test_worker_death_mid_round_redispatch():
    """Kill a worker mid-round (after it ships one chunk): the coordinator
    re-dispatches its remaining chunks to survivors and the round still
    completes bit-identical to the inline reference."""
    ref = _inline_ref("f32")
    got = _run_world("f32", 3, poison=(1, 1, 1))  # round 1, worker 1
    _assert_equivalent(ref, got)
    assert got["alive"] is not None and len(got["alive"]) == 2
    assert 1 not in got["alive"]
    assert len(got["failures"]) == 1
    f = got["failures"][0]
    assert isinstance(f, WorkerFailure) and f.worker_id == 1
    assert f.chunks and set(f.survivors) == set(got["alive"])
    assert got["stats"]["redispatched_chunks"] == len(f.chunks)


def test_redispatch_chunks_round_robin():
    got = redispatch_chunks([7, 3, 5], survivors=[0, 2])
    assert got == {0: [3, 7], 2: [5]}
    with pytest.raises(RuntimeError):
        redispatch_chunks([1], survivors=[])


def test_segment_layout_alignment_and_wire():
    layout, total = segment_layout(
        [(100,), (7,)], ["float32", "float32"], 3, "int8")
    # int8 wire: leaves stored int8, then one f32 scale column per leaf.
    assert [d for _, _, d in layout] == ["int8", "int8",
                                        "float32", "float32"]
    assert all(off % 64 == 0 for off, _, _ in layout)
    assert layout[2][1] == (3,) and total >= layout[-1][0] + 12
    f_layout, _ = segment_layout([(100,)], ["float32"], 3, "f32")
    assert f_layout == [(0, (3, 100), "float32")]


def test_tuned_blocks_table_and_override(monkeypatch):
    # Large stacks: int8 rows stream 1 byte/elem, affording taller tiles.
    assert tuned_blocks(4096, 65536, np.float32) == (256, 512)
    assert tuned_blocks(4096, 65536, np.int8) == (512, 1024)
    # Small stacks clamp to the padded shape — no 8x over-padding.
    assert tuned_blocks(24, 16, np.float32) == (32, 128)
    assert tuned_blocks(100, 1000, np.float32)[0] <= 128
    monkeypatch.setenv("FED_REDUCE_BLOCKS", "64,256")
    assert tuned_blocks(4096, 65536, np.float32) == (64, 256)
    monkeypatch.setenv("FED_REDUCE_BLOCKS", "garbage")
    with pytest.raises(ValueError):
        tuned_blocks(4096, 65536, np.float32)


def test_tuned_blocks_drive_pallas_kernel(monkeypatch):
    """The tuned (and overridden) blockings agree with the ref reduction
    through the interpreted kernel path."""
    k = jax.random.PRNGKey(3)
    stack = jax.random.normal(k, (37, 300))
    w = jax.random.uniform(jax.random.PRNGKey(4), (37,))
    ref = fed_reduce(stack, w, impl="ref")
    got = fed_reduce(stack, w, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    monkeypatch.setenv("FED_REDUCE_BLOCKS", "32,128")
    got2 = fed_reduce(stack, w, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref), atol=1e-5)


def test_one_manifest_runtime_checkpoint(tmp_path):
    """Satellite: fleet RNG counters and streaming-aggregation partials ride
    the SAME ``Checkpointer.save(runtime_state=...)`` manifest as the engine
    + DeviceFlow snapshot — one atomic unit, one restore call."""
    fleet = DeviceFleet(GRADES["High"], 6, seed=11)
    fleet.run_round(0)  # advance the per-device counters past zero

    params = {"w": jnp.zeros(DIM)}
    svc = AggregationService(params, trigger=SampleThresholdTrigger(10**9),
                             streaming=True)
    flow = DeviceFlow(svc)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 2}))
    eng = TaskEngine(rm, lambda t: [])

    state = eng.state_dict(deviceflow=flow, fleets={"High": fleet},
                           services={0: svc})
    assert set(state["fleets"]) == {"High"}
    assert set(state["aggregation"]) == {0}

    ck = Checkpointer(tmp_path)
    ck.save(3, params, runtime_state=state)
    # Consumed AFTER the snapshot — the restore must replay this exact draw.
    ref_next = fleet.run_round(1)
    manifest_sections = sorted(state)
    restored = ck.restore_runtime_state()
    assert sorted(restored) == manifest_sections
    import json
    manifest = json.loads(
        (tmp_path / "step_0000000003" / "manifest.json").read_text())
    assert "fleets" in manifest["runtime_sections"]
    assert "aggregation" in manifest["runtime_sections"]

    # Restore into a fresh world: fleet RNG resumes exactly where it left
    # off (the round-1 draw replays bit-identically).
    fleet2 = DeviceFleet(GRADES["High"], 6, seed=11)
    svc2 = AggregationService(params, trigger=SampleThresholdTrigger(10**9),
                              streaming=True)
    flow2 = DeviceFlow(svc2)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    rm2 = ResourceManager(ResourcePool({"High": 8}, {"High": 2}))
    eng2 = TaskEngine(rm2, lambda t: [])
    eng2.load_state_dict(restored, tasks=[], deviceflow=flow2,
                         fleets={"High": fleet2}, services={0: svc2})
    replay = fleet2.run_round(1)
    np.testing.assert_array_equal(replay.stage_duration_min,
                                  ref_next.stage_duration_min)
    # Legacy engine states (no fleets/aggregation sections) still load.
    legacy = {k: v for k, v in restored.items()
              if k not in ("fleets", "aggregation")}
    eng2.load_state_dict(legacy, tasks=[], deviceflow=flow2,
                         fleets={"High": fleet2}, services={0: svc2})
