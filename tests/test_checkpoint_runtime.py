"""Checkpoint/restart, fault-tolerance supervisor, elastic rescale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.allocation import GradeRuntime
from repro.core.deviceflow import DeviceFlow, Message
from repro.core.scheduler import ResourceManager, ResourcePool
from repro.core.strategies import AccumulatedStrategy
from repro.core.task import GradeSpec
from repro.runtime.fault_tolerance import (
    ElasticController,
    RetryPolicy,
    StragglerPolicy,
    TrainingSupervisor,
    with_retries,
)


def state_tree(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_checkpoint_roundtrip_bitexact(tmp_path):
    ck = Checkpointer(tmp_path)
    t = state_tree(3.5)
    ck.save(7, t, extra={"note": "hello"})
    restored, extra = ck.restore(state_tree())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert extra == {"note": "hello"}
    assert ck.latest_step() == 7


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, state_tree(float(s)))
    ck.wait()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, state_tree(1.0))
    # Simulate a crash mid-save: a step dir without manifest.
    (tmp_path / "step_0000000009").mkdir()
    assert ck.latest_step() == 1


def test_supervisor_restarts_from_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path)
    crashed = {"done": False}

    def step_fn(state, step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return {"params": state["params"],
                "step": state["step"] + 1}

    sup = TrainingSupervisor(ck, checkpoint_every=2,
                             policy=RetryPolicy(backoff_s=0.01))
    state, step = sup.run(state_tree(0.0), step_fn, 8,
                          state_like=state_tree())
    assert step == 8
    assert int(state["step"]) == 8  # replayed steps after restore
    assert crashed["done"]


def test_with_retries_gives_up():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise RuntimeError("nope")

    f = with_retries(bad, RetryPolicy(max_attempts=3, backoff_s=0.0))
    with pytest.raises(RuntimeError):
        f()
    assert calls["n"] == 3


def test_straggler_policy():
    p = StragglerPolicy(target=100, over_select=0.3, deadline_s=60.0)
    assert p.num_selected == 130
    assert not p.round_complete(arrived=99, elapsed_s=10)
    assert p.round_complete(arrived=100, elapsed_s=10)
    assert p.round_complete(arrived=10, elapsed_s=61)


def test_deviceflow_dispatcher_state_survives_checkpoint():
    """Regression: restore rebuilt Dispatchers from scratch, losing ``_cycle``
    — an AccumulatedStrategy with per-cycle thresholds silently restarted at
    threshold 0 after a checkpoint restore."""
    strategy = AccumulatedStrategy(thresholds=(2, 5))

    def mk(sink):
        flow = DeviceFlow(sink, seed=0)
        flow.register_task(0, strategy)
        return flow

    got = []
    flow = mk(got.append)
    for i in range(2):  # first cycle (threshold 2) fires -> cursor at 1
        flow.submit(Message(0, i, 0, payload=i), t=1.0)
    assert len(got) == 2
    state = flow.state_dict()

    restored_got = []
    restored = mk(restored_got.append)
    restored.load_state_dict(state)
    for i in range(4):  # below the *current* threshold of 5: must NOT fire
        restored.submit(Message(0, 10 + i, 0, payload=i), t=2.0)
    assert restored_got == []
    restored.submit(Message(0, 99, 0, payload="x"), t=3.0)
    assert len(restored_got) == 5  # fires exactly at the cycle-1 threshold
    assert restored.conservation_ok(0)


def test_deviceflow_accepts_legacy_shelf_only_state():
    got = []
    flow = DeviceFlow(got.append)
    flow.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    legacy = {0: {"task_id": 0, "buf": [Message(0, 0, 0, payload=0)],
                  "received": 1, "dispatched": 0, "dropped": 0}}
    flow.load_state_dict(legacy)
    assert len(flow.shelf(0)) == 1
    assert flow.conservation_ok(0)


def test_elastic_rescale_resolves_allocation():
    rm = ResourceManager(ResourcePool({"High": 200}, {"High": 17}))
    ec = ElasticController(rm)
    specs = [GradeSpec("High", 100, logical_bundles=200,
                       bundles_per_device=8, physical_devices=17)]
    rts = [GradeRuntime(alpha=16.0, beta=21.6, lam=15.0)]
    before = ec.scale_up("High", bundles=0, task_specs=specs, runtimes=rts)
    # Lose 12 phones: allocation shifts toward the logical tier.
    after = ec.node_failure("High", phones=12, task_specs=specs, runtimes=rts)
    assert after is not None
    assert after.per_grade[0].physical_devices <= before.per_grade[0].physical_devices
    assert (after.per_grade[0].logical_devices
            + after.per_grade[0].physical_devices == 100)
    assert len(ec.events) == 2


# --------------------------------------------------------------------------- #
# Unified runtime snapshot: engine + in-flight columnar batches through the
# Checkpointer's pickle channel.
# --------------------------------------------------------------------------- #
from repro.core.allocation import GradeRuntime as _GR  # noqa: E402
from repro.core.deviceflow import ArrivalBatch  # noqa: E402
from repro.core.scheduler import TaskEngine  # noqa: E402
from repro.core.task import OperatorFlow, Task  # noqa: E402
from repro.core.updates import UpdateBuffer  # noqa: E402


def _mini_buffer(n, dim=4, seed=0):
    rng = np.random.default_rng(seed)
    leaf = jnp.asarray(rng.standard_normal((n, dim)) * 0.1, jnp.float32)
    return UpdateBuffer([leaf], jax.tree.structure({"w": 0}), [(dim,)],
                        [np.dtype(np.float32)])


def test_unified_runtime_snapshot_restores_identical_timeline(tmp_path):
    """Acceptance: a mid-round engine snapshot with in-flight columnar
    batches — TaskEngine.state_dict(deviceflow=...) pickled through
    ``Checkpointer.save(runtime_state=...)`` — restores to the identical
    delivery timeline and task completion times."""
    _flow = OperatorFlow(("train",))
    rts = lambda t: [_GR(alpha=5.0, beta=8.0, lam=2.0)] * len(t.grades)

    def make_task(**kw):
        return Task(_flow, (GradeSpec("High", 10, logical_bundles=8,
                                      physical_devices=2),), **kw)

    def build(sink):
        flow = DeviceFlow(sink)
        flow.register_task(0, AccumulatedStrategy(thresholds=(5,)))
        rm = ResourceManager(ResourcePool({"High": 8}, {"High": 2}))
        return TaskEngine(rm, rts, preemptive=True, clock=flow.clock), flow

    def flat(got):
        out = []
        for d in got:
            if d.batch is not None:
                out += [(d.t, int(i)) for i in d.batch.device_ids]
            else:
                out.append((d.t, int(d.message.device_id)))
        return out

    buf = _mini_buffer(3, seed=2)

    def first_half(eng, flow, tasks):
        a, hi = tasks
        eng.submit(a)
        eng.submit(hi, at=15.0)  # deferred arrival, mid round 1 of a
        flow.submit_batch(ArrivalBatch.from_buffer(0, 0, buf),
                          ts=[1.0, 2.0, 3.0])  # below threshold: shelved

    def second_half(eng, flow):
        flow.submit_batch(
            ArrivalBatch.from_buffer(0, 0, _mini_buffer(2, seed=3),
                                     device_ids=np.arange(3, 5)),
            ts=[20.0, 21.0])  # 5th row crosses the threshold
        eng.drain()

    # Reference: uninterrupted run.
    got_r = []
    eng_r, flow_r = build(got_r.append)
    tasks_r = (make_task(rounds=3), make_task(rounds=1, priority=5))
    first_half(eng_r, flow_r, tasks_r)
    second_half(eng_r, flow_r)

    # Interrupted: snapshot after the t=0 admission, batch still shelved,
    # high-priority arrival still pending.
    got_1 = []
    eng_1, flow_1 = build(got_1.append)
    tasks_1 = (make_task(rounds=3), make_task(rounds=1, priority=5))
    first_half(eng_1, flow_1, tasks_1)
    assert eng_1.clock.run_one()
    snapshot = eng_1.state_dict(deviceflow=flow_1)

    ck = Checkpointer(tmp_path)
    ck.save(3, state_tree(1.0), runtime_state=snapshot)
    restored = ck.restore_runtime_state()
    assert restored is not None

    got_2 = []
    eng_2, flow_2 = build(got_2.append)
    eng_2.load_state_dict(restored, tasks=list(tasks_1), deviceflow=flow_2)
    assert len(flow_2.shelf(0)) == 3  # shelved batch rows survived the pickle
    second_half(eng_2, flow_2)

    for t_ref, t_new in zip(tasks_r, tasks_1):
        assert eng_2.executions[t_new.task_id].finished_t == pytest.approx(
            eng_r.executions[t_ref.task_id].finished_t)
    assert flat(got_2) == flat(got_r)
    assert flow_2.conservation_ok(0)
    # Buffer numerics survive the host-view pickle bit-for-bit.
    d2 = next(d for d in got_2 if d.batch is not None)
    np.testing.assert_array_equal(
        np.asarray(d2.batch.buffer.materialize()["w"]),
        np.asarray(buf.materialize()["w"]))


def test_restore_runtime_state_absent_returns_none(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, state_tree(1.0))
    assert ck.restore_runtime_state() is None
    with pytest.raises(FileNotFoundError):
        Checkpointer(tmp_path / "empty").restore_runtime_state()
