"""Checkpoint/restart, fault-tolerance supervisor, elastic rescale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.allocation import GradeRuntime
from repro.core.scheduler import ResourceManager, ResourcePool
from repro.core.task import GradeSpec
from repro.runtime.fault_tolerance import (
    ElasticController,
    RetryPolicy,
    StragglerPolicy,
    TrainingSupervisor,
    with_retries,
)


def state_tree(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_checkpoint_roundtrip_bitexact(tmp_path):
    ck = Checkpointer(tmp_path)
    t = state_tree(3.5)
    ck.save(7, t, extra={"note": "hello"})
    restored, extra = ck.restore(state_tree())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert extra == {"note": "hello"}
    assert ck.latest_step() == 7


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, state_tree(float(s)))
    ck.wait()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, state_tree(1.0))
    # Simulate a crash mid-save: a step dir without manifest.
    (tmp_path / "step_0000000009").mkdir()
    assert ck.latest_step() == 1


def test_supervisor_restarts_from_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path)
    crashed = {"done": False}

    def step_fn(state, step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return {"params": state["params"],
                "step": state["step"] + 1}

    sup = TrainingSupervisor(ck, checkpoint_every=2,
                             policy=RetryPolicy(backoff_s=0.01))
    state, step = sup.run(state_tree(0.0), step_fn, 8,
                          state_like=state_tree())
    assert step == 8
    assert int(state["step"]) == 8  # replayed steps after restore
    assert crashed["done"]


def test_with_retries_gives_up():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise RuntimeError("nope")

    f = with_retries(bad, RetryPolicy(max_attempts=3, backoff_s=0.0))
    with pytest.raises(RuntimeError):
        f()
    assert calls["n"] == 3


def test_straggler_policy():
    p = StragglerPolicy(target=100, over_select=0.3, deadline_s=60.0)
    assert p.num_selected == 130
    assert not p.round_complete(arrived=99, elapsed_s=10)
    assert p.round_complete(arrived=100, elapsed_s=10)
    assert p.round_complete(arrived=10, elapsed_s=61)


def test_elastic_rescale_resolves_allocation():
    rm = ResourceManager(ResourcePool({"High": 200}, {"High": 17}))
    ec = ElasticController(rm)
    specs = [GradeSpec("High", 100, logical_bundles=200,
                       bundles_per_device=8, physical_devices=17)]
    rts = [GradeRuntime(alpha=16.0, beta=21.6, lam=15.0)]
    before = ec.scale_up("High", bundles=0, task_specs=specs, runtimes=rts)
    # Lose 12 phones: allocation shifts toward the logical tier.
    after = ec.node_failure("High", phones=12, task_specs=specs, runtimes=rts)
    assert after is not None
    assert after.per_grade[0].physical_devices <= before.per_grade[0].physical_devices
    assert (after.per_grade[0].logical_devices
            + after.per_grade[0].physical_devices == 100)
    assert len(ec.events) == 2
