"""Checkpoint/restart, fault-tolerance supervisor, elastic rescale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.allocation import GradeRuntime
from repro.core.deviceflow import DeviceFlow, Message
from repro.core.scheduler import ResourceManager, ResourcePool
from repro.core.strategies import AccumulatedStrategy
from repro.core.task import GradeSpec
from repro.runtime.fault_tolerance import (
    ElasticController,
    RetryPolicy,
    StragglerPolicy,
    TrainingSupervisor,
    with_retries,
)


def state_tree(x=0.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_checkpoint_roundtrip_bitexact(tmp_path):
    ck = Checkpointer(tmp_path)
    t = state_tree(3.5)
    ck.save(7, t, extra={"note": "hello"})
    restored, extra = ck.restore(state_tree())
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert extra == {"note": "hello"}
    assert ck.latest_step() == 7


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, state_tree(float(s)))
    ck.wait()
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_ignores_uncommitted(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, state_tree(1.0))
    # Simulate a crash mid-save: a step dir without manifest.
    (tmp_path / "step_0000000009").mkdir()
    assert ck.latest_step() == 1


def test_supervisor_restarts_from_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path)
    crashed = {"done": False}

    def step_fn(state, step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")
        return {"params": state["params"],
                "step": state["step"] + 1}

    sup = TrainingSupervisor(ck, checkpoint_every=2,
                             policy=RetryPolicy(backoff_s=0.01))
    state, step = sup.run(state_tree(0.0), step_fn, 8,
                          state_like=state_tree())
    assert step == 8
    assert int(state["step"]) == 8  # replayed steps after restore
    assert crashed["done"]


def test_with_retries_gives_up():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise RuntimeError("nope")

    f = with_retries(bad, RetryPolicy(max_attempts=3, backoff_s=0.0))
    with pytest.raises(RuntimeError):
        f()
    assert calls["n"] == 3


def test_straggler_policy():
    p = StragglerPolicy(target=100, over_select=0.3, deadline_s=60.0)
    assert p.num_selected == 130
    assert not p.round_complete(arrived=99, elapsed_s=10)
    assert p.round_complete(arrived=100, elapsed_s=10)
    assert p.round_complete(arrived=10, elapsed_s=61)


def test_deviceflow_dispatcher_state_survives_checkpoint():
    """Regression: restore rebuilt Dispatchers from scratch, losing ``_cycle``
    — an AccumulatedStrategy with per-cycle thresholds silently restarted at
    threshold 0 after a checkpoint restore."""
    strategy = AccumulatedStrategy(thresholds=(2, 5))

    def mk(sink):
        flow = DeviceFlow(sink, seed=0)
        flow.register_task(0, strategy)
        return flow

    got = []
    flow = mk(got.append)
    for i in range(2):  # first cycle (threshold 2) fires -> cursor at 1
        flow.submit(Message(0, i, 0, payload=i), t=1.0)
    assert len(got) == 2
    state = flow.state_dict()

    restored_got = []
    restored = mk(restored_got.append)
    restored.load_state_dict(state)
    for i in range(4):  # below the *current* threshold of 5: must NOT fire
        restored.submit(Message(0, 10 + i, 0, payload=i), t=2.0)
    assert restored_got == []
    restored.submit(Message(0, 99, 0, payload="x"), t=3.0)
    assert len(restored_got) == 5  # fires exactly at the cycle-1 threshold
    assert restored.conservation_ok(0)


def test_deviceflow_accepts_legacy_shelf_only_state():
    got = []
    flow = DeviceFlow(got.append)
    flow.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    legacy = {0: {"task_id": 0, "buf": [Message(0, 0, 0, payload=0)],
                  "received": 1, "dispatched": 0, "dropped": 0}}
    flow.load_state_dict(legacy)
    assert len(flow.shelf(0)) == 1
    assert flow.conservation_ok(0)


def test_elastic_rescale_resolves_allocation():
    rm = ResourceManager(ResourcePool({"High": 200}, {"High": 17}))
    ec = ElasticController(rm)
    specs = [GradeSpec("High", 100, logical_bundles=200,
                       bundles_per_device=8, physical_devices=17)]
    rts = [GradeRuntime(alpha=16.0, beta=21.6, lam=15.0)]
    before = ec.scale_up("High", bundles=0, task_specs=specs, runtimes=rts)
    # Lose 12 phones: allocation shifts toward the logical tier.
    after = ec.node_failure("High", phones=12, task_specs=specs, runtimes=rts)
    assert after is not None
    assert after.per_grade[0].physical_devices <= before.per_grade[0].physical_devices
    assert (after.per_grade[0].logical_devices
            + after.per_grade[0].physical_devices == 100)
    assert len(ec.events) == 2
