"""Fleet-calibrated runtimes: round-trip fidelity, allocator integration."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocation import (
    GradeRuntime,
    solve_allocation,
    solve_allocation_bruteforce,
)
from repro.core.calibration import (
    RuntimeCalibrator,
    calibrate_runtimes,
    table1_runtime,
)
from repro.core.devicemodel import GRADES, DeviceFleet, Stage
from repro.core.scheduler import ResourceManager, ResourcePool, TaskRunner
from repro.core.task import GradeSpec, OperatorFlow, Task


# --------------------------------------------------------------------------- #
# Round trip: fleet samples -> calibrated runtimes reproduce Table-I means
# --------------------------------------------------------------------------- #
def test_calibrate_runtimes_roundtrip_table1():
    samples = []
    for g in ("High", "Low"):
        fleet = DeviceFleet(GRADES[g], 2000, seed=3)
        samples += [fleet.run_round(r) for r in range(2)]
    measured = calibrate_runtimes(samples=samples)
    for g in ("High", "Low"):
        ref = table1_runtime(GRADES[g])
        got = measured[g]
        assert got.alpha == pytest.approx(ref.alpha, rel=0.02)
        assert got.beta == pytest.approx(ref.beta, rel=0.02)
        assert got.lam == pytest.approx(ref.lam, rel=0.02)
    # Table-I ordering survives measurement: Low phones are slower.
    assert measured["High"].beta < measured["Low"].beta


def test_calibrate_from_benchmarking_reports():
    fleet = DeviceFleet(GRADES["High"], 600, seed=1)
    reports = [fleet.run_round(0).report(i) for i in range(600)]
    measured = calibrate_runtimes(reports=reports)["High"]
    ref = table1_runtime(GRADES["High"])
    assert measured.beta == pytest.approx(ref.beta, rel=0.05)
    assert measured.lam == pytest.approx(ref.lam, rel=0.05)


def test_observed_logical_durations_override_alpha():
    cal = RuntimeCalibrator()
    cal.observe_fleet(DeviceFleet(GRADES["High"], 64, seed=0).run_round(0))
    assert cal.runtime("High").alpha == pytest.approx(
        table1_runtime(GRADES["High"]).alpha, rel=0.1)
    for d in (4.0, 6.0):
        cal.observe_logical("High", d)
    assert cal.runtime("High").alpha == pytest.approx(5.0)


def test_logical_only_observations_still_measure_alpha():
    """A grade observed solely via observe_logical keeps the measured alpha
    (beta/lambda come from the fallback) instead of being ignored."""
    cal = RuntimeCalibrator()
    cal.observe_logical("High", 5.0)
    rt = cal.runtime("High")
    assert rt.alpha == pytest.approx(5.0)
    ref = table1_runtime(GRADES["High"])
    assert rt.beta == pytest.approx(ref.beta)
    assert rt.lam == pytest.approx(ref.lam)


def test_uncalibrated_grade_falls_back_to_prior_then_table1():
    cal = RuntimeCalibrator(prior={"Custom": GradeRuntime(1.0, 2.0, 0.5)})
    assert cal.runtime("Custom").beta == 2.0  # explicit prior
    assert cal.runtime("High").beta == pytest.approx(
        table1_runtime(GRADES["High"]).beta)  # Table-I default
    with pytest.raises(KeyError):
        cal.runtime("Unknown")


def test_sample_runtimes_draws_observed_rounds():
    cal = RuntimeCalibrator()
    fleet = DeviceFleet(GRADES["High"], 128, seed=5)
    cal.observe_fleet(fleet.run_round(0))
    rng = np.random.default_rng(0)
    draws = [cal.sample_runtimes(["High"], rng)[0] for _ in range(16)]
    betas = {d.beta for d in draws}
    assert len(betas) > 1  # sampled, not the mean
    mean_beta = cal.runtime("High").beta
    assert all(abs(d.beta - mean_beta) / mean_beta < 0.5 for d in draws)
    # Sampled durations drive a valid allocation (finite makespan).
    spec = GradeSpec("High", 20, logical_bundles=8, physical_devices=4)
    res = solve_allocation([spec], cal.sample_runtimes([spec], rng))
    assert np.isfinite(res.makespan)


# --------------------------------------------------------------------------- #
# Scheduler integration: TaskRunner consumes the calibrator directly
# --------------------------------------------------------------------------- #
def test_task_runner_accepts_calibrator():
    cal = RuntimeCalibrator()
    cal.observe_fleet(DeviceFleet(GRADES["High"], 64, seed=2).run_round(0))
    rm = ResourceManager(ResourcePool({"High": 100}, {"High": 10}))
    seen = []
    runner = TaskRunner(
        rm, runtimes=cal,
        tier_runners={"logical": lambda *a: seen.append(("l", a[2])) or [],
                      "device": lambda *a: seen.append(("d", a[2])) or []},
    )
    task = Task(OperatorFlow(("train",)),
                (GradeSpec("High", 8, logical_bundles=40,
                           physical_devices=4),))
    rm.freeze(task.task_id, task.demand())
    rec = runner.run(task)
    assert rec.state.value == "completed"
    assert sum(n for _, n in seen) == 8  # all devices placed by the split


# --------------------------------------------------------------------------- #
# Property: calibrated runtimes keep the exact solver exact
# --------------------------------------------------------------------------- #
grade_strategy = st.builds(
    lambda N, q, f, k, m: GradeSpec(
        "g", N, benchmarking_devices=min(q, N), logical_bundles=f,
        bundles_per_device=k, physical_devices=m),
    N=st.integers(0, 30), q=st.integers(0, 4), f=st.integers(1, 20),
    k=st.integers(1, 5), m=st.integers(1, 6),
)


@settings(max_examples=40, deadline=None)
@given(st.lists(grade_strategy, min_size=1, max_size=2),
       st.integers(0, 10_000), st.integers(1, 64), st.integers(1, 3))
def test_calibrated_allocator_matches_bruteforce(specs, seed, n_dev, n_rounds):
    """Allocation on *measured* runtimes agrees with the O(N) oracle."""
    cal = RuntimeCalibrator()
    for g in ("High", "Low"):
        fleet = DeviceFleet(GRADES[g], n_dev, seed=seed)
        for r in range(n_rounds):
            cal.observe_fleet(fleet.run_round(r))
    specs = [
        GradeSpec(("High", "Low")[i % 2], s.num_devices,
                  s.benchmarking_devices, s.logical_bundles,
                  s.bundles_per_device, s.physical_devices)
        for i, s in enumerate(specs)
    ]
    rts = cal.runtimes_for(specs)
    a = solve_allocation(specs, rts)
    b = solve_allocation_bruteforce(specs, rts)
    assert a.makespan == pytest.approx(b.makespan)
    assert a.total_logical == b.total_logical


def test_table1_runtime_train_cost_scale():
    base = table1_runtime(GRADES["High"])
    scaled = table1_runtime(GRADES["High"], train_cost_scale=2.0)
    assert scaled.alpha == pytest.approx(2 * base.alpha)
    assert scaled.beta == pytest.approx(base.beta + base.alpha)
    assert scaled.lam == pytest.approx(base.lam)
