"""End-to-end behaviour tests for the SimDC platform."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deviceflow import DeviceFlow, Message
from repro.core.devicemodel import GRADES
from repro.core.federation import AggregationService, SampleThresholdTrigger
from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier
from repro.core.strategies import AccumulatedStrategy, TimeIntervalStrategy
from repro.core.traffic_curves import right_tailed_normal
from repro.data.synthetic_ctr import make_federated_ctr
from repro.models import ctr as ctr_lib


def test_federated_ctr_learns():
    """The paper's core loop (LR on CTR, FedAvg) improves over rounds."""
    from benchmarks.common import run_federated_ctr

    out = run_federated_ctr(num_devices=64, rounds=8, dim=64, seed=0)
    accs = [h["acc"] for h in out["history"]]
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]
    assert accs[-1] >= 0.6  # learnable synthetic task


def test_hybrid_simulation_round_end_to_end():
    """Allocation split -> both tiers execute -> DeviceFlow -> aggregation."""
    dim, n_clients, rpd = 32, 12, 10
    data = make_federated_ctr(num_devices=n_clients, records_per_device=rpd,
                              dim=dim, seed=0)
    local = ctr_lib.make_local_train_fn(lr=1e-2, epochs=3)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)

    svc = AggregationService(params, trigger=SampleThresholdTrigger(
        n_clients * rpd))
    flow = DeviceFlow(svc)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))

    sim = HybridSimulation(
        LogicalTier(local, cohort_size=8),
        DeviceTier(local, GRADES["High"], dtype=jnp.bfloat16),
        deviceflow=flow,
    )
    X, Y, counts = data.stacked_shards(np.arange(n_clients), rpd)
    mask = (np.arange(rpd)[None] < counts[:, None]).astype(np.float32)
    outcome = sim.run_round(
        task_id=0, round_idx=0, global_params=params,
        client_batches={"x": jnp.asarray(X), "y": jnp.asarray(Y),
                        "mask": jnp.asarray(mask)},
        num_samples=counts, num_logical=8,
        rng=jax.random.PRNGKey(1), benchmark_devices=2,
    )
    assert outcome.num_logical == 8 and outcome.num_physical == 4
    assert len(outcome.messages) == n_clients
    assert len(outcome.reports) == 2  # benchmarking devices measured
    assert len(svc.history) == 1  # threshold reached -> one aggregation
    assert flow.conservation_ok(0)


def test_logical_vs_device_tier_numerical_gap_small():
    """Fig 6 premise: bf16 'device operators' track f32 'logical operators'."""
    dim = 32
    data = make_federated_ctr(num_devices=4, records_per_device=16,
                              dim=dim, seed=2)
    local = ctr_lib.make_local_train_fn(lr=1e-3, epochs=10)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    X, Y, counts = data.stacked_shards(np.arange(4), 16)
    batch = {"x": jnp.asarray(X[0]), "y": jnp.asarray(Y[0]),
             "mask": jnp.ones(16, jnp.float32)}
    p32, _ = jax.jit(local)(params, batch, jax.random.PRNGKey(0))
    tier = DeviceTier(local, GRADES["Low"], dtype=jnp.bfloat16)
    pbf, _, _ = tier.run_device(0, params, batch, jax.random.PRNGKey(0), 0)
    diff = float(jnp.abs(p32["w"] - pbf["w"]).max())
    assert diff < 5e-2  # operators differ but remain close (paper <0.5% ACC)


def test_traffic_curve_shifts_aggregation_timing():
    """Fig 9 behaviour: slower curves delay aggregation completion."""
    results = {}
    for sigma in (1.0, 3.0):
        deliveries = []
        flow = DeviceFlow(lambda d: deliveries.append(d))
        flow.register_task(0, TimeIntervalStrategy(
            curve=right_tailed_normal(sigma, hi=12.0), interval=600.0))
        for i in range(400):
            flow.submit(Message(0, i, 0, payload=None))
        flow.round_complete(0)
        flow.run()
        ts = np.array([d.t for d in deliveries])
        # time by which half the messages have arrived
        results[sigma] = np.percentile(ts, 50)
        deliveries.clear()
    assert results[1.0] < results[3.0]


def test_serve_pipeline_handle_payloads_and_traffic_accounting():
    """Satellite: serving-path token messages carry handle payloads with
    real ``payload_nbytes``, so DeviceFlow byte accounting covers serving
    traffic; same-buffer batches gather prompts on device."""
    from repro.launch.serve import BatchedServer, stack_requests
    from repro.configs.registry import get_config

    cfg = get_config("llama3_2_3b", smoke=True)
    prompt_len, n_req = 8, 4
    server = BatchedServer(cfg, batch_size=2, prompt_len=prompt_len,
                           decode_tokens=4, max_len=16)
    flow = DeviceFlow(server)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size,
                        size=(n_req, prompt_len)).astype(np.int32)
    buf = stack_requests(toks)
    for i in range(n_req):
        flow.submit(Message(0, i, 0, payload=buf.handle(i)))
    flow.run()
    server.drain(flow.clock.now)
    assert sum(m.tokens_decoded for m in server.metrics) == 16
    shelf = flow.shelf(0)
    # Every request message reports its true wire size (prompt_len int32s).
    assert shelf.total_bytes_dispatched == n_req * prompt_len * 4

    # Same prompts as host-dict payloads decode the same tokens (the handle
    # path is accounting + transport, not numerics).
    server2 = BatchedServer(cfg, batch_size=2, prompt_len=prompt_len,
                            decode_tokens=4, max_len=16)
    flow2 = DeviceFlow(server2)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    for i in range(n_req):
        flow2.submit(Message(0, i, 0, payload={"tokens": toks[i]}))
    flow2.run()
    server2.drain(flow2.clock.now)
    assert (sum(m.tokens_decoded for m in server2.metrics)
            == sum(m.tokens_decoded for m in server.metrics))


def test_serve_pipeline_end_to_end():
    from repro.launch.serve import BatchedServer
    from repro.configs.registry import get_config

    cfg = get_config("llama3_2_3b", smoke=True)
    server = BatchedServer(cfg, batch_size=2, prompt_len=8, decode_tokens=4,
                           max_len=16)
    flow = DeviceFlow(server)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    rng = np.random.default_rng(0)
    for i in range(4):
        flow.submit(Message(0, i, 0, payload={
            "tokens": rng.integers(1, cfg.vocab_size, 8).astype(np.int32)}))
    flow.run()
    server.drain(flow.clock.now)
    # 4 requests in batches of 2 -> 2 batches x 4 decode steps x 2 seqs
    assert sum(m.tokens_decoded for m in server.metrics) == 16
