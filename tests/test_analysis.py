"""simcheck: lint rules (fixture corpus + clean tree) and runtime sanitizers.

The fixture corpus under ``tests/lint_fixtures/`` holds one deliberately-bad
snippet per rule; it is excluded from the default walk (``EXCLUDE_DIRS``),
so these tests lint the files explicitly and assert each rule fires at the
expected line.  The clean-tree test is the other half of the contract: after
this PR's fixes, ``lint src tests`` over the real tree reports nothing.
"""
import os
import pathlib
from multiprocessing import shared_memory

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint, sanitizers
from repro.core import federation
from repro.core.deviceflow import VirtualClock
from repro.core.monitoring import InMemorySink, MetricsBus
from repro.core.updates import UpdateBuffer

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "lint_fixtures"


# --------------------------------------------------------------------------
# lint: every rule proven live by a firing fixture


FIXTURE_EXPECTATIONS = [
    ("bad_r001.py", "R001", {4}),
    (os.path.join("core", "bad_r002.py"), "R002", {6}),
    ("bad_r003.py", "R003", {9, 10}),
    ("bad_r004.py", "R004", {6}),
    ("bad_r005.py", "R005", {6, 7}),
    ("bad_r006.py", "R006", {7}),
]


@pytest.mark.parametrize("rel,rule,lines", FIXTURE_EXPECTATIONS,
                         ids=[rule for _, rule, _ in FIXTURE_EXPECTATIONS])
def test_fixture_fires_rule_at_expected_lines(rel, rule, lines):
    path = FIXTURES / rel
    findings = lint.lint_file(path)
    assert findings, f"{rel} produced no findings"
    assert {f.rule for f in findings} == {rule}
    assert {f.line for f in findings} == lines
    for f in findings:
        assert str(f).startswith(f"{path}:{f.line}: {rule} ")


def test_fixture_corpus_is_excluded_from_directory_walks():
    findings = lint.lint_paths([str(REPO / "tests")])
    assert not any("lint_fixtures" in f.path for f in findings)


def test_clean_tree_lints_clean():
    findings = lint.lint_paths([str(REPO / "src"), str(REPO / "tests")])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_codes(capsys):
    assert lint.main([str(REPO / "src"), str(REPO / "tests")]) == 0
    assert "simcheck: clean" in capsys.readouterr().out
    assert lint.main([str(FIXTURES / "bad_r001.py")]) == 1
    out = capsys.readouterr().out
    assert "R001" in out and "1 finding(s)" in out


def test_cli_rule_subset():
    # R001 fixture has no R005 problem: subsetting away R001 lints clean.
    assert lint.main(["--rules", "R005",
                      str(FIXTURES / "bad_r001.py")]) == 0


def test_suppression_comments():
    src = "import jax\nf = jax.jit(lambda s: s, donate_argnums=(0,))"
    assert [f.rule for f in lint.lint_source(src)] == ["R001"]
    assert lint.lint_source(src + "  # simcheck: ok") == []
    assert lint.lint_source(src + "  # simcheck: ok[R001]") == []
    # A suppression naming a different rule does not apply.
    assert [f.rule for f in
            lint.lint_source(src + "  # simcheck: ok[R003]")] == ["R001"]


def test_shape_arithmetic_is_exempt_from_r003():
    src = (
        "from repro.analysis.sanitizers import hot_path\n"
        "@hot_path\n"
        "def f(x):\n"
        "    n = int(x.shape[0])\n"        # shape math: fine
        "    def emit(row):\n"
        "        return float(row[0])\n"   # nested def: not scanned
        "    return n\n"
    )
    assert lint.lint_source(src) == []


# --------------------------------------------------------------------------
# sanitizers: enable switch and transfer guard


def test_override_controls_enabled():
    with sanitizers.override(True):
        assert sanitizers.enabled()
        with sanitizers.override(False):
            assert not sanitizers.enabled()
        assert sanitizers.enabled()


def test_hot_paths_are_marked():
    from repro.core.serving import ContinuousBatchingEngine
    from repro.core.simulation import HybridSimulation
    from repro.kernels.fed_reduce.ops import fed_reduce

    assert ContinuousBatchingEngine.step.__simdc_hot_path__
    assert HybridSimulation._run_split.__simdc_hot_path__
    assert fed_reduce.__simdc_hot_path__


def test_hot_path_guard_catches_implicit_transfer():
    @sanitizers.hot_path
    def dispatch(x):
        return jax.jit(lambda y: y + 1)(x)

    host = np.ones((4,), np.float32)
    with sanitizers.override(False):
        np.testing.assert_allclose(np.asarray(dispatch(host)), 2.0)
    with sanitizers.override(True):
        with pytest.raises(Exception, match="[Tt]ransfer"):
            dispatch(host)
        # Explicitly-placed operands stay legal under the guard.
        dev = jax.device_put(host)
        np.testing.assert_allclose(np.asarray(dispatch(dev)), 2.0)


def test_exempt_lets_user_callbacks_transfer():
    def user_transform(rows):
        return jnp.asarray(rows, jnp.float32)  # implicit under "disallow"

    @sanitizers.hot_path
    def with_exempt(rows):
        return sanitizers.exempt(user_transform)(rows)

    @sanitizers.hot_path
    def without_exempt(rows):
        return user_transform(rows)

    with sanitizers.override(True):
        out = with_exempt([1.0, 2.0])
        np.testing.assert_allclose(np.asarray(out), [1.0, 2.0])
        with pytest.raises(Exception, match="[Tt]ransfer"):
            without_exempt([1.0, 2.0])
    assert sanitizers.exempt(None) is None


# --------------------------------------------------------------------------
# sanitizers: use-after-donate poisoning


def _small_buffer():
    return UpdateBuffer.from_stacked(
        {"w": jnp.ones((3, 2, 2), jnp.float32)})


def test_poison_donated_buffer_raises_on_leaf_access():
    buf = _small_buffer()
    sanitizers.poison_donated(buf)
    assert type(buf).__simdc_donated__
    assert isinstance(buf, UpdateBuffer)  # still the same nominal type
    with pytest.raises(sanitizers.UseAfterDonateError):
        buf.leaves2d
    with pytest.raises(sanitizers.UseAfterDonateError):
        buf.materialize_row(0)
    # Layout metadata stays readable — only the dead leaves are fenced.
    assert buf.num_rows == 3
    assert buf.row_nbytes == 16


def test_poison_donated_is_idempotent_and_caches_classes():
    a, b = _small_buffer(), _small_buffer()
    sanitizers.poison_donated(a)
    cls = type(a)
    sanitizers.poison_donated(a)
    sanitizers.poison_donated(b)
    assert type(a) is cls and type(b) is cls


def test_donated_apply_invalidates_old_param_buffers():
    # Regression for the R001 fixes: the donated server-update jits carry
    # keep_unused=True, so donation genuinely consumes the old round's
    # global-params buffer instead of silently no-opping.
    params = {"w": jnp.ones((4,), jnp.float32)}
    old_leaf = params["w"]
    new = federation._APPLY_WEIGHTED_SUM_DONATED(
        params, (jnp.full((4,), 2.0, jnp.float32),),
        jax.device_put(np.float32(0.5)), jax.device_put(np.float32(1.0)))
    assert old_leaf.is_deleted()
    # w <- w + lr * (sum * inv_total - w) = 1 + (2*0.5 - 1) = 1
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0)


# --------------------------------------------------------------------------
# sanitizers: segment-leak audit and clock monotonicity


def test_segment_leak_audit_fires_at_pool_teardown():
    from repro.runtime.workers import FleetWorkerPool

    pool = FleetWorkerPool.__new__(FleetWorkerPool)
    pool._closed = False
    pool._workers = []
    pool._segments = {}
    pool._dead_owner_names = set()
    shm = shared_memory.SharedMemory(create=True, size=64)
    view = np.frombuffer(shm.buf, np.uint8)  # pins the mapping
    pool._to_close = [shm]
    try:
        with sanitizers.override(True):
            with pytest.raises(sanitizers.SegmentLeakError, match=shm.name):
                pool.close()
    finally:
        del view
        pool._drain_closes()
        shm.unlink()
    assert pool._to_close == []


def test_segment_leak_audit_silent_when_disabled():
    from repro.runtime.workers import FleetWorkerPool

    pool = FleetWorkerPool.__new__(FleetWorkerPool)
    pool._closed = False
    pool._workers = []
    pool._segments = {}
    pool._dead_owner_names = set()
    shm = shared_memory.SharedMemory(create=True, size=64)
    view = np.frombuffer(shm.buf, np.uint8)
    pool._to_close = [shm]
    try:
        with sanitizers.override(False):
            pool.close()  # leak tolerated (view may legitimately outlive)
        assert pool._to_close == [shm]
    finally:
        del view
        pool._drain_closes()
        shm.unlink()


def test_virtual_clock_past_schedule():
    clock = VirtualClock()
    clock.run_until(5.0)
    with sanitizers.override(True):
        with pytest.raises(sanitizers.ClockMonotonicityError):
            clock.schedule(1.0, lambda: None)
    with sanitizers.override(False):
        clock.schedule(1.0, lambda: None)  # clamped, not raised
    assert clock.next_time() == 5.0


# --------------------------------------------------------------------------
# R002 satellite: MetricsBus clock injection


def test_metrics_bus_requires_injected_clock_for_emit_now():
    bus = MetricsBus()
    with pytest.raises(RuntimeError, match="R002"):
        bus.emit_now("cloud", 1, "round_start")


def test_metrics_bus_stamps_virtual_time():
    clock = VirtualClock()
    clock.run_until(3.5)
    bus = MetricsBus.on_virtual_clock(clock)
    sink = InMemorySink()
    bus.subscribe(sink)
    bus.emit_now("cloud", 7, "aggregation", applied=4)
    ev = sink.latest(7, "aggregation")
    assert ev is not None
    assert ev.t == 3.5
    assert ev.values == {"applied": 4}
