"""Hybrid allocation ILP (paper Eq. 1): exactness, invariants, properties."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocation import (
    GradeRuntime,
    fixed_ratio_allocation,
    solve_allocation,
    solve_allocation_bruteforce,
)
from repro.core.task import GradeSpec


def mk(N, q=0, f=10, k=2, m=3):
    return GradeSpec("g", N, benchmarking_devices=q, logical_bundles=f,
                     bundles_per_device=k, physical_devices=m)


def test_all_logical_when_no_phones():
    spec = GradeSpec("g", 10, logical_bundles=10, bundles_per_device=1,
                     physical_devices=0)
    rt = GradeRuntime(alpha=2.0, beta=1.0, lam=1.0)
    res = solve_allocation([spec], [rt])
    assert res.per_grade[0].logical_devices == 10
    assert res.makespan == pytest.approx(2.0)  # ceil(10/10)*2


def test_all_physical_when_no_bundles():
    spec = GradeSpec("g", 9, logical_bundles=0, physical_devices=3)
    rt = GradeRuntime(alpha=2.0, beta=1.0, lam=0.5)
    res = solve_allocation([spec], [rt])
    assert res.per_grade[0].physical_devices == 9
    assert res.makespan == pytest.approx(math.ceil(9 / 3) * 1.0 + 0.5)


def test_infeasible_raises():
    spec = GradeSpec("g", 5, logical_bundles=0, physical_devices=0)
    rt = GradeRuntime(alpha=1.0, beta=1.0, lam=0.0)
    with pytest.raises(ValueError):
        solve_allocation([spec], [rt])


def test_benchmarking_devices_excluded():
    spec = mk(10, q=4)
    rt = GradeRuntime(alpha=1.0, beta=1.0, lam=0.0)
    res = solve_allocation([spec], [rt])
    g = res.per_grade[0]
    assert g.logical_devices + g.physical_devices == 6


grade_strategy = st.builds(
    lambda N, q, f, k, m: GradeSpec(
        "g", N, benchmarking_devices=min(q, N), logical_bundles=f,
        bundles_per_device=k, physical_devices=m),
    N=st.integers(0, 40), q=st.integers(0, 5), f=st.integers(1, 30),
    k=st.integers(1, 6), m=st.integers(1, 8),
)
runtime_strategy = st.builds(
    GradeRuntime,
    alpha=st.floats(0.1, 50, allow_nan=False),
    beta=st.floats(0.1, 50, allow_nan=False),
    lam=st.floats(0, 20, allow_nan=False),
)


@settings(max_examples=150, deadline=None)
@given(st.lists(st.tuples(grade_strategy, runtime_strategy),
                min_size=1, max_size=3))
def test_solver_matches_bruteforce(pairs):
    specs = [
        GradeSpec(f"g{i}", s.num_devices, s.benchmarking_devices,
                  s.logical_bundles, s.bundles_per_device, s.physical_devices)
        for i, (s, _) in enumerate(pairs)
    ]
    rts = [r for _, r in pairs]
    a = solve_allocation(specs, rts)
    b = solve_allocation_bruteforce(specs, rts)
    assert a.makespan == pytest.approx(b.makespan)
    assert a.total_logical == b.total_logical  # secondary objective too


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(grade_strategy, runtime_strategy),
                min_size=1, max_size=3),
       st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]))
def test_optimal_never_worse_than_fixed_ratio(pairs, frac):
    """Paper Fig. 7 claim as a property."""
    specs = [
        GradeSpec(f"g{i}", s.num_devices, s.benchmarking_devices,
                  s.logical_bundles, s.bundles_per_device, s.physical_devices)
        for i, (s, _) in enumerate(pairs)
    ]
    rts = [r for _, r in pairs]
    opt = solve_allocation(specs, rts)
    fixed = fixed_ratio_allocation(specs, rts, frac)
    assert opt.makespan <= fixed.makespan + 1e-9


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(grade_strategy, runtime_strategy),
                min_size=1, max_size=3))
def test_allocation_conserves_devices(pairs):
    specs = [
        GradeSpec(f"g{i}", s.num_devices, s.benchmarking_devices,
                  s.logical_bundles, s.bundles_per_device, s.physical_devices)
        for i, (s, _) in enumerate(pairs)
    ]
    rts = [r for _, r in pairs]
    res = solve_allocation(specs, rts)
    for spec, g in zip(specs, res.per_grade):
        n = spec.num_devices - spec.benchmarking_devices
        assert g.logical_devices + g.physical_devices == n
        assert 0 <= g.logical_devices <= n
        assert max(g.logical_time, g.physical_time) <= res.makespan + 1e-9
