"""Quantized wire format: int8 UpdateBuffers, fused dequantize-and-reduce,
error feedback, byte accounting, and the columnar compression transform."""
import os
import pickle
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.deviceflow import ArrivalBatch, Delivery, DeviceFlow, Message
from repro.core.devicemodel import GRADES
from repro.core.federation import (
    AggregationService,
    ClientCountTrigger,
    SampleThresholdTrigger,
    fedavg_delta,
)
from repro.core.simulation import DeviceTier, HybridSimulation, LogicalTier
from repro.core.strategies import AccumulatedStrategy
from repro.core.updates import (
    UpdateBuffer,
    dequantize_rows,
    quantize_rows,
)
from repro.kernels.fed_reduce.ops import fed_reduce
from repro.kernels.fed_reduce.ref import fed_reduce_ref
from repro.models import ctr as ctr_lib
from repro.optim.compression import (
    payload_bytes,
    topk_compress,
    topk_compress_rows,
    topk_init,
)


# --------------------------------------------------------------------------- #
# Fused dequantize-and-reduce vs explicit dequantize-then-reduce
# --------------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 24), d=st.integers(1, 300), seed=st.integers(0, 9999),
       use_bf16=st.integers(0, 1), weight_scale=st.floats(0.1, 50.0),
       impl=st.sampled_from(["ref", "pallas_interpret"]))
def test_fused_int8_reduce_matches_dequantize_then_reduce(
        n, d, seed, use_bf16, weight_scale, impl):
    """Property: folding per-row scales into the weight vector reproduces
    quantize -> dequantize -> fed_reduce_ref exactly (both accumulate f32)
    across source dtypes, weights, and kernel impls."""
    rng = np.random.default_rng(seed)
    src_dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    x = jnp.asarray(rng.standard_normal((n, d)) * 3.0, src_dtype)
    w = jnp.asarray(rng.random(n) * weight_scale + 1e-3, jnp.float32)

    (q,), (s,), _ = quantize_rows([x])
    want = fed_reduce_ref(dequantize_rows([q], [s])[0], w)
    got = fed_reduce(q, w, scales=s, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_quantize_rows_error_feedback_residual_identity():
    """residual = x - dequantize(quantize(x)) exactly, so deq + res == x."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, 33)), jnp.float32)
    (q,), (s,), (res,) = quantize_rows([x], compute_residual=True)
    deq = dequantize_rows([q], [s])[0]
    np.testing.assert_array_equal(np.asarray(deq + res), np.asarray(x))
    # Quantization error is bounded by half a step per entry.
    bound = np.broadcast_to(np.asarray(s)[:, None] * 0.5 + 1e-7, res.shape)
    np.testing.assert_array_less(np.abs(np.asarray(res)), bound)


def test_fed_reduce_rejects_mismatched_scales():
    stack = jnp.zeros((4, 8), jnp.int8)
    w = jnp.ones(4)
    with pytest.raises(ValueError, match="scales"):
        fed_reduce(stack, w, scales=jnp.ones(3), impl="ref")


def test_fed_reduce_mesh_int8_padding_rows_contribute_zero():
    """dp=4 sharded fused int8 reduce: rows not divisible by the shard count
    are zero-weight padded — folded scales must not resurrect them.  Runs in
    a subprocess because XLA_FLAGS must be set before jax initializes."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.updates import dequantize_rows, quantize_rows
        from repro.distribution.sharding import make_fleet_mesh
        from repro.kernels.fed_reduce.ops import fed_reduce

        assert len(jax.devices()) == 4, jax.devices()
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((10, 48)), jnp.float32)
        w = jnp.asarray(rng.random(10), jnp.float32)
        (q,), (s,), _ = quantize_rows([x])
        mesh = make_fleet_mesh(4)
        out = fed_reduce(q, w, scales=s, impl="ref", mesh=mesh)
        ref = jnp.tensordot(w * s, dequantize_rows([q], [s])[0] /
                            s[:, None], axes=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=1e-5)
        print("MESH_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "MESH_OK" in proc.stdout


# --------------------------------------------------------------------------- #
# int8 UpdateBuffer: footprint, materialization, checkpoint round-trip
# --------------------------------------------------------------------------- #
def test_quantized_buffer_reports_wire_footprint():
    stacked = {"w": jnp.ones((4, 512), jnp.float32),
               "b": jnp.ones((4, 3), jnp.float32)}
    f32 = UpdateBuffer.from_stacked(stacked)
    q = UpdateBuffer.quantized_from_stacked(stacked)
    assert f32.row_nbytes == (512 + 3) * 4
    # int8 row = 1 byte/elem + one f32 scale per leaf.
    assert q.row_nbytes == (512 + 4) + (3 + 4)
    assert f32.row_nbytes / q.row_nbytes > 3.9
    assert "wire='int8'" in repr(q)
    # The ArrivalBatch nbytes column picks the quantized footprint up
    # automatically via the row_nbytes default.
    batch = ArrivalBatch(0, 0, rows=np.arange(4), buffer=q)
    assert batch.total_bytes == 4 * q.row_nbytes


def test_quantized_buffer_materializes_dequantized():
    rng = np.random.default_rng(1)
    stacked = {"w": jnp.asarray(rng.standard_normal((3, 4, 8)), jnp.float32)}
    q = UpdateBuffer.quantized_from_stacked(stacked)
    out = q.materialize()
    assert out["w"].shape == (3, 4, 8) and out["w"].dtype == np.float32
    # Max error = half a quantization step.
    step = np.abs(np.asarray(stacked["w"]).reshape(3, -1)).max(1) / 127
    err = np.abs(out["w"] - np.asarray(stacked["w"])).reshape(3, -1).max(1)
    np.testing.assert_array_less(err, step * 0.51)
    row = q.materialize_row(1)
    np.testing.assert_array_equal(row["w"], out["w"][1])
    assert q.handle(1).nbytes == q.row_nbytes


def test_quantized_buffer_state_dict_roundtrip():
    rng = np.random.default_rng(2)
    stacked = {"w": jnp.asarray(rng.standard_normal((3, 5)), jnp.float32)}
    q = UpdateBuffer.quantized_from_stacked(stacked)
    d = pickle.loads(pickle.dumps(q.state_dict()))
    restored = UpdateBuffer.from_state_dict(d)
    assert restored.wire == "int8"
    assert restored.row_nbytes == q.row_nbytes
    np.testing.assert_array_equal(np.asarray(restored.materialize()["w"]),
                                  np.asarray(q.materialize()["w"]))
    # f32 snapshots from older checkpoints (no "wire" key) still load.
    f32d = UpdateBuffer.from_stacked(stacked).state_dict()
    f32d.pop("wire", None)
    assert UpdateBuffer.from_state_dict(f32d).wire == "f32"


def test_quantized_batch_survives_deviceflow_checkpoint():
    """A shelved quantized ArrivalBatch round-trips through the flow's
    state_dict: scales come back and deliveries dequantize correctly."""
    got = []
    flow = DeviceFlow(got.append)
    flow.register_task(0, AccumulatedStrategy(thresholds=(3,)))
    stacked = {"w": jnp.asarray([[2.0], [4.0]])}
    q = UpdateBuffer.quantized_from_stacked(stacked)
    flow.submit_batch(ArrivalBatch(0, 0, rows=np.arange(2), buffer=q),
                      ts=np.full(2, 1.0))
    assert flow.shelf(0).total_bytes_received == 2 * q.row_nbytes

    restored = DeviceFlow(got.append)
    restored.register_task(0, AccumulatedStrategy(thresholds=(3,)))
    restored.load_state_dict(pickle.loads(pickle.dumps(flow.state_dict())))
    restored.submit(Message(0, 9, 0, {"w": np.array([6.0])}), t=2.0)
    restored.run(10.0)
    rows = [np.asarray(jax.tree.leaves(
        d.batch.buffer.materialize_row(int(r)) if d.batch is not None
        else d.message.payload)[0]).reshape(-1)[0]
        for d in got for r in (d.batch.rows if d.batch is not None else [0])]
    np.testing.assert_allclose(sorted(rows), [2.0, 4.0, 6.0], atol=0.05)


# --------------------------------------------------------------------------- #
# Aggregation over quantized buffers
# --------------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 10), seed=st.integers(0, 9999),
       streaming=st.integers(0, 1))
def test_service_aggregates_quantized_batch_like_host_reference(
        n, seed, streaming):
    """Property: fused aggregation of an int8 batch equals the host
    ``fedavg_delta`` over the dequantized updates (fused vs streaming)."""
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.standard_normal((n, 4, 8)), jnp.float32),
               "b": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)}
    global_params = {
        "w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal(3), jnp.float32),
    }
    counts = rng.integers(1, 6, n)
    q = UpdateBuffer.quantized_from_stacked(stacked)
    want = fedavg_delta(
        global_params,
        [q.materialize_row(i) for i in range(n)], counts.tolist())

    svc = AggregationService(jax.tree.map(jnp.array, global_params),
                             trigger=ClientCountTrigger(n),
                             streaming=bool(streaming))
    svc(Delivery(t=0.0, batch=ArrivalBatch(
        0, 0, rows=np.arange(n), num_samples=counts, buffer=q)))
    assert len(svc.history) == 1
    for a, b in zip(jax.tree.leaves(svc.global_params),
                    jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_zero_weights_uniform_fallback_with_quantized_buffer():
    """All-zero staleness weights must hit the uniform fallback with the
    scales folded in (mean of the dequantized rows, not garbage)."""
    stacked = {"w": jnp.asarray([[2.0], [4.0]])}
    q = UpdateBuffer.quantized_from_stacked(stacked)
    svc = AggregationService({"w": jnp.zeros(1)},
                             trigger=ClientCountTrigger(2),
                             staleness_discount=lambda s: 0.0)
    for i, h in enumerate(q.handles()):
        svc(Delivery(t=0.0, message=Message(0, i, 0, h, num_samples=i + 1)))
    assert len(svc.history) == 1
    np.testing.assert_allclose(np.asarray(svc.global_params["w"]), [3.0],
                               atol=0.05)


# --------------------------------------------------------------------------- #
# End-to-end: HybridSimulation wire="int8" with error feedback
# --------------------------------------------------------------------------- #
def _ctr_setup(n=12, rpd=8, dim=16):
    from repro.data.synthetic_ctr import make_federated_ctr
    data = make_federated_ctr(num_devices=n, records_per_device=rpd,
                              dim=dim, seed=0)
    local = ctr_lib.make_local_train_fn(lr=1e-2, epochs=2)
    params = ctr_lib.lr_init(jax.random.PRNGKey(0), dim)
    X, Y, counts = data.stacked_shards(np.arange(n), rpd)
    mask = (np.arange(rpd)[None] < counts[:, None]).astype(np.float32)
    batches = {"x": jnp.asarray(X), "y": jnp.asarray(Y),
               "mask": jnp.asarray(mask)}
    return local, params, batches, counts


def _run_rounds(wire, *, rounds=4, error_feedback=True):
    local, params, batches, counts = _ctr_setup()
    svc = AggregationService(
        jax.tree.map(jnp.array, params),
        trigger=SampleThresholdTrigger(int(counts.sum())))
    flow = DeviceFlow(svc)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    sim = HybridSimulation(LogicalTier(local, cohort_size=5),
                           DeviceTier(local, GRADES["High"], cohort_size=4),
                           deviceflow=flow, zero_copy=True, wire=wire,
                           error_feedback=error_feedback)
    for rnd in range(rounds):
        sim.run_round(0, rnd, svc.global_params, batches, counts, 12,
                      jax.random.PRNGKey(rnd))
        flow.run(1e9)
        svc.tick(flow.clock.now)
    return svc, flow


def test_int8_wire_round_cuts_bytes_and_tracks_f32():
    svc8, flow8 = _run_rounds("int8")
    svc32, flow32 = _run_rounds("f32")
    assert len(svc8.history) == len(svc32.history) == 4
    b8 = flow8.shelf(0).total_bytes_dispatched
    b32 = flow32.shelf(0).total_bytes_dispatched
    # The 17-param CTR model pays proportionally heavy per-leaf scale
    # overhead (even a scalar leaf carries a 4-byte scale); ~4x at realistic
    # leaf widths is the quantized_wire benchmark's gate, not this one's.
    assert b32 / b8 > 2.5, (b32, b8)
    # Error feedback keeps the quantized trajectory glued to f32.
    for a, b in zip(jax.tree.leaves(svc8.global_params),
                    jax.tree.leaves(svc32.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_error_feedback_carries_residuals_across_rounds():
    """The EF residual store fills per chunk and its entries change round
    over round (residuals are actually carried, not recomputed from zero)."""
    local, params, batches, counts = _ctr_setup()
    svc = AggregationService(
        jax.tree.map(jnp.array, params),
        trigger=SampleThresholdTrigger(int(counts.sum())))
    flow = DeviceFlow(svc)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    sim = HybridSimulation(LogicalTier(local, cohort_size=5),
                           DeviceTier(local, GRADES["High"], cohort_size=4),
                           deviceflow=flow, zero_copy=True, wire="int8")
    sim.run_round(0, 0, svc.global_params, batches, counts, 12,
                  jax.random.PRNGKey(0))
    flow.run(1e9)
    svc.tick(flow.clock.now)
    assert sim._ef_residuals  # one entry per cohort chunk
    snap = {k: [np.asarray(r) for r in v]
            for k, v in sim._ef_residuals.items()}
    sim.run_round(0, 1, svc.global_params, batches, counts, 12,
                  jax.random.PRNGKey(1))
    assert set(sim._ef_residuals) == set(snap)  # stable chunk keys
    changed = any(
        not np.array_equal(np.asarray(r), old)
        for k, v in sim._ef_residuals.items()
        for r, old in zip(v, snap[k]))
    assert changed

    off = HybridSimulation(LogicalTier(local, cohort_size=5),
                           DeviceTier(local, GRADES["High"], cohort_size=4),
                           zero_copy=True, wire="int8", error_feedback=False)
    off.run_round(0, 0, svc.global_params, batches, counts, 12,
                  jax.random.PRNGKey(0))
    assert not off._ef_residuals


def test_int8_wire_requires_zero_copy():
    local, *_ = _ctr_setup()
    with pytest.raises(ValueError, match="zero_copy"):
        HybridSimulation(LogicalTier(local, cohort_size=4),
                         DeviceTier(local, GRADES["High"]),
                         zero_copy=False, wire="int8")
    with pytest.raises(ValueError, match="wire"):
        HybridSimulation(LogicalTier(local, cohort_size=4),
                         DeviceTier(local, GRADES["High"]), wire="int4")


# --------------------------------------------------------------------------- #
# Columnar compression transform (payload_transform) + byte accounting
# --------------------------------------------------------------------------- #
def test_payload_transform_compresses_on_the_columnar_plane():
    """--compress-style transform: every arrival stays columnar (batches in,
    batches out), nbytes reflects the sparse wire size, aggregation runs."""
    local, params, batches, counts = _ctr_setup()
    svc = AggregationService(
        jax.tree.map(jnp.array, params),
        trigger=SampleThresholdTrigger(int(counts.sum())))
    flow = DeviceFlow(svc)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))

    seen = {"batches": 0, "messages": 0}

    def compress(e):
        if isinstance(e, ArrivalBatch) and e.buffer is not None:
            seen["batches"] += 1
            stacked = jax.tree.map(lambda l: l[np.asarray(e.rows)],
                                   e.buffer.materialize())
            kept, _, nnz = topk_compress_rows(stacked, None, fraction=0.3)
            return ArrivalBatch(
                e.task_id, e.round_idx, rows=np.arange(e.n),
                created_t=e.created_t, nbytes=np.maximum(nnz, 1) * 8,
                num_samples=e.num_samples, device_ids=e.device_ids,
                buffer=UpdateBuffer.from_stacked(kept))
        seen["messages"] += 1
        return e

    sim = HybridSimulation(LogicalTier(local, cohort_size=5),
                           DeviceTier(local, GRADES["High"], cohort_size=4),
                           deviceflow=flow, zero_copy=True,
                           payload_transform=compress)
    sim.run_round(0, 0, svc.global_params, batches, counts, 12,
                  jax.random.PRNGKey(0))
    flow.run(1e9)
    svc.tick(flow.clock.now)
    assert seen["batches"] >= 2 and len(svc.history) == 1
    dense_row = sum(  # f32 bytes of one uncompressed update row
        int(np.prod(np.asarray(l).shape)) * 4 for l in jax.tree.leaves(params))
    assert 0 < flow.shelf(0).total_bytes_dispatched < 12 * dense_row


def test_payload_bytes_counts_quantized_pair_and_scalars():
    q = {"w": np.zeros((4, 8), np.int8)}
    scales = {"w": np.zeros(4, np.float32)}
    assert payload_bytes(q) == 32
    assert payload_bytes((q, scales)) == 32 + 16  # scales ride the wire too
    assert payload_bytes((q, {"w": 0.5})) == 32 + 8  # python-scalar scale


def test_topk_stats_are_correct_and_single_sync():
    rng = np.random.default_rng(0)
    u = {"w": jnp.asarray(rng.standard_normal((20, 10)), jnp.float32)}
    kept, state, stats = topk_compress(u, topk_init(u), fraction=0.05)
    assert stats["total"] == 200
    assert stats["nonzero"] == int(np.count_nonzero(np.asarray(kept["w"])))
    assert stats["compression_ratio"] == pytest.approx(
        stats["total"] / stats["nonzero"])


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 8), d=st.integers(2, 60), seed=st.integers(0, 999),
       fraction=st.floats(0.05, 0.9))
def test_topk_rows_matches_scalar_topk_per_row(n, d, seed, fraction):
    """Property: the columnar per-row top-k equals running the scalar
    ``topk_compress`` on each row independently (no residual memory)."""
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    kept, res, nnz = topk_compress_rows(stacked, None, fraction=fraction)
    assert len(res) == 1 and res[0].shape == (n, d)
    for i in range(n):
        row = {"w": stacked["w"][i]}
        want, _, stats = topk_compress(row, topk_init(row),
                                       fraction=fraction)
        np.testing.assert_allclose(np.asarray(kept["w"][i]),
                                   np.asarray(want["w"]), atol=1e-7)
        assert int(nnz[i]) == stats["nonzero"]
    # Error-feedback identity: kept + residual == original.
    np.testing.assert_allclose(np.asarray(kept["w"]) + np.asarray(res[0]),
                               np.asarray(stacked["w"]), atol=1e-6)


def test_topk_rows_restarts_on_layout_change():
    stacked = {"w": jnp.ones((3, 8))}
    _, res, _ = topk_compress_rows(stacked, None, fraction=0.5)
    other = {"w": jnp.ones((4, 8))}
    kept, res2, _ = topk_compress_rows(other, res, fraction=0.5)
    assert res2[0].shape == (4, 8)  # stale residual dropped, not crashed
