"""Monitoring bus + data-pipeline coverage."""
import jax.numpy as jnp
import numpy as np

from repro.core.deviceflow import DeviceFlow, Message, Delivery
from repro.core.federation import AggregationService, ClientCountTrigger
from repro.core.monitoring import (
    InMemorySink, MetricEvent, MetricsBus, TaskMonitor,
    wire_aggregation_service,
)
from repro.core.strategies import AccumulatedStrategy
from repro.data.partition import (
    dirichlet_partition, iid_partition, label_skew_partition,
)
from repro.data.tokens import TokenPipeline


def test_monitor_aggregation_feed():
    bus = MetricsBus()
    svc = AggregationService({"w": jnp.zeros(2)},
                             trigger=ClientCountTrigger(2))
    wire_aggregation_service(bus, svc, task_id=7)
    mon = TaskMonitor(bus, task_id=7)
    flow = DeviceFlow(svc)
    flow.register_task(7, AccumulatedStrategy(thresholds=(1,)))
    for i in range(4):
        flow.submit(Message(7, i, 0, {"w": jnp.ones(2)}, num_samples=5))
    s = mon.summary()
    assert s["aggregations"] == 2
    assert s["clients_aggregated"] == 4
    assert "aggregations" in mon.to_json()


def test_monitor_filters_other_tasks():
    bus = MetricsBus()
    mon = TaskMonitor(bus, task_id=1)
    bus.emit(MetricEvent(0.0, "cloud", 2, "aggregation", {"num_clients": 3}))
    bus.emit(MetricEvent(0.0, "cloud", 1, "aggregation", {"num_clients": 5}))
    assert mon.summary()["clients_aggregated"] == 5


def test_token_pipeline_determinism_and_restart():
    p1 = TokenPipeline(vocab_size=512, seq_len=16, batch_size=4, seed=3)
    b1 = [next(p1) for _ in range(3)]
    state = p1.state_dict()
    b_next = next(p1)
    # Restore into a fresh pipeline -> identical continuation.
    p2 = TokenPipeline(vocab_size=512, seq_len=16, batch_size=4, seed=3)
    p2.load_state_dict(state)
    b_next2 = next(p2)
    np.testing.assert_array_equal(b_next.tokens, b_next2.tokens)
    # Different hosts draw different streams.
    ph = TokenPipeline(vocab_size=512, seq_len=16, batch_size=4, seed=3,
                       host_id=1, num_hosts=2)
    assert not np.array_equal(next(ph).tokens, b1[0].tokens)
    assert b1[0].tokens.max() < 512 and b1[0].tokens.min() >= 0


def test_partitioners_cover_all_records():
    labels = np.random.default_rng(0).integers(0, 2, 1000).astype(np.float32)
    for parts in (
        iid_partition(1000, 10),
        label_skew_partition(labels, 10),
        dirichlet_partition(labels, 10, alpha=0.5),
    ):
        assert len(parts) == 10
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(allidx)  # no duplicates
        assert len(allidx) >= 900  # near-total coverage


def test_label_skew_creates_noniid():
    labels = np.random.default_rng(0).integers(0, 2, 2000).astype(np.float32)
    parts = label_skew_partition(labels, 10, frac_positive_heavy=0.7,
                                 heavy_pos_share=0.8)
    rates = [labels[p].mean() for p in parts if len(p)]
    assert max(rates) - min(rates) > 0.3  # heavy vs light devices differ
