"""DeviceFlow: dispatch strategies, conservation, fidelity, checkpointing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.deviceflow import Delivery, DeviceFlow, Message
from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchPoint,
    TimeIntervalStrategy,
    TimePointStrategy,
    discretize_curve,
)
from repro.core.traffic_curves import TrafficCurve, right_tailed_normal, table2_curves


def collect():
    out = []
    return out, out.append


def msgs(n, task_id=0):
    return [Message(task_id, i, 0, payload=i) for i in range(n)]


def test_accumulated_threshold_cycles():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(2, 3)))
    for m in msgs(10):
        flow.submit(m)
    # cycle 2,3,2,3 -> all 10 dispatched
    assert len(got) == 10
    assert flow.conservation_ok(0)


def test_accumulated_realtime_is_immediate():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow.submit(msgs(1)[0])
    assert len(got) == 1


def test_accumulated_dropout_probability():
    got, sink = collect()
    flow = DeviceFlow(sink, seed=42)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,), failure_prob=0.5))
    for m in msgs(2000):
        flow.submit(m)
    frac = len(got) / 2000
    assert 0.42 < frac < 0.58
    assert flow.conservation_ok(0)


def test_time_point_dispatch_order_and_counts():
    got, sink = collect()
    flow = DeviceFlow(sink)
    strat = TimePointStrategy(points=(
        DispatchPoint(t=1.0, count=3),
        DispatchPoint(t=5.0, count=2),
    ))
    flow.register_task(0, strat)
    for m in msgs(5):
        flow.submit(m)
    flow.round_complete(0)
    flow.run()
    assert [d.t for d in got] == [1.0] * 3 + [5.0] * 2
    # FIFO within shelf
    assert [d.message.device_id for d in got] == list(range(5))
    assert flow.conservation_ok(0)


def test_time_interval_strategy_end_to_end():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, TimeIntervalStrategy(
        curve=right_tailed_normal(1.0), interval=30.0))
    for m in msgs(500):
        flow.submit(m)
    flow.round_complete(0)
    flow.run()
    assert len(got) == 500
    assert flow.conservation_ok(0)
    ts = np.array([d.t for d in got])
    assert (np.diff(ts) >= -1e-9).all()  # time-ordered


def test_independent_tasks_do_not_interfere():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(5,)))
    flow.register_task(1, AccumulatedStrategy(thresholds=(1,)))
    flow.submit(Message(1, 0, 0, payload="x"))
    assert len(got) == 1  # task 1 realtime, task 0 untouched
    for m in msgs(4, task_id=0):
        flow.submit(m)
    assert len(got) == 1  # below threshold
    flow.submit(Message(0, 99, 0, payload="y"))
    assert len(got) == 6


def test_created_t_stamping_sentinel():
    """Unstamped messages (created_t=None) are stamped at submit time; a
    producer-stamped ``created_t`` is preserved verbatim — including 0.0,
    which the old ``== 0.0`` sentinel silently re-stamped at t>0, corrupting
    latency accounting."""
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow.clock.now = 5.0
    flow.submit(Message(0, 0, 0, payload="unstamped"))
    assert got[-1].message.created_t == 5.0
    flow.submit(Message(0, 1, 0, payload="stamped-at-zero", created_t=0.0))
    assert got[-1].message.created_t == 0.0  # producer stamp survives t>0
    # Bulk path: same contract, arrival times stamp only unstamped messages.
    flow.submit_many(
        [Message(0, 2, 0, payload="bulk-unstamped"),
         Message(0, 3, 0, payload="bulk-stamped", created_t=0.0)],
        ts=[7.0, 8.0])
    by_dev = {d.message.device_id: d.message for d in got}
    assert by_dev[2].created_t == 7.0
    assert by_dev[3].created_t == 0.0
    # Submitting at t=0 stamps an explicit 0.0 (no longer "unstamped").
    flow2 = DeviceFlow(sink)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow2.submit(Message(0, 4, 0, payload="at-zero"))
    assert got[-1].message.created_t == 0.0


def test_shelf_checkpoint_roundtrip():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    for m in msgs(7):
        flow.submit(m)
    state = flow.state_dict()
    flow2 = DeviceFlow(sink)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    flow2.load_state_dict(state)
    assert len(flow2.shelf(0)) == 7
    assert flow2.shelf(0).total_received == 7


@settings(max_examples=60, deadline=None)
@given(
    n_msgs=st.integers(0, 300),
    thresholds=st.lists(st.integers(1, 17), min_size=1, max_size=4),
    p=st.floats(0.0, 1.0),
)
def test_conservation_property(n_msgs, thresholds, p):
    """received == dispatched + dropped + pending, always."""
    got, sink = collect()
    flow = DeviceFlow(sink, seed=1)
    flow.register_task(0, AccumulatedStrategy(
        thresholds=tuple(thresholds), failure_prob=p))
    for m in msgs(n_msgs):
        flow.submit(m)
    assert flow.conservation_ok(0)
    s = flow.shelf(0)
    assert s.total_dispatched == len(got)


@settings(max_examples=30, deadline=None)
@given(
    total=st.integers(1, 20000),
    interval=st.floats(1.0, 600.0),
    cap=st.floats(10.0, 2000.0),
)
def test_discretize_conserves_mass_and_respects_capacity(total, interval, cap):
    curve = right_tailed_normal(1.5)
    pts = discretize_curve(curve, total, interval, cap)
    counts = [c for _, c in pts]
    assert sum(counts) == total
    if len(pts) >= 2:
        dt = pts[1][0] - pts[0][0]
        assert max(counts) <= max(1, int(cap * dt)) + 1e-9


def test_table2_fidelity_all_curves():
    """Paper Table II: Pearson r > 0.99 for every evaluated curve."""
    for curve in table2_curves():
        pts = discretize_curve(curve, 6000, 60.0, 700.0)
        pts = [(t, c) for t, c in pts if t < 60.0]  # spill ticks excluded
        ts = np.array([t for t, _ in pts])
        cs = np.array([c for _, c in pts], dtype=float)
        span = curve.hi - curve.lo
        dt = ts[1] - ts[0] if len(ts) > 1 else 0.0
        # counts are per-tick integrals: compare against tick MIDPOINTS
        ref = np.array([curve(curve.lo + (t + dt / 2) / 60.0 * span)
                        for t in ts])
        r = np.corrcoef(cs, ref)[0, 1]
        assert r > 0.99, (curve.name, r)
