"""DeviceFlow: dispatch strategies, conservation, fidelity, checkpointing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.deviceflow import Delivery, DeviceFlow, Message
from repro.core.strategies import (
    AccumulatedStrategy,
    DispatchPoint,
    TimeIntervalStrategy,
    TimePointStrategy,
    discretize_curve,
)
from repro.core.traffic_curves import TrafficCurve, right_tailed_normal, table2_curves


def collect():
    out = []
    return out, out.append


def msgs(n, task_id=0):
    return [Message(task_id, i, 0, payload=i) for i in range(n)]


def test_accumulated_threshold_cycles():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(2, 3)))
    for m in msgs(10):
        flow.submit(m)
    # cycle 2,3,2,3 -> all 10 dispatched
    assert len(got) == 10
    assert flow.conservation_ok(0)


def test_accumulated_realtime_is_immediate():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow.submit(msgs(1)[0])
    assert len(got) == 1


def test_accumulated_dropout_probability():
    got, sink = collect()
    flow = DeviceFlow(sink, seed=42)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,), failure_prob=0.5))
    for m in msgs(2000):
        flow.submit(m)
    frac = len(got) / 2000
    assert 0.42 < frac < 0.58
    assert flow.conservation_ok(0)


def test_time_point_dispatch_order_and_counts():
    got, sink = collect()
    flow = DeviceFlow(sink)
    strat = TimePointStrategy(points=(
        DispatchPoint(t=1.0, count=3),
        DispatchPoint(t=5.0, count=2),
    ))
    flow.register_task(0, strat)
    for m in msgs(5):
        flow.submit(m)
    flow.round_complete(0)
    flow.run()
    assert [d.t for d in got] == [1.0] * 3 + [5.0] * 2
    # FIFO within shelf
    assert [d.message.device_id for d in got] == list(range(5))
    assert flow.conservation_ok(0)


def test_time_interval_strategy_end_to_end():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, TimeIntervalStrategy(
        curve=right_tailed_normal(1.0), interval=30.0))
    for m in msgs(500):
        flow.submit(m)
    flow.round_complete(0)
    flow.run()
    assert len(got) == 500
    assert flow.conservation_ok(0)
    ts = np.array([d.t for d in got])
    assert (np.diff(ts) >= -1e-9).all()  # time-ordered


def test_independent_tasks_do_not_interfere():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(5,)))
    flow.register_task(1, AccumulatedStrategy(thresholds=(1,)))
    flow.submit(Message(1, 0, 0, payload="x"))
    assert len(got) == 1  # task 1 realtime, task 0 untouched
    for m in msgs(4, task_id=0):
        flow.submit(m)
    assert len(got) == 1  # below threshold
    flow.submit(Message(0, 99, 0, payload="y"))
    assert len(got) == 6


def test_created_t_stamping_sentinel():
    """Unstamped messages (created_t=None) are stamped at submit time; a
    producer-stamped ``created_t`` is preserved verbatim — including 0.0,
    which the old ``== 0.0`` sentinel silently re-stamped at t>0, corrupting
    latency accounting."""
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow.clock.now = 5.0
    flow.submit(Message(0, 0, 0, payload="unstamped"))
    assert got[-1].message.created_t == 5.0
    flow.submit(Message(0, 1, 0, payload="stamped-at-zero", created_t=0.0))
    assert got[-1].message.created_t == 0.0  # producer stamp survives t>0
    # Bulk path: same contract, arrival times stamp only unstamped messages.
    flow.submit_many(
        [Message(0, 2, 0, payload="bulk-unstamped"),
         Message(0, 3, 0, payload="bulk-stamped", created_t=0.0)],
        ts=[7.0, 8.0])
    by_dev = {d.message.device_id: d.message for d in got}
    assert by_dev[2].created_t == 7.0
    assert by_dev[3].created_t == 0.0
    # Submitting at t=0 stamps an explicit 0.0 (no longer "unstamped").
    flow2 = DeviceFlow(sink)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    flow2.submit(Message(0, 4, 0, payload="at-zero"))
    assert got[-1].message.created_t == 0.0


def test_shelf_checkpoint_roundtrip():
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    for m in msgs(7):
        flow.submit(m)
    state = flow.state_dict()
    flow2 = DeviceFlow(sink)
    flow2.register_task(0, AccumulatedStrategy(thresholds=(100,)))
    flow2.load_state_dict(state)
    assert len(flow2.shelf(0)) == 7
    assert flow2.shelf(0).total_received == 7


@settings(max_examples=60, deadline=None)
@given(
    n_msgs=st.integers(0, 300),
    thresholds=st.lists(st.integers(1, 17), min_size=1, max_size=4),
    p=st.floats(0.0, 1.0),
)
def test_conservation_property(n_msgs, thresholds, p):
    """received == dispatched + dropped + pending, always."""
    got, sink = collect()
    flow = DeviceFlow(sink, seed=1)
    flow.register_task(0, AccumulatedStrategy(
        thresholds=tuple(thresholds), failure_prob=p))
    for m in msgs(n_msgs):
        flow.submit(m)
    assert flow.conservation_ok(0)
    s = flow.shelf(0)
    assert s.total_dispatched == len(got)


@settings(max_examples=30, deadline=None)
@given(
    total=st.integers(1, 20000),
    interval=st.floats(1.0, 600.0),
    cap=st.floats(10.0, 2000.0),
)
def test_discretize_conserves_mass_and_respects_capacity(total, interval, cap):
    curve = right_tailed_normal(1.5)
    pts = discretize_curve(curve, total, interval, cap)
    counts = [c for _, c in pts]
    assert sum(counts) == total
    if len(pts) >= 2:
        dt = pts[1][0] - pts[0][0]
        assert max(counts) <= max(1, int(cap * dt)) + 1e-9


def test_table2_fidelity_all_curves():
    """Paper Table II: Pearson r > 0.99 for every evaluated curve."""
    for curve in table2_curves():
        pts = discretize_curve(curve, 6000, 60.0, 700.0)
        pts = [(t, c) for t, c in pts if t < 60.0]  # spill ticks excluded
        ts = np.array([t for t, _ in pts])
        cs = np.array([c for _, c in pts], dtype=float)
        span = curve.hi - curve.lo
        dt = ts[1] - ts[0] if len(ts) > 1 else 0.0
        # counts are per-tick integrals: compare against tick MIDPOINTS
        ref = np.array([curve(curve.lo + (t + dt / 2) / 60.0 * span)
                        for t in ts])
        r = np.corrcoef(cs, ref)[0, 1]
        assert r > 0.99, (curve.name, r)


# --------------------------------------------------------------------------- #
# Columnar message plane: ArrivalBatch end-to-end through the same Shelf /
# Dispatcher machinery as scalar Messages.
# --------------------------------------------------------------------------- #
from repro.core.deviceflow import ArrivalBatch  # noqa: E402


def batch(rows, task_id=0, dev0=0, nbytes=16, created_t=None, round_idx=0):
    """Metadata-only batch (no UpdateBuffer): fine for transport tests."""
    return ArrivalBatch(
        task_id, round_idx, rows=np.arange(rows, dtype=np.int32),
        created_t=created_t, nbytes=np.full(rows, nbytes, np.int64),
        device_ids=np.arange(dev0, dev0 + rows, dtype=np.int64))


def flat_deliveries(got):
    """Every delivery flattened to (t, device_id) rows, in order."""
    out = []
    for d in got:
        if d.batch is not None:
            out.extend((d.t, int(dev)) for dev in d.batch.device_ids)
        else:
            out.append((d.t, d.message.device_id))
    return out


def test_batch_dispatch_matches_scalar_plane_exactly():
    """Dispatch-group membership and threshold-crossing timestamps of a
    columnar submit must equal the same rows submitted as per-device
    Messages — the batch plane is an encoding change, not a semantics
    change."""
    ts = np.array([2.0, 2.0, 3.0, 5.0, 5.0, 5.0, 9.0])
    # Scalar reference.
    got_s, sink_s = collect()
    flow_s = DeviceFlow(sink_s)
    flow_s.register_task(0, AccumulatedStrategy(thresholds=(3, 2)))
    flow_s.submit_many([Message(0, i, 0, payload=None, size_bytes=16)
                        for i in range(7)], ts=ts)
    # Columnar: rows 0-4 as one batch, 5-6 as scalars, one mixed call.
    got_b, sink_b = collect()
    flow_b = DeviceFlow(sink_b)
    flow_b.register_task(0, AccumulatedStrategy(thresholds=(3, 2)))
    flow_b.submit_arrivals(
        [batch(5), Message(0, 5, 0, payload=None, size_bytes=16),
         Message(0, 6, 0, payload=None, size_bytes=16)], ts=ts)
    assert flat_deliveries(got_b) == flat_deliveries(got_s)
    for flow in (flow_s, flow_b):
        flow.round_complete(0)
        flow.run()
        assert flow.conservation_ok(0)
    s_s, s_b = flow_s.shelf(0), flow_b.shelf(0)
    assert s_b.total_received == s_s.total_received == 7
    assert s_b.total_bytes_received == s_s.total_bytes_received == 7 * 16
    assert s_b.total_bytes_dispatched == s_s.total_bytes_dispatched


def test_batch_created_t_nan_sentinel():
    """NaN is the columnar unstamped sentinel (scalar plane: None): NaN rows
    stamp with their arrival time at submit; producer stamps — including
    0.0 — survive verbatim."""
    got, sink = collect()
    flow = DeviceFlow(sink)
    flow.register_task(0, AccumulatedStrategy(thresholds=(1,)))
    created = np.array([np.nan, 0.0, 1.5])
    flow.submit_batch(batch(3, created_t=created), ts=[7.0, 8.0, 9.0])
    stamps = {d.message.device_id: d.message.created_t for d in got}
    assert stamps[0] == 7.0   # unstamped -> arrival time
    assert stamps[1] == 0.0   # producer stamp at zero survives t>0
    assert stamps[2] == 1.5   # ordinary producer stamp survives
    # Original batch columns are never mutated in place.
    assert np.isnan(created[0])


def test_batch_state_roundtrip_mid_threshold():
    """Snapshot with a partially-consumed batch group on the shelf restores
    to the identical delivery timeline."""
    def run(flow, got, snapshot_after=None):
        flow.register_task(0, AccumulatedStrategy(thresholds=(4,)))
        flow.submit_arrivals([batch(3), batch(2, dev0=3)],
                             ts=[1.0, 2.0, 3.0, 4.0, 5.0])
        state = flow.state_dict() if snapshot_after is not None else None
        return state

    got_a, sink_a = collect()
    flow_a = DeviceFlow(sink_a)
    state = run(flow_a, got_a, snapshot_after=True)
    # 4-threshold crossed once: 4 rows delivered, 1 row still shelved.
    assert len(flat_deliveries(got_a)) == 4
    assert len(flow_a.shelf(0)) == 1

    got_b, sink_b = collect()
    flow_b = DeviceFlow(sink_b)
    flow_b.register_task(0, AccumulatedStrategy(thresholds=(4,)))
    flow_b.load_state_dict(state)
    assert len(flow_b.shelf(0)) == 1
    # Continue both flows identically: 3 more rows -> second crossing.
    for flow in (flow_a, flow_b):
        flow.submit_batch(batch(3, dev0=5), ts=[6.0, 7.0, 8.0])
    assert flat_deliveries(got_b) == flat_deliveries(got_a)[4:]
    assert flow_b.conservation_ok(0)


def test_batch_failure_prob_conservation():
    got, sink = collect()
    flow = DeviceFlow(sink, seed=3)
    flow.register_task(0, AccumulatedStrategy(
        thresholds=(1,), failure_prob=0.5))
    for i in range(20):
        flow.submit_batch(batch(100, dev0=100 * i))
    n_delivered = len(flat_deliveries(got))
    s = flow.shelf(0)
    assert flow.conservation_ok(0)
    assert s.total_received == 2000
    assert s.total_dispatched == n_delivered
    assert s.total_dropped == 2000 - n_delivered
    assert 0.42 < n_delivered / 2000 < 0.58


@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(("scalar", "batch", "round")),
                  st.integers(1, 9)),
        min_size=0, max_size=25),
    thresholds=st.lists(st.integers(1, 7), min_size=1, max_size=3),
    p=st.floats(0.0, 1.0),
)
def test_interleaved_plane_conservation_property(ops, thresholds, p):
    """Any interleaving of scalar submits, columnar batch submits, and
    round_completes conserves rows across both planes; with no failures it
    conserves bytes exactly (every row weighs 16 bytes here, so pending
    bytes are 16 * pending rows)."""
    got, sink = collect()
    flow = DeviceFlow(sink, seed=11)
    flow.register_task(0, AccumulatedStrategy(
        thresholds=tuple(thresholds), failure_prob=p))
    dev = 0
    sent_rows = 0
    for kind, k in ops:
        if kind == "scalar":
            flow.submit_many([Message(0, dev + i, 0, payload=None,
                                      size_bytes=16) for i in range(k)])
            dev += k
            sent_rows += k
        elif kind == "batch":
            flow.submit_batch(batch(k, dev0=dev, nbytes=16))
            dev += k
            sent_rows += k
        else:
            flow.round_complete(0)
            flow.run()
    s = flow.shelf(0)
    assert flow.conservation_ok(0)
    assert s.total_received == sent_rows
    assert s.total_bytes_received == 16 * sent_rows
    assert s.total_dispatched == len(flat_deliveries(got))
    if p == 0.0:
        # Byte conservation: received == dispatched + still-pending.
        assert s.total_bytes_received == \
            s.total_bytes_dispatched + 16 * len(s)
