"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import choose_mesh_plan, padded_vocab
from repro.configs.registry import get_config, lm_arch_ids
from repro.models.registry import get_model


def make_batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.d_model)) * 0.01,
            jnp.bfloat16)
    if cfg.family == "audio":
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)) * 0.01, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", lm_arch_ids())
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        api.loss_fn, has_aux=True)(params, batch, cfg)
    assert np.isfinite(float(loss))
    # At random init, loss ~= ln(vocab).
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", lm_arch_ids())
def test_smoke_logits_shape(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    if cfg.family == "audio":
        from repro.models import encdec
        mem = encdec.encode(params, batch["src_embeds"], cfg)
        logits = encdec.decode_train(params, batch["tokens"], mem, cfg)
        assert logits.shape == (b, s, padded_vocab(cfg.vocab_size))
    else:
        logits, _ = api.apply(params, batch["tokens"], cfg,
                              **({"prefix_embeds": batch["prefix_embeds"]}
                                 if cfg.family == "vlm" else {}))
        expect_s = s + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        assert logits.shape == (b, expect_s, padded_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["phi3_medium_14b", "mamba2_1_3b",
                                  "zamba2_1_2b", "granite_moe_3b_a800m"])
def test_prefill_decode_matches_full_forward(arch):
    """Greedy continuation via prefill+decode equals full-sequence forward."""
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, attention_impl="einsum")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s + 1)), jnp.int32)
    # Full forward logits at position s-1 predict token s.
    logits_full, _ = api.apply(params, toks, cfg)
    want = logits_full[:, s - 1, : cfg.vocab_size]
    # Prefill on first s tokens -> same logits for the next token.
    out = api.prefill(params, toks[:, :s], cfg, s + 8)
    logits_pre = out[0][:, : cfg.vocab_size]
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(want), atol=0.1, rtol=0.1)
    # One decode step consumes token s and matches full forward at position s.
    logits_dec, _ = api.decode_step(params, toks[:, s], cfg, out[1])
    want2 = logits_full[:, s, : cfg.vocab_size]
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, : cfg.vocab_size]), np.asarray(want2),
        atol=0.1, rtol=0.1)


def test_mesh_plans_cover_all_archs():
    for arch in lm_arch_ids():
        cfg = get_config(arch)
        plan = choose_mesh_plan(cfg)
        assert plan.tp * plan.sp == 16
        if cfg.family != "ssm":
            assert cfg.num_heads % plan.tp == 0
            assert (cfg.num_kv_heads % plan.tp == 0
                    or plan.tp % cfg.num_kv_heads == 0)


def test_param_counts_match_targets():
    """Config param counts sit near the published sizes (backbone-only for
    vlm/audio — the stubbed frontends carry the remaining params)."""
    targets = {
        "phi3_medium_14b": (13e9, 16e9),
        "llama3_2_3b": (3.0e9, 4.2e9),
        "qwen2_7b": (7e9, 8.5e9),
        "nemotron_4_15b": (14e9, 17e9),
        "zamba2_1_2b": (1.0e9, 1.4e9),
        "mamba2_1_3b": (1.2e9, 1.6e9),
        "granite_moe_3b_a800m": (3.0e9, 3.8e9),
        "phi3_5_moe_42b_a6_6b": (40e9, 44e9),
    }
    for arch, (lo, hi) in targets.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_fraction():
    cfg = get_config("phi3_5_moe_42b_a6_6b")
    act = cfg.active_params()
    assert 5e9 <= act <= 9e9  # "a6.6b"
