"""ResourceManager pool-accounting invariants (property-based).

The hybrid pool is mutated from many directions — admission freezes,
completion releases, elastic refreezes (up *and* down), and dynamic
``scale`` in all four flavors (grow, shrink, rejected shrink, reclaim
shrink).  The invariant that keeps every one of them honest is

    free + frozen == total        (per grade, per resource field)

including after *failed* operations: a rejected freeze/refreeze/scale must
leave the pool exactly as it found it (the PR 5 satellite fixed ``scale``
mutating ``logical_bundles`` before validating ``physical_devices``, and
``refreeze`` releasing before discovering the new grant didn't fit).
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import ResourceManager, ResourcePool

GRADES = ("High", "Mid", "Low")


def _snapshot(rm: ResourceManager):
    free, total = rm.free(), rm.total()
    return ({g: free.logical_bundles.get(g, 0) for g in GRADES},
            {g: free.physical_devices.get(g, 0) for g in GRADES},
            {g: total.logical_bundles.get(g, 0) for g in GRADES},
            {g: total.physical_devices.get(g, 0) for g in GRADES})


def _check_invariant(rm: ResourceManager, frozen_by_task: dict):
    free_b, free_p, tot_b, tot_p = _snapshot(rm)
    for g in GRADES:
        frozen_b = sum(d.get(g, (0, 0))[0] for d in frozen_by_task.values())
        frozen_p = sum(d.get(g, (0, 0))[1] for d in frozen_by_task.values())
        assert free_b[g] + frozen_b == tot_b[g], (g, "bundles")
        assert free_p[g] + frozen_p == tot_p[g], (g, "phones")
        assert tot_b[g] >= 0 and tot_p[g] >= 0
        # Only a reclaim shrink may leave free negative; the deficit
        # accessor must agree with it.
        db, dp = rm.deficit(g)
        assert db == max(0, -free_b[g]) and dp == max(0, -free_p[g])


# One random operation: (kind, task_id, grade, amounts...).
_OP = st.tuples(
    st.sampled_from(("freeze", "release", "refreeze", "scale", "reclaim")),
    st.integers(0, 4),  # task id
    st.sampled_from(GRADES),
    st.integers(0, 6),  # bundles / |bundles_delta|
    st.integers(0, 3),  # phones / |phones_delta|
    st.integers(0, 1),  # sign bit for scale deltas (0 = grow, 1 = shrink)
)


@settings(max_examples=120, deadline=None)
@given(ops=st.lists(_OP, min_size=1, max_size=40))
def test_pool_invariant_across_random_op_sequences(ops):
    rm = ResourceManager(ResourcePool(
        {g: 8 for g in GRADES}, {g: 4 for g in GRADES}))
    frozen_by_task: dict[int, dict] = {}  # shadow model of rm._frozen
    for kind, tid, grade, b, p, sign in ops:
        before = _snapshot(rm)
        try:
            if kind == "freeze":
                if tid in frozen_by_task:  # model: one grant per task
                    continue
                rm.freeze(tid, {grade: (b, p)})
                frozen_by_task[tid] = {grade: (b, p)}
            elif kind == "release":
                rm.release(tid)
                frozen_by_task.pop(tid, None)
            elif kind == "refreeze":
                rm.refreeze(tid, {grade: (b, p)})
                frozen_by_task[tid] = {grade: (b, p)}
            elif kind == "scale":
                rm.scale(grade, bundles_delta=-b if sign else b,
                         phones_delta=-p if sign else p)
            else:  # reclaim shrink: may drive free negative, never total
                rm.scale(grade, bundles_delta=-b, phones_delta=-p,
                         reclaim=True)
        except (ValueError, KeyError):
            # Failure path: the pool must be untouched (atomicity).
            assert _snapshot(rm) == before
        _check_invariant(rm, frozen_by_task)
        # frozen() view matches the shadow model for every known task.
        for t, d in frozen_by_task.items():
            assert rm.frozen(t) == d


def test_rejected_shrink_leaves_both_pools_consistent():
    """Regression: ``scale`` used to mutate logical_bundles, then raise on
    physical_devices, leaving free/total inconsistent."""
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 4}))
    rm.freeze(0, {"High": (0, 4)})  # all phones frozen, bundles free
    with pytest.raises(ValueError, match="physical_devices"):
        rm.scale("High", bundles_delta=-2, phones_delta=-1)
    free, total = rm.free(), rm.total()
    assert free.logical_bundles["High"] == 8  # NOT 6: first field untouched
    assert total.logical_bundles["High"] == 8
    assert free.physical_devices["High"] == 0
    assert total.physical_devices["High"] == 4


def test_zero_delta_scale_fires_no_listeners():
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 4}))
    fired = []
    rm.subscribe(lambda: fired.append(1))
    rm.scale("High")  # no-op: both deltas zero
    assert fired == []
    rm.scale("High", bundles_delta=1)
    assert fired == [1]


def test_refreeze_failure_does_not_release_the_old_grant():
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 4}))
    rm.freeze(0, {"High": (8, 2)})
    with pytest.raises(ValueError):
        rm.refreeze(0, {"High": (8, 5)})  # 5 phones never fit (4 total)
    assert rm.frozen(0) == {"High": (8, 2)}
    assert rm.free().logical_bundles["High"] == 0  # still frozen, not leaked


def test_refreeze_grows_one_component_despite_unrelated_deficit():
    """Paying down (or leaving alone) a deficit component must not block
    growing a different component: validation is per-component and only on
    the growing side."""
    rm = ResourceManager(ResourcePool({"High": 4}, {"High": 6}))
    rm.freeze(0, {"High": (4, 0)})
    rm.freeze(1, {"High": (0, 4)})
    rm.scale("High", bundles_delta=-2, reclaim=True)  # free: (-2, 2)
    rm.refreeze(1, {"High": (0, 6)})  # phones grow 4->6; bundles untouched
    assert rm.frozen(1) == {"High": (0, 6)}
    assert rm.free().physical_devices["High"] == 0
    assert rm.deficit("High") == (2, 0)  # untouched by the phone grow
    # Shrinking the deficit component is always legal, even mid-deficit.
    rm.refreeze(0, {"High": (2, 0)})
    assert rm.deficit("High") == (0, 0)


def test_reclaim_shrink_tracks_deficit_until_paid_down():
    rm = ResourceManager(ResourcePool({"High": 8}, {"High": 4}))
    rm.freeze(0, {"High": (8, 4)})
    rm.scale("High", bundles_delta=-4, reclaim=True)
    assert rm.deficit("High") == (4, 0)
    assert rm.total().logical_bundles["High"] == 4
    # Shrinking the frozen grant by the deficit settles the pool.
    rm.refreeze(0, {"High": (4, 4)})
    assert rm.deficit("High") == (0, 0)
    assert rm.free().logical_bundles["High"] == 0
    # Even reclaim cannot remove more than the total capacity.
    with pytest.raises(ValueError, match="total"):
        rm.scale("High", phones_delta=-5, reclaim=True)
