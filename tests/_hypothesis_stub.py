"""Minimal in-repo fallback for ``hypothesis`` (property-based testing).

The tier-1 suite uses a small slice of the hypothesis API (``given``,
``settings``, and a handful of strategies).  CI installs the real package via
``pyproject.toml``'s ``test`` extra; hermetic containers without network
access fall back to this stub so the property tests still *run* (seeded
pseudo-random example generation) instead of failing collection with
``ModuleNotFoundError``.

Differences from real hypothesis: no shrinking, no example database, no
``@example`` replay — just N deterministic random examples per test.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

IS_FALLBACK = True
DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False) -> SearchStrategy:
    span = max_value - min_value

    def draw(rng):
        # Hit the endpoints occasionally — they are where bugs live.
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return float(min_value + span * rng.random())

    return SearchStrategy(draw)


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(size)]

    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[int(rng.integers(len(elements)))])


def builds(target, *arg_strategies, **kw_strategies) -> SearchStrategy:
    def draw(rng):
        args = [s.example(rng) for s in arg_strategies]
        kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
        return target(*args, **kwargs)

    return SearchStrategy(draw)


class settings:
    """Decorator recording ``max_examples`` on the wrapped test."""

    def __init__(self, max_examples: int | None = None, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._hypo_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hypo_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"args={drawn!r} kwargs={drawn_kw!r}"
                    ) from e

        # pytest must not mistake the drawn parameters for fixtures: hide the
        # original signature from inspect (which otherwise follows __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorate


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "sampled_from",
                 "builds"):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    mod.IS_FALLBACK = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
